//! Execution-plan comparison (§4.2 / Fig 6): build all five
//! decomposition plans over the same joint space and run them with the
//! same budget, plus the progressive strategy of §4.3.
//!
//!     cargo run --release --example plan_comparison

use volcanoml::bench::Table;
use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::plan::PlanKind;

fn main() -> anyhow::Result<()> {
    let ds = generate(&registry::by_name("phoneme").unwrap());
    let runtime = volcanoml::bench::try_runtime();
    let evals = 40;
    let mut table = Table::new(
        &format!("plans on {} ({} evals each)", ds.name, evals),
        &["strategy", "valid util", "test util", "secs"]);

    for kind in PlanKind::all() {
        let cfg = VolcanoConfig {
            plan: kind,
            scale: SpaceScale::Large,
            max_evals: evals,
            ..Default::default()
        };
        let out = VolcanoML::new(cfg).run(&ds, runtime.as_ref())?;
        table.row(vec![
            format!("Plan {}", kind.name()),
            format!("{:.4}", out.best_valid_utility),
            format!("{:.4}", out.test_utility),
            format!("{:.1}", out.elapsed_secs),
        ]);
    }
    // progressive strategy (§4.3)
    let cfg = VolcanoConfig {
        progressive: true,
        scale: SpaceScale::Large,
        max_evals: evals,
        ..Default::default()
    };
    let out = VolcanoML::new(cfg).run(&ds, runtime.as_ref())?;
    table.row(vec![
        "Progressive".into(),
        format!("{:.4}", out.best_valid_utility),
        format!("{:.4}", out.test_utility),
        format!("{:.1}", out.elapsed_secs),
    ]);
    table.print();
    println!("\nthe paper's finding: plan CA (VolcanoML's default) wins \
              on most tasks; progressive is fast but riskier.");
    Ok(())
}
