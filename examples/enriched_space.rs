//! Search-space enrichment (§6.3 / Table 2): add the smote_balancer
//! operator to the balancing stage — the fine-grained enrichment
//! auto-sklearn cannot express — plus a user-defined custom FE stage,
//! and compare searches with and without the enrichment on an
//! imbalanced dataset.
//!
//!     cargo run --release --example enriched_space

use std::sync::Arc;

use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::fe::{ops::Fitted, CustomOp, FePipeline};
use volcanoml::space::{Config, ConfigSpace};

/// A domain-specific operator (the paper's astronomy-normalisation
/// motivation): winsorising standardiser.
struct RobustClip;

impl CustomOp for RobustClip {
    fn name(&self) -> &str {
        "robust_clip"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new().float("width", 1.0, 6.0, 3.0)
    }
    fn fit(&self, ds: &volcanoml::data::Dataset, train: &[usize],
           cfg: &Config, _rng: &mut volcanoml::util::rng::Rng)
        -> Fitted {
        let (mean, std) = ds.col_stats(train);
        let width = cfg.f64_or("width", 3.0);
        let scale = std.iter()
            .map(|s| 1.0 / (s.max(1e-9) * width)).collect();
        Fitted::Affine { shift: mean, scale }
    }
}

fn main() -> anyhow::Result<()> {
    let ds = generate(&registry::by_name("pc2").unwrap());
    let runtime = volcanoml::bench::try_runtime();
    println!("dataset pc2: n={}, d={}, class counts {:?}",
             ds.n, ds.d, ds.class_counts());

    // pipeline inspection: plain vs enriched
    let plain = FePipeline::standard(false, false);
    let mut enriched = FePipeline::standard(true, false);
    enriched.add_custom_stage("postprocess",
                              vec![Arc::new(RobustClip)]);
    println!("plain FE space: {} hyper-parameters",
             plain.space().len());
    println!("enriched FE space: {} hyper-parameters \
              (+smote_balancer, +custom stage)",
             enriched.space().len());

    for (label, smote) in [("without smote", false), ("with smote", true)] {
        let cfg = VolcanoConfig {
            scale: SpaceScale::Large,
            enriched_smote: smote,
            max_evals: 40,
            ..Default::default()
        };
        let out = VolcanoML::new(cfg).run(&ds, runtime.as_ref())?;
        println!("{label:>14}: test balanced accuracy = {:.4} \
                  (ensemble {:.4})",
                 out.test_metric_value, out.ensemble_test_utility);
        if let Some(best) = &out.best_config {
            println!("{:>14}  balancer = {}", "",
                     best.str_or("fe:balancer", "none"));
        }
    }
    Ok(())
}
