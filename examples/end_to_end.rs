//! End-to-end system driver — proves all three layers compose on a
//! real workload, and demonstrates the parallel Volcano executor.
//!
//! Part 1 (always runs): a VolcanoML search (plan CA) on a synthetic
//! blob workload, once strictly serially (`workers = 1`, batch of 1 —
//! the exact pre-parallel execution path) and, when `--workers N > 1`,
//! once with batched `do_next` fanned out across N persistent worker
//! threads, once more with cross-leaf super-batching (`--super-batch
//! 0`: a whole conditioning round per `evaluate_batch` submission, so
//! elimination rounds parallelise across arms too), and finally with
//! the async pipeline at depth 2 (the next round is speculatively
//! proposed while the current one is in flight, at the identical eval
//! budget), and a nested-conditioning plan (CC) at pipeline depth 1
//! vs 2 — the recursive scheduler batching across decomposition
//! levels. Prints the incumbents and the wall-clock speedups.
//!
//! Part 2: full searches over several registry datasets whose
//! trainable arms run through the AOT-compiled JAX/Pallas artifacts
//! via PJRT when artifacts are built (degrades to the native roster
//! otherwise). Logs validation curves, held-out test results and PJRT
//! execution stats.
//!
//!     cargo run --release --example end_to_end -- --workers 4

use std::time::Instant;

use volcanoml::baselines::{run_system, BaseSpec, SystemKind};
use volcanoml::bench::{try_runtime, Table};
use volcanoml::cli::Args;
use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::dataset::Task;
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::{generate, GenKind, Profile};
use volcanoml::ensemble::EnsembleMethod;
use volcanoml::plan::PlanKind;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let workers = args.usize_or("workers", 2)?.max(1);
    // super-batch size / pipeline depth for the part-2 registry runs
    // (part 1 sweeps the settings itself); super-batch: 1 = off, 0 =
    // whole conditioning round; pipeline depth: 1 = synchronous
    let super_batch = args.usize_or("super-batch", 1)?;
    let pipeline_depth = args.usize_or("pipeline-depth", 1)?.max(1);
    // FE artifact store for the part-2 runs (part 1 compares on/off
    // itself); trajectory-neutral, so any bound is safe
    let fe_cache_mb = args.usize_or("fe-cache-mb", 0)?;
    // optional Chrome-trace capture of the whole driver run (CI
    // uploads the file as an artifact); trajectory-neutral, so the
    // bit-identity asserts below hold with it on or off
    let trace_out = args.str_opt("trace-out");
    args.finish()?;
    if trace_out.is_some() {
        volcanoml::obs::enable(volcanoml::obs::TRACE);
        volcanoml::obs::trace::clear();
    }
    let evals = std::env::var("E2E_EVALS")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(48);

    // ---- part 1: parallel executor on the synthetic blob workload --
    let blobs = generate(&Profile {
        name: "blobs-e2e".into(),
        task: Task::Classification { n_classes: 3 },
        gen: GenKind::Blobs { sep: 1.5 },
        n: 1600,
        d: 12,
        noise: 0.05,
        imbalance: 1.3,
        redundant: 2,
        wild_scales: false,
        seed: 7,
    });
    let search = |w: usize, batch: usize, super_batch: usize,
                  depth: usize|
        -> anyhow::Result<(f64, f64, usize)> {
        let cfg = VolcanoConfig {
            plan: PlanKind::CA,
            scale: SpaceScale::Medium,
            metric: Metric::BalancedAccuracy,
            max_evals: evals,
            // no ensemble refits: time the search itself
            ensemble: EnsembleMethod::None,
            workers: w,
            eval_batch: batch,
            super_batch,
            pipeline_depth: depth,
            seed: 42,
            ..Default::default()
        };
        let t0 = Instant::now();
        let out = VolcanoML::new(cfg).run(&blobs, None)?;
        Ok((t0.elapsed().as_secs_f64(), out.best_valid_utility,
            out.n_evals))
    };

    println!("== parallel Volcano executor on {} (n={}, d={}, {} \
              evals) ==", blobs.name, blobs.n, blobs.d, evals);
    let (t1, u1, n1) = search(1, 1, 1, 1)?;
    println!("  serial        (workers=1): {t1:7.2}s  best valid \
              {u1:.4}  ({n1} evals)");
    if workers > 1 {
        let (tn, un, nn) = search(workers, 0, 1, 1)?;
        println!("  leaf-batched  (workers={workers}): {tn:7.2}s  best \
                  valid {un:.4}  ({nn} evals)");
        println!("    speedup vs serial: {:.2}x", t1 / tn.max(1e-9));
        assert!(un.is_finite() && nn == n1,
                "parallel run must spend the identical budget");
        // cross-leaf super-batching: keep the leaf batch at 1 (every
        // arm proposes serial-quality candidates) and submit a whole
        // conditioning round per evaluate_batch call — the pool stays
        // saturated across arm boundaries instead of joining after
        // every leaf pull
        let (ts, us, ns) = search(workers, 1, 0, 1)?;
        println!("  super-batched (workers={workers}): {ts:7.2}s  best \
                  valid {us:.4}  ({ns} evals)");
        println!("    speedup vs serial: {:.2}x  vs leaf-batched: \
                  {:.2}x", t1 / ts.max(1e-9), tn / ts.max(1e-9));
        assert!(us.is_finite(),
                "super-batched search must produce an incumbent");
        // async pipeline depth 2: same super-batched rounds and the
        // same eval budget, but while a round is in flight on the
        // pool the coordinator refits surrogates and proposes the
        // next round — the search's "thinking time" leaves the
        // wall-clock hot path
        let (tp, up, np) = search(workers, 1, 0, 2)?;
        println!("  pipelined d=2 (workers={workers}): {tp:7.2}s  best \
                  valid {up:.4}  ({np} evals)");
        println!("    speedup vs serial: {:.2}x  vs depth-1 \
                  super-batched: {:.2}x",
                 t1 / tp.max(1e-9), ts / tp.max(1e-9));
        assert!(up.is_finite(),
                "pipelined search must produce an incumbent");
        assert_eq!(np, ns,
                   "pipeline depth must not change the eval budget");
        // nested-plan cross-level batching (plan CC: conditioning on
        // algorithm, then on an FE stage): propose/observe is total
        // over the block algebra, so one super-batch spans both
        // decomposition levels — and at depth 2 the next nested
        // round is proposed while this one is in flight
        let nested = |depth: usize|
            -> anyhow::Result<(f64, f64, usize)> {
            let cfg = VolcanoConfig {
                plan: PlanKind::CC,
                scale: SpaceScale::Medium,
                metric: Metric::BalancedAccuracy,
                max_evals: evals,
                ensemble: EnsembleMethod::None,
                workers,
                eval_batch: 1,
                super_batch: 0,
                pipeline_depth: depth,
                seed: 42,
                ..Default::default()
            };
            let t0 = Instant::now();
            let out = VolcanoML::new(cfg).run(&blobs, None)?;
            Ok((t0.elapsed().as_secs_f64(), out.best_valid_utility,
                out.n_evals))
        };
        let (tc1, uc1, nc1) = nested(1)?;
        println!("  nested CC d=1 (workers={workers}): {tc1:7.2}s  \
                  best valid {uc1:.4}  ({nc1} evals)");
        let (tc2, uc2, nc2) = nested(2)?;
        println!("  nested CC d=2 (workers={workers}): {tc2:7.2}s  \
                  best valid {uc2:.4}  ({nc2} evals)");
        println!("    nested speedup d=2 vs d=1: {:.2}x",
                 tc1 / tc2.max(1e-9));
        assert!(uc1.is_finite() && uc2.is_finite(),
                "nested searches must produce incumbents");
        assert_eq!(nc1, nc2,
                   "nested runs must spend the identical budget");
    } else {
        println!("  (pass --workers N to compare against the worker \
                  pool, cross-leaf super-batching and the async \
                  pipeline)");
    }

    // FE artifact store: a conditioning plan over the full FE space
    // (CC nests on an FE stage, so whole arms share stage prefixes)
    // at the identical budget, store off vs on. Content addressing
    // makes the store trajectory-neutral — the incumbent must agree
    // bit for bit — while repeated FE prefixes are served from the
    // cache and transforming stages row-shard across the pool.
    let fe_run = |mb: usize| -> anyhow::Result<(
        f64, f64, usize, volcanoml::coordinator::evaluator::EvalStats,
    )> {
        let cfg = VolcanoConfig {
            plan: PlanKind::CC,
            scale: SpaceScale::Large,
            metric: Metric::BalancedAccuracy,
            max_evals: evals,
            ensemble: EnsembleMethod::None,
            workers,
            eval_batch: 1,
            fe_cache_mb: mb,
            seed: 42,
            ..Default::default()
        };
        let t0 = Instant::now();
        let out = VolcanoML::new(cfg).run(&blobs, None)?;
        Ok((t0.elapsed().as_secs_f64(), out.best_valid_utility,
            out.n_evals, out.eval_stats))
    };
    println!("\n== FE artifact store on plan CC ({} evals, \
              workers={workers}) ==", evals);
    let (t_off, u_off, n_off, _) = fe_run(0)?;
    println!("  store off   : {t_off:7.2}s  best valid {u_off:.4}  \
              ({n_off} evals)");
    let (t_on, u_on, n_on, stats) = fe_run(256)?;
    let fe = stats.fe.expect("store was enabled");
    println!("  store 256MB : {t_on:7.2}s  best valid {u_on:.4}  \
              ({n_on} evals)");
    println!("    hit rate {:.0}%  ({} hits + {} coalesced vs {} \
              fitted, {} KiB resident)  speedup vs off: {:.2}x",
             fe.hit_rate() * 100.0, fe.hits, fe.coalesced, fe.misses,
             fe.bytes / 1024, t_off / t_on.max(1e-9));
    assert_eq!(u_on.to_bits(), u_off.to_bits(),
               "the FE store must be trajectory-neutral");
    assert_eq!(n_on, n_off,
               "the FE store must not change the spent budget");
    assert!(fe.hits + fe.coalesced > 0,
            "a conditioning plan over the FE space must share \
             stage prefixes");

    // ---- part 2: registry datasets, PJRT arms when available -------
    let runtime = try_runtime();
    match &runtime {
        Some(rt) => println!(
            "\nPJRT runtime up: {} artifacts, canonical (n_train={}, \
             d={})",
            rt.artifact_names().len(), rt.constants().n_train,
            rt.constants().d),
        None => println!("\nPJRT artifacts not built: running the \
                          native algorithm roster"),
    }

    let datasets = ["quake", "segment", "space_ga"];
    let mut table = Table::new(
        "end-to-end: VolcanoML (CA+BO+ensemble) across registry \
         datasets",
        &["dataset", "task", "evals", "best valid", "test (single)",
          "test (ensemble)", "secs"]);

    for name in datasets {
        let ds = generate(&registry::by_name(name).unwrap());
        let metric = if ds.task.is_classification() {
            Metric::BalancedAccuracy
        } else {
            Metric::Mse
        };
        let spec = BaseSpec {
            scale: SpaceScale::Large,
            metric,
            max_evals: evals,
            budget_secs: f64::INFINITY,
            workers,
            super_batch,
            pipeline_depth,
            fe_cache_mb,
            seed: 42,
        };
        let out = run_system(SystemKind::VolcanoMLMinus, &ds, &spec,
                             None, runtime.as_ref())?;
        println!("\n--- {} ---", ds.name);
        println!("validation improvement curve:");
        for (t, u) in &out.valid_curve {
            println!("  {t:8.2}s  utility {u:.4}");
        }
        table.row(vec![
            ds.name.clone(),
            if ds.task.is_classification() { "cls".into() }
            else { "reg".into() },
            out.n_evals.to_string(),
            format!("{:.4}", out.best_valid_utility),
            format!("{:.4}", out.test_utility),
            format!("{:.4}", out.ensemble_test_utility),
            format!("{:.1}", out.elapsed_secs),
        ]);
    }
    table.print();

    if let Some(rt) = &runtime {
        println!("\nPJRT execution stats (artifact, #execs, total \
                  secs):");
        for (name, n, secs) in rt.exec_stats() {
            println!("  {name:<20} {n:>6}  {secs:>8.2}s");
        }
        println!("\nall layers composed: Rust blocks -> PJRT \
                  executables -> Pallas kernels.");
    }

    if let Some(path) = &trace_out {
        let n = volcanoml::obs::trace::write_chrome_trace(
            std::path::Path::new(path))?;
        let dropped = volcanoml::obs::trace::dropped_events();
        println!("\ntrace: wrote {n} events to {path} ({dropped} \
                  dropped by ring overflow) — load in \
                  chrome://tracing or Perfetto");
    }
    Ok(())
}
