//! End-to-end system driver — proves all three layers compose on a
//! real workload: the Rust coordinator executes a full VolcanoML
//! search (plan CA, conditioning + alternating + joint blocks) whose
//! trainable arms run through the AOT-compiled JAX/Pallas artifacts
//! via PJRT, on several registry datasets. Logs the validation
//! improvement curve, held-out test results and PJRT execution stats.
//! Results are recorded in EXPERIMENTS.md §End-to-end driver.
//!
//!     make artifacts && cargo run --release --example end_to_end

use volcanoml::baselines::{run_system, BaseSpec, SystemKind};
use volcanoml::bench::Table;
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let runtime = Runtime::new(&Runtime::default_dir())?;
    println!("PJRT runtime up: {} artifacts, canonical \
              (n_train={}, d={})",
             runtime.artifact_names().len(),
             runtime.constants().n_train, runtime.constants().d);

    let datasets = ["quake", "segment", "space_ga"];
    let evals = std::env::var("E2E_EVALS")
        .ok().and_then(|v| v.parse().ok()).unwrap_or(60);

    let mut table = Table::new(
        "end-to-end: VolcanoML (CA+BO+ensemble) with PJRT arms",
        &["dataset", "task", "evals", "best valid", "test (single)",
          "test (ensemble)", "secs"]);

    for name in datasets {
        let ds = generate(&registry::by_name(name).unwrap());
        let metric = if ds.task.is_classification() {
            Metric::BalancedAccuracy
        } else {
            Metric::Mse
        };
        let spec = BaseSpec {
            scale: SpaceScale::Large,
            metric,
            max_evals: evals,
            budget_secs: f64::INFINITY,
            seed: 42,
        };
        let out = run_system(SystemKind::VolcanoMLMinus, &ds, &spec,
                             None, Some(&runtime))?;
        println!("\n--- {} ---", ds.name);
        println!("validation improvement curve:");
        for (t, u) in &out.valid_curve {
            println!("  {t:8.2}s  utility {u:.4}");
        }
        table.row(vec![
            ds.name.clone(),
            if ds.task.is_classification() { "cls".into() }
            else { "reg".into() },
            out.n_evals.to_string(),
            format!("{:.4}", out.best_valid_utility),
            format!("{:.4}", out.test_utility),
            format!("{:.4}", out.ensemble_test_utility),
            format!("{:.1}", out.elapsed_secs),
        ]);
    }
    table.print();

    println!("\nPJRT execution stats (artifact, #execs, total secs):");
    for (name, n, secs) in runtime.exec_stats() {
        println!("  {name:<20} {n:>6}  {secs:>8.2}s");
    }
    println!("\nall layers composed: Rust blocks -> PJRT executables \
              -> Pallas kernels.");
    Ok(())
}
