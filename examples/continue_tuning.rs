//! Continue tuning in the conditioning block (§3.3.6 / Fig 12): start
//! a search with a restricted algorithm roster, then add new
//! algorithms mid-run. The conditioning block extends its surviving
//! candidate set instead of restarting, and the active-arm trend shows
//! the bandit re-converging.
//!
//!     cargo run --release --example continue_tuning

use volcanoml::blocks::{Arm, BuildingBlock, ConditioningBlock, Env};
use volcanoml::coordinator::evaluator::PipelineEvaluator;
use volcanoml::coordinator::{joint_space, pipeline_for, roster_for,
                             SpaceScale};
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::data::Split;
use volcanoml::plan::{EngineKind, PlanBuilder, PlanKind};
use volcanoml::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let ds = generate(&registry::by_name("pc4").unwrap());
    let runtime = volcanoml::bench::try_runtime();
    let mut rng = Rng::new(42);
    let split = Split::stratified(&ds, &mut rng);

    let pipeline = pipeline_for(SpaceScale::Large, false, false);
    let algos = roster_for(SpaceScale::Large, ds.task,
                           runtime.is_some());
    let space = joint_space(&pipeline, &algos);
    let all_names: Vec<String> =
        algos.iter().map(|a| a.name().to_string()).collect();
    let (initial, added) = all_names.split_at(all_names.len() - 3);
    println!("initial arms: {initial:?}");
    println!("added mid-run: {added:?}");

    let mut evaluator = PipelineEvaluator::new(
        &ds, split, Metric::BalancedAccuracy, &pipeline, &algos,
        runtime.as_ref(), 42)
        .with_budget(120, f64::INFINITY);

    // plan CA restricted to the initial arms
    let mut builder = PlanBuilder::new(&space, EngineKind::Bo, 42);
    builder.arm_filter = Some(initial.to_vec());
    let mut root = builder.build(PlanKind::CA);

    println!("\nphase 1 (initial roster):");
    let mut trend: Vec<(usize, usize)> = Vec::new();
    for round in 0..4 {
        {
            let mut env = Env::new(&mut evaluator, &mut rng);
            root.do_next(&mut env)?;
        }
        trend.push((evaluator.n_evals(), root.active_children()));
        println!("  round {round}: {} evals, {} active arms, \
                  best={:.4}",
                 evaluator.n_evals(), root.active_children(),
                 root.current_best().map(|(_, y)| y).unwrap_or(0.0));
    }

    // §3.3.6: extend the surviving candidate set with the new arms
    println!("\nadding {} new algorithms (continue tuning, no \
              restart)...", added.len());
    let mut add_builder = PlanBuilder::new(&space, EngineKind::Bo, 43);
    add_builder.arm_filter = Some(added.to_vec());
    let new_arms: Vec<Arm> = add_builder.ca_arms();
    let cond = root
        .as_any_mut()
        .downcast_mut::<ConditioningBlock>()
        .expect("CA root is a conditioning block");
    cond.add_arms(new_arms);

    println!("\nphase 2 (extended roster):");
    for round in 0..6 {
        {
            let mut env = Env::new(&mut evaluator, &mut rng);
            root.do_next(&mut env)?;
        }
        trend.push((evaluator.n_evals(), root.active_children()));
        println!("  round {round}: {} evals, {} active arms, \
                  best={:.4}",
                 evaluator.n_evals(), root.active_children(),
                 root.current_best().map(|(_, y)| y).unwrap_or(0.0));
    }

    println!("\nactive-arm trend (evals, arms): {trend:?}");
    let (best_cfg, best) = root.current_best().unwrap();
    println!("final best: {:.4} with algorithm {}", best,
             best_cfg.str_or("algorithm", "?"));
    Ok(())
}
