//! Embedding-selection stage (§6.3 / Fig 5): extend the FE pipeline
//! with a stage choosing among frozen "pre-trained" embeddings (the
//! TF-Hub substitution, see DESIGN.md) and search it jointly — on the
//! image-like dogs-vs-cats analogue raw pixels defeat tabular models
//! while spectral embeddings crack the task.
//!
//!     cargo run --release --example embedding_selection

use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;

fn main() -> anyhow::Result<()> {
    let ds = generate(&registry::dogs_vs_cats());
    let runtime = volcanoml::bench::try_runtime();
    println!("dogs-vs-cats analogue: n={}, d={} raw texture samples",
             ds.n, ds.d);

    for (label, with_embedding) in
        [("raw pixels only", false), ("with embedding stage", true)] {
        let cfg = VolcanoConfig {
            scale: SpaceScale::Large,
            with_embedding,
            max_evals: 35,
            seed: 11,
            ..Default::default()
        };
        let out = VolcanoML::new(cfg).run(&ds, runtime.as_ref())?;
        let chosen = out.best_config.as_ref()
            .map(|c| c.str_or("fe:embedding", "raw").to_string())
            .unwrap_or_default();
        println!("{label:>22}: test accuracy = {:.4}  \
                  (embedding = {chosen})",
                 out.test_metric_value);
    }
    println!("\npaper's shape: 96.5% with embeddings vs 70.4% without \
              — expect a similar gap here.");
    Ok(())
}
