//! Quickstart: the paper's six-lines-of-code experience (Appendix
//! A.2.2) in Rust. Load a dataset, fit a Classifier, predict.
//!
//!     cargo run --release --example quickstart

use volcanoml::coordinator::automl::{Classifier, VolcanoConfig};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;

fn main() -> anyhow::Result<()> {
    // "dm.load_train('train.csv')" — here: a registry dataset
    let ds = generate(&registry::by_name("segment").unwrap());

    // "clf = Classifier(**params).fit(train_node)"
    let runtime = volcanoml::bench::try_runtime();
    let mut clf = Classifier::new(VolcanoConfig {
        scale: SpaceScale::Medium,
        max_evals: 25,
        ..Default::default()
    });
    let outcome = clf.fit(&ds, runtime.as_ref())?;
    println!("search finished: {} evaluations, test balanced \
              accuracy = {:.4}",
             outcome.n_evals, outcome.test_metric_value);

    // "predictions = clf.predict(test_node)"
    let rows: Vec<usize> = (0..10).collect();
    let labels = clf.predict(&ds, &rows, runtime.as_ref())?;
    println!("first 10 predictions: {labels:?}");
    Ok(())
}
