"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/links/hyper-parameters; assert_allclose against
ref.py is THE core correctness signal for the compiled hot path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_grad import fused_grad
from compile.kernels.distance import pairwise_sq_dists, BIG
from compile.kernels import ref

LINKS = ref.LINKS


def _mk(rng, n, d, c, link, frac_masked=0.0, live_classes=None):
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    if link in ("softmax", "hinge"):
        live = live_classes or c
        lab = rng.integers(0, live, n)
        y = np.zeros((n, c), np.float32)
        y[np.arange(n), lab] = 1.0
        cm = np.zeros((1, c), np.float32)
        cm[0, :live] = 1.0
    else:
        y = rng.normal(0, 1, (n, c)).astype(np.float32)
        cm = np.ones((1, c), np.float32)
    w = rng.normal(0, 0.3, (d, c)).astype(np.float32)
    b = rng.normal(0, 0.1, (1, c)).astype(np.float32)
    mask = np.ones((n, 1), np.float32)
    k = int(n * frac_masked)
    if k:
        mask[n - k:] = 0.0
    return x, y, w, b, mask, cm


@pytest.mark.parametrize("link", LINKS)
def test_fused_grad_matches_ref_basic(link):
    rng = np.random.default_rng(0)
    c = 4 if link in ("softmax", "hinge") else 1
    n, d = 256, 16
    x, y, w, b, mask, cm = _mk(rng, n, d, c, link)
    scal = np.array([[1.0 / n, 1e-3, 1e-4, 0.7]], np.float32)
    gw, gb = fused_grad(x, y, w, b, mask, cm, scal, link=link, block_n=64)
    gw_r, gb_r = ref.fused_grad_ref(x, y, w, b, mask, cm, scal, link)
    np.testing.assert_allclose(gw, gw_r, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(gb, gb_r, rtol=2e-5, atol=2e-6)


@settings(max_examples=25, deadline=None)
@given(
    link=st.sampled_from(LINKS),
    log2n=st.integers(6, 9),
    d=st.integers(2, 24),
    c_live=st.integers(2, 8),
    frac_masked=st.floats(0.0, 0.9),
    l2=st.floats(0.0, 1.0),
    l1=st.floats(0.0, 0.5),
    delta=st.floats(0.05, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_grad_matches_ref_sweep(link, log2n, d, c_live, frac_masked,
                                      l2, l1, delta, seed):
    rng = np.random.default_rng(seed)
    n = 2 ** log2n
    c = 8 if link in ("softmax", "hinge") else 1
    x, y, w, b, mask, cm = _mk(rng, n, d, c, link,
                               frac_masked=frac_masked,
                               live_classes=min(c_live, c))
    scal = np.array([[1.0 / max(mask.sum(), 1), l2, l1, delta]], np.float32)
    bn = min(n, 64)
    gw, gb = fused_grad(x, y, w, b, mask, cm, scal, link=link, block_n=bn)
    gw_r, gb_r = ref.fused_grad_ref(x, y, w, b, mask, cm, scal, link)
    np.testing.assert_allclose(gw, gw_r, rtol=5e-5, atol=5e-6)
    np.testing.assert_allclose(gb, gb_r, rtol=5e-5, atol=5e-6)


@pytest.mark.parametrize("link", LINKS)
def test_fused_grad_all_rows_masked_gives_reg_only(link):
    """With every row masked out, the gradient is exactly the reg term."""
    rng = np.random.default_rng(3)
    c = 4 if link in ("softmax", "hinge") else 1
    x, y, w, b, _, cm = _mk(rng, 128, 8, c, link)
    mask = np.zeros((128, 1), np.float32)
    scal = np.array([[1.0, 0.5, 0.25, 1.0]], np.float32)
    gw, gb = fused_grad(x, y, w, b, mask, cm, scal, link=link, block_n=64)
    np.testing.assert_allclose(gw, 0.5 * w + 0.25 * np.sign(w), rtol=1e-6)
    np.testing.assert_allclose(gb, np.zeros_like(gb), atol=1e-7)


def test_fused_grad_rejects_indivisible_batch():
    rng = np.random.default_rng(1)
    x, y, w, b, mask, cm = _mk(rng, 100, 4, 1, "identity")
    scal = np.array([[0.01, 0.0, 0.0, 1.0]], np.float32)
    with pytest.raises(AssertionError):
        fused_grad(x, y, w, b, mask, cm, scal, link="identity", block_n=64)


def test_softmax_residual_ignores_dead_classes():
    """Probability must not leak into padded class columns."""
    rng = np.random.default_rng(7)
    x, y, w, b, mask, cm = _mk(rng, 64, 8, 8, "softmax", live_classes=3)
    scal = np.array([[1.0 / 64, 0.0, 0.0, 1.0]], np.float32)
    gw, _ = fused_grad(x, y, w, b, mask, cm, scal, link="softmax",
                       block_n=64)
    np.testing.assert_allclose(np.asarray(gw)[:, 3:], 0.0, atol=1e-6)


def test_pairwise_dists_matches_ref():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 1, (128, 12)).astype(np.float32)
    b = rng.normal(0, 1, (256, 12)).astype(np.float32)
    m = np.ones((256, 1), np.float32)
    d = pairwise_sq_dists(a, b, m, block_m=32)
    d_r = ref.pairwise_sq_dists_ref(a, b)
    np.testing.assert_allclose(d, d_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    log2m=st.integers(4, 7),
    n=st.integers(8, 200),
    d=st.integers(1, 24),
    frac_masked=st.floats(0.0, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_dists_sweep(log2m, n, d, frac_masked, seed):
    rng = np.random.default_rng(seed)
    m = 2 ** log2m
    a = rng.normal(0, 2, (m, d)).astype(np.float32)
    b = rng.normal(0, 2, (n, d)).astype(np.float32)
    bm = np.ones((n, 1), np.float32)
    k = int(n * frac_masked)
    if k:
        bm[n - k:] = 0.0
    out = np.asarray(pairwise_sq_dists(a, b, bm, block_m=min(m, 16)))
    d_r = np.asarray(ref.pairwise_sq_dists_ref(a, b))
    live = bm[:, 0] > 0
    np.testing.assert_allclose(out[:, live], d_r[:, live],
                               rtol=1e-3, atol=1e-3)
    if k:
        assert (out[:, ~live] >= BIG * 0.5).all()


def test_pairwise_dists_zero_on_self():
    rng = np.random.default_rng(5)
    a = rng.normal(0, 1, (32, 6)).astype(np.float32)
    m = np.ones((32, 1), np.float32)
    d = np.asarray(pairwise_sq_dists(a, a, m, block_m=16))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-4)
    assert (d >= -1e-4).all()
