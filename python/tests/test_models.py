"""L2 correctness: the trainers learn, shapes hold, KNN is exact.

These run the same jitted functions that aot.py lowers, so passing here
plus the HLO-roundtrip test in Rust means the compiled artifacts compute
the right thing.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import shapes
from compile.models import (make_glm_trainer, make_knn_scorer,
                            make_mlp_trainer)
from compile.kernels import ref


def _pad_cls(rng, m, d_live, n_classes, sep=3.0):
    """Gaussian blobs padded to canonical shapes."""
    n, d, c = shapes.N_TRAIN, shapes.D, shapes.C
    X = np.zeros((n, d), np.float32)
    Y = np.zeros((n, c), np.float32)
    lab = rng.integers(0, n_classes, m)
    centers = rng.normal(0, sep, (n_classes, d_live)).astype(np.float32)
    X[:m, :d_live] = rng.normal(0, 0.6, (m, d_live)) + centers[lab]
    Y[np.arange(m), lab] = 1.0
    mask = np.zeros((n, 1), np.float32)
    mask[:m] = 1.0
    cm = np.zeros((1, c), np.float32)
    cm[0, :n_classes] = 1.0
    return X, Y, mask, cm, lab


def _sched():
    return np.ones((shapes.T_STEPS,), np.float32)


def _hy(lr, l2=0.0, l1=0.0, delta=1.0):
    return np.array([[lr, l2, l1, delta]], np.float32)


@pytest.mark.parametrize("link", ["softmax", "hinge"])
def test_glm_classifier_learns_blobs(link):
    rng = np.random.default_rng(0)
    X, Y, mask, cm, lab = _pad_cls(rng, 400, 4, 3)
    tr = make_glm_trainer(link)
    scores, w, b = tr(X, Y, mask, cm, X[:shapes.N_VAL], _sched(),
                      _hy(0.5, 1e-4))
    pred = np.argmax(np.asarray(scores)[:, :3], axis=1)
    acc = (pred == lab[:shapes.N_VAL]).mean()
    assert acc > 0.9, f"{link} acc={acc}"


def test_glm_returned_weights_reproduce_val_scores():
    """(w, b) returned to Rust must reproduce val_scores exactly."""
    rng = np.random.default_rng(1)
    X, Y, mask, cm, _ = _pad_cls(rng, 300, 6, 4)
    tr = make_glm_trainer("softmax")
    Xv = X[:shapes.N_VAL]
    scores, w, b = tr(X, Y, mask, cm, Xv, _sched(), _hy(0.3, 1e-3))
    np.testing.assert_allclose(np.asarray(scores),
                               Xv @ np.asarray(w) + np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_ridge_approaches_closed_form():
    """Identity link + l2 GD should approach the ridge solution."""
    rng = np.random.default_rng(2)
    n, d = shapes.N_TRAIN, shapes.D
    m, d_live = 500, 8
    X = np.zeros((n, d), np.float32)
    X[:m, :d_live] = rng.normal(0, 1, (m, d_live))
    w_true = rng.normal(0, 1, (d_live, 1)).astype(np.float32)
    Y = np.zeros((n, 1), np.float32)
    Y[:m] = X[:m, :d_live] @ w_true + 0.01 * rng.normal(0, 1, (m, 1))
    mask = np.zeros((n, 1), np.float32)
    mask[:m] = 1.0
    cm = np.ones((1, 1), np.float32)
    lam = 0.1
    tr = make_glm_trainer("identity")
    _, w, b = tr(X, Y, mask, cm, X[:shapes.N_VAL], _sched(),
                 _hy(0.4, lam))
    # closed form on the live block: (X^T X / m + lam I)^-1 X^T y / m
    Xl = X[:m, :d_live]
    A = Xl.T @ Xl / m + lam * np.eye(d_live)
    w_star = np.linalg.solve(A, Xl.T @ Y[:m] / m)
    np.testing.assert_allclose(np.asarray(w)[:d_live], w_star,
                               rtol=0.15, atol=0.05)


def test_lasso_l1_shrinks_irrelevant_features():
    rng = np.random.default_rng(3)
    n, d = shapes.N_TRAIN, shapes.D
    m = 500
    X = np.zeros((n, d), np.float32)
    X[:m] = rng.normal(0, 1, (m, d))
    Y = np.zeros((n, 1), np.float32)
    Y[:m] = 2.0 * X[:m, :1]          # only feature 0 matters
    mask = np.zeros((n, 1), np.float32)
    mask[:m] = 1.0
    cm = np.ones((1, 1), np.float32)
    tr = make_glm_trainer("identity")
    _, w_l1, _ = tr(X, Y, mask, cm, X[:shapes.N_VAL], _sched(),
                    _hy(0.3, 0.0, 0.05))
    w_l1 = np.asarray(w_l1)
    assert abs(w_l1[0, 0]) > 1.0
    assert np.abs(w_l1[1:, 0]).max() < 0.1


def test_huber_link_robust_to_outliers():
    rng = np.random.default_rng(4)
    n, d = shapes.N_TRAIN, shapes.D
    m = 400
    X = np.zeros((n, d), np.float32)
    X[:m] = rng.normal(0, 1, (m, d))
    Y = np.zeros((n, 1), np.float32)
    Y[:m] = X[:m, :1]
    Y[:20] += 50.0                   # gross outliers
    mask = np.zeros((n, 1), np.float32)
    mask[:m] = 1.0
    cm = np.ones((1, 1), np.float32)
    _, w_hub, _ = make_glm_trainer("huber")(
        X, Y, mask, cm, X[:shapes.N_VAL], _sched(), _hy(0.3, 0.0, 0.0, 0.5))
    _, w_sq, _ = make_glm_trainer("identity")(
        X, Y, mask, cm, X[:shapes.N_VAL], _sched(), _hy(0.3))
    # huber estimate of the true slope should beat squared loss
    assert abs(np.asarray(w_hub)[0, 0] - 1.0) < \
        abs(np.asarray(w_sq)[0, 0] - 1.0)


def test_lr_schedule_zero_tail_freezes_training():
    """Fidelity knob: zeroing the schedule tail == training fewer steps."""
    rng = np.random.default_rng(5)
    X, Y, mask, cm, _ = _pad_cls(rng, 300, 4, 3)
    tr = make_glm_trainer("softmax")
    half = np.ones((shapes.T_STEPS,), np.float32)
    half[shapes.T_STEPS // 2:] = 0.0
    s_half, w_half, _ = tr(X, Y, mask, cm, X[:shapes.N_VAL], half,
                           _hy(0.3))
    short = np.ones((shapes.T_STEPS,), np.float32)
    s_full, w_full, _ = tr(X, Y, mask, cm, X[:shapes.N_VAL], short,
                           _hy(0.3))
    assert not np.allclose(np.asarray(w_half), np.asarray(w_full))
    # and the frozen half equals literally stopping at T/2
    tr_short = make_glm_trainer("softmax", t_steps=shapes.T_STEPS // 2)
    s2, w2, _ = tr_short(X, Y, mask, cm, X[:shapes.N_VAL],
                         short[:shapes.T_STEPS // 2], _hy(0.3))
    np.testing.assert_allclose(np.asarray(w_half), np.asarray(w2),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("hidden", list(shapes.MLP_HIDDEN))
def test_mlp_learns_nonlinear_boundary(hidden):
    """GLM cannot fit XOR-ish data; the MLP must."""
    rng = np.random.default_rng(6)
    n, d, c = shapes.N_TRAIN, shapes.D, shapes.C
    m = 480
    X = np.zeros((n, d), np.float32)
    X[:m, :2] = rng.normal(0, 1, (m, 2))
    lab = ((X[:m, 0] * X[:m, 1]) > 0).astype(int)
    Y = np.zeros((n, c), np.float32)
    Y[np.arange(m), lab] = 1.0
    mask = np.zeros((n, 1), np.float32)
    mask[:m] = 1.0
    cm = np.zeros((1, c), np.float32)
    cm[0, :2] = 1.0
    tr = make_mlp_trainer("softmax", hidden)
    hy = np.array([[0.5, 1e-4, 0.9, 0.0]], np.float32)
    seed = np.array([42], np.int32)
    scores, *_ = tr(X, Y, mask, cm, X[:shapes.N_VAL], _sched(), hy, seed)
    pred = np.argmax(np.asarray(scores)[:, :2], axis=1)
    acc = (pred == lab[:shapes.N_VAL]).mean()
    assert acc > 0.85, f"h={hidden} acc={acc}"


def test_mlp_returned_params_reproduce_val_scores():
    rng = np.random.default_rng(7)
    X, Y, mask, cm, _ = _pad_cls(rng, 256, 4, 3)
    tr = make_mlp_trainer("softmax", 16)
    hy = np.array([[0.2, 0.0, 0.5, 0.0]], np.float32)
    Xv = X[:shapes.N_VAL]
    scores, w1, b1, w2, b2 = tr(X, Y, mask, cm, Xv, _sched(), hy,
                                np.array([1], np.int32))
    hv = np.maximum(Xv @ np.asarray(w1) + np.asarray(b1), 0.0)
    np.testing.assert_allclose(np.asarray(scores),
                               hv @ np.asarray(w2) + np.asarray(b2),
                               rtol=1e-4, atol=1e-4)


def test_mlp_seed_changes_init_deterministically():
    rng = np.random.default_rng(8)
    X, Y, mask, cm, _ = _pad_cls(rng, 128, 4, 2)
    tr = make_mlp_trainer("softmax", 16)
    hy = np.array([[0.1, 0.0, 0.0, 0.0]], np.float32)
    args = (X, Y, mask, cm, X[:shapes.N_VAL], _sched(), hy)
    a = np.asarray(tr(*args, np.array([1], np.int32))[1])
    a2 = np.asarray(tr(*args, np.array([1], np.int32))[1])
    b = np.asarray(tr(*args, np.array([2], np.int32))[1])
    np.testing.assert_allclose(a, a2)
    assert not np.allclose(a, b)


def test_knn_exact_neighbours():
    rng = np.random.default_rng(9)
    n, d, c = shapes.N_TRAIN, shapes.D, shapes.C
    m = 300
    X = np.zeros((n, d), np.float32)
    X[:m] = rng.normal(0, 1, (m, d))
    lab = rng.integers(0, 3, m)
    Y = np.zeros((n, c), np.float32)
    Y[np.arange(m), lab] = 1.0
    mask = np.zeros((n, 1), np.float32)
    mask[:m] = 1.0
    Xq = rng.normal(0, 1, (shapes.N_VAL, d)).astype(np.float32)
    dists, neigh = make_knn_scorer()(X, Y, mask, Xq)
    dists, neigh = np.asarray(dists), np.asarray(neigh)
    # brute-force check on a few queries
    for q in range(0, 16):
        full = ((Xq[q] - X[:m]) ** 2).sum(axis=1)
        order = np.argsort(full)[:shapes.K_MAX]
        np.testing.assert_allclose(np.sort(dists[q]),
                                   np.sort(full[order]),
                                   rtol=1e-3, atol=1e-3)
        # 1-NN label match
        assert neigh[q, 0].argmax() == lab[order[0]]
    # distances sorted ascending
    assert (np.diff(dists, axis=1) >= -1e-5).all()


def test_knn_never_returns_masked_rows():
    n, d, c = shapes.N_TRAIN, shapes.D, shapes.C
    X = np.zeros((n, d), np.float32)       # all-zero features
    Y = np.zeros((n, c), np.float32)
    Y[:, 0] = 1.0
    Y[30:, 0] = 0.0
    Y[30:, 1] = 1.0                        # masked rows have class 1
    mask = np.zeros((n, 1), np.float32)
    mask[:30] = 1.0                        # only 30 live rows (>= K_MAX)
    Xq = np.zeros((shapes.N_VAL, d), np.float32)
    _, neigh = make_knn_scorer()(X, Y, mask, Xq)
    neigh = np.asarray(neigh)
    assert (neigh[:, :, 1] == 0).all(), "masked row leaked into neighbours"


def test_link_residual_ref_shapes_and_cases():
    z = np.array([[2.0, -1.0]], np.float32)
    y = np.array([[1.0, 0.0]], np.float32)
    cm = np.ones((1, 2), np.float32)
    # hinge: correct class with margin > 1 -> zero residual there
    r = np.asarray(ref.link_residual_ref(jnp.array(z), jnp.array(y),
                                         "hinge", jnp.array(cm), 1.0))
    assert r[0, 0] == 0.0      # margin satisfied
    assert r[0, 1] == 0.0      # wrong class margin also satisfied (z=-1)
    r2 = np.asarray(ref.link_residual_ref(jnp.array([[0.5, 0.5]], np.float32),
                                          jnp.array(y), "hinge",
                                          jnp.array(cm), 1.0))
    assert r2[0, 0] == -1.0 and r2[0, 1] == 1.0
