"""AOT path: every artifact lowers to parseable HLO text with the
manifest describing exactly the shapes the Rust runtime will feed."""

import json
import os

import numpy as np
import pytest

import jax
from jax._src.lib import xla_client as xc

from compile import aot, shapes

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_artifact_spec_names_unique():
    names = [s[0] for s in aot.artifact_specs()]
    assert len(names) == len(set(names))
    assert "glm_softmax" in names and "knn_reg" in names
    assert len(names) == 4 + 2 * len(shapes.MLP_HIDDEN) + 2


def test_lowering_produces_entry_computation():
    name, fn, ex_args, _ = aot.artifact_specs()[0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*ex_args))
    assert "ENTRY" in text and "HloModule" in text
    # tuple return convention (rust unwraps the tuple)
    assert "ROOT" in text


def test_hlo_text_roundtrips_through_parser():
    """The text must re-parse into an XlaComputation (what Rust does)."""
    name, fn, ex_args, _ = aot.artifact_specs()[0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*ex_args))
    # xla_client can parse HLO text back via the HloModule APIs
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR,
                                                    "manifest.json")),
                    reason="artifacts not built (run make artifacts)")
def test_manifest_matches_specs():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    specs = {s[0]: s for s in aot.artifact_specs()}
    assert set(man["artifacts"]) == set(specs)
    for name, entry in man["artifacts"].items():
        _, fn, ex_args, meta = specs[name]
        assert entry["family"] == meta["family"]
        assert [tuple(i["shape"]) for i in entry["inputs"]] == \
            [a.shape for a in ex_args]
        out = jax.eval_shape(fn, *ex_args)
        assert [tuple(o["shape"]) for o in entry["output_shapes"]] == \
            [o.shape for o in out]
        path = os.path.join(ART_DIR, entry["file"])
        assert os.path.exists(path)
        with open(path) as fh:
            head = fh.read(4096)
        assert "HloModule" in head


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR,
                                                    "manifest.json")),
                    reason="artifacts not built (run make artifacts)")
def test_manifest_constants_match_shapes():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    c = man["constants"]
    assert c["n_train"] == shapes.N_TRAIN
    assert c["n_val"] == shapes.N_VAL
    assert c["d"] == shapes.D
    assert c["c"] == shapes.C
    assert c["t_steps"] == shapes.T_STEPS
    assert c["k_max"] == shapes.K_MAX


def test_stamp_freshness(tmp_path):
    """aot main() skips re-lowering when sources unchanged."""
    out = tmp_path / "arts"
    out.mkdir()
    (out / ".stamp").write_text(aot._source_hash())
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(out)]
    try:
        aot.main()     # must return without writing artifacts
    finally:
        sys.argv = argv
    assert not list(out.glob("*.hlo.txt"))
