"""L1 Pallas kernel: fused GLM gradient step.

One kernel fuses the whole gradient computation of a generalized linear
model on a masked, padded batch:

    Z = X @ W + b          (forward matmul, MXU work)
    R = link'(Z, Y)        (element-wise residual: softmax/identity/
                            hinge/huber)
    gW = X^T R + l2 W + l1 sign(W)
    gb = sum_rows R

The batch dimension is tiled into BN-row blocks via the grid: each grid
step streams one (BN, D) tile of X and the matching (BN, C) tile of Y
through "VMEM" while W/gW stay resident (their BlockSpec index map is
constant, so the output is accumulated across grid steps — the standard
Pallas reduction pattern; it expresses the HBM->VMEM schedule a CUDA
implementation would do with threadblocks + atomics).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the Rust PJRT
client runs directly. On a real TPU the same kernel compiles with
interpret=False and bfloat16 inputs to hit the MXU (see DESIGN.md).

All hyper-parameters (inv_n, l2, l1, huber delta) arrive in a (1, 4)
scalar tile so the compiled artifact serves the entire hyper-parameter
subspace without recompilation. The link nonlinearity is static (one
artifact per algorithm family).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual(z, y, link, cls_mask, delta):
    """dL/dz inside the kernel; mirrors ref.link_residual_ref."""
    if link == "softmax":
        zm = z + (cls_mask - 1.0) * 1e9
        zmax = jnp.max(zm, axis=1, keepdims=True)
        e = jnp.exp(zm - zmax)
        p = e / jnp.sum(e, axis=1, keepdims=True)
        return (p - y) * cls_mask
    if link == "identity":
        return z - y
    if link == "hinge":
        s = 2.0 * y - 1.0
        active = (s * z < 1.0).astype(z.dtype)
        return -s * active * cls_mask
    if link == "huber":
        return jnp.clip(z - y, -delta, delta)
    raise ValueError(f"unknown link {link!r}")


def _kernel(x_ref, y_ref, w_ref, b_ref, mask_ref, cmask_ref, scal_ref,
            gw_ref, gb_ref, *, link):
    i = pl.program_id(0)
    scal = scal_ref[...]
    inv_n, l2, l1, delta = scal[0, 0], scal[0, 1], scal[0, 2], scal[0, 3]
    w = w_ref[...]

    # First tile initialises the accumulators with the regularisation
    # terms (added once, not per tile).
    @pl.when(i == 0)
    def _init():
        gw_ref[...] = l2 * w + l1 * jnp.sign(w)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    x = x_ref[...]                                     # (BN, D)
    z = jnp.dot(x, w, preferred_element_type=jnp.float32) + b_ref[...]
    r = _residual(z, y_ref[...], link, cmask_ref[...], delta)
    r = r * mask_ref[...] * inv_n                      # (BN, C)
    gw_ref[...] += jnp.dot(x.T, r, preferred_element_type=jnp.float32)
    gb_ref[...] += jnp.sum(r, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("link", "block_n"))
def fused_grad(x, y, w, b, mask, cls_mask, scal, *, link, block_n=None):
    """Fused gradient of a GLM loss. Shapes:

    x (N, D), y (N, C), w (D, C), b (1, C), mask (N, 1), cls_mask (1, C),
    scal (1, 4) = [inv_n, l2, l1, delta]. N must be divisible by block_n.
    Returns (gw (D, C), gb (1, C)).
    """
    n, d = x.shape
    c = y.shape[1]
    if block_n is None:
        from .. import shapes
        block_n = min(n, shapes.BN)
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_kernel, link=link),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # X tile
            pl.BlockSpec((block_n, c), lambda i: (i, 0)),   # Y tile
            pl.BlockSpec((d, c), lambda i: (0, 0)),         # W resident
            pl.BlockSpec((1, c), lambda i: (0, 0)),         # b resident
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),   # row mask tile
            pl.BlockSpec((1, c), lambda i: (0, 0)),         # class mask
            pl.BlockSpec((1, 4), lambda i: (0, 0)),         # scalars
        ],
        out_specs=[
            pl.BlockSpec((d, c), lambda i: (0, 0)),         # gW accumulator
            pl.BlockSpec((1, c), lambda i: (0, 0)),         # gb accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, c), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
        ],
        interpret=True,
    )(x, y, w, b, mask, cls_mask, scal)
