"""L1 Pallas kernel: tiled pairwise squared distances (KNN hot-spot).

dist[i, j] = ||a_i||^2 + ||b_j||^2 - 2 a_i . b_j

The query matrix A is tiled into BM-row blocks over the grid; the
reference matrix B stays resident. The -2ab term is the MXU matmul; the
norms are cheap VPU work fused into the same tile pass. Masked reference
rows are pushed to +BIG so lax.top_k never selects padding.

interpret=True for the same reason as fused_grad.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e9


def _kernel(a_ref, b_ref, bmask_ref, o_ref):
    a = a_ref[...]                       # (BM, D)
    b = b_ref[...]                       # (N, D)
    aa = jnp.sum(a * a, axis=1, keepdims=True)          # (BM, 1)
    bb = jnp.sum(b * b, axis=1, keepdims=True).T        # (1, N)
    ab = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    d = aa + bb - 2.0 * ab
    # Padding rows of B must never be chosen as neighbours.
    o_ref[...] = d + (1.0 - bmask_ref[...].T) * BIG


@functools.partial(jax.jit, static_argnames=("block_m",))
def pairwise_sq_dists(a, b, bmask, *, block_m=None):
    """a (M, D) queries, b (N, D) references, bmask (N, 1) row mask.

    Returns (M, N) squared distances with masked columns at +BIG.
    M must be divisible by block_m.
    """
    m, d = a.shape
    n = b.shape[0]
    if block_m is None:
        from .. import shapes
        block_m = min(m, shapes.BM)
    assert m % block_m == 0, f"M={m} not divisible by block_m={block_m}"
    return pl.pallas_call(
        _kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b, bmask)
