# L1: Pallas kernels for the pipeline-evaluation hot-spot.
from .fused_grad import fused_grad  # noqa: F401
from .distance import pairwise_sq_dists  # noqa: F401
