"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest/hypothesis sweeps the Pallas
kernels against these functions (assert_allclose). They are also used by
the L2 models' unit tests as an independent implementation of the same
math.
"""

import jax.numpy as jnp

LINKS = ("softmax", "identity", "hinge", "huber")


def link_residual_ref(z, y, link, cls_mask, delta):
    """Residual dL/dz for one sample batch.

    z: (N, C) raw scores; y: (N, C) targets (one-hot for classification,
    real-valued for regression); cls_mask: (1, C) 1.0 for live class
    columns; delta: huber threshold (scalar).
    """
    if link == "softmax":
        # Masked softmax cross-entropy: dead class columns get -inf logits.
        zm = z + (cls_mask - 1.0) * 1e9
        zmax = jnp.max(zm, axis=1, keepdims=True)
        e = jnp.exp(zm - zmax)
        p = e / jnp.sum(e, axis=1, keepdims=True)
        return (p - y) * cls_mask
    if link == "identity":
        return z - y
    if link == "hinge":
        # One-vs-rest hinge on +-1 targets: s = 2y-1, grad = -s * 1[s*z < 1].
        s = 2.0 * y - 1.0
        active = (s * z < 1.0).astype(z.dtype)
        return -s * active * cls_mask
    if link == "huber":
        return jnp.clip(z - y, -delta, delta)
    raise ValueError(f"unknown link {link!r}")


def fused_grad_ref(x, y, w, b, mask, cls_mask, scal, link):
    """Reference for the fused gradient kernel.

    x: (N, D), y: (N, C), w: (D, C), b: (1, C), mask: (N, 1) row mask,
    cls_mask: (1, C), scal: (1, 4) = [inv_n, l2, l1, delta].
    Returns (gw: (D, C), gb: (1, C)).
    """
    inv_n, l2, l1, delta = scal[0, 0], scal[0, 1], scal[0, 2], scal[0, 3]
    z = x @ w + b
    r = link_residual_ref(z, y, link, cls_mask, delta)
    r = r * mask * inv_n
    gw = x.T @ r + l2 * w + l1 * jnp.sign(w)
    gb = jnp.sum(r, axis=0, keepdims=True)
    return gw, gb


def pairwise_sq_dists_ref(a, b):
    """||a_i - b_j||^2 for a: (M, D), b: (N, D) -> (M, N)."""
    aa = jnp.sum(a * a, axis=1, keepdims=True)
    bb = jnp.sum(b * b, axis=1, keepdims=True).T
    return aa + bb - 2.0 * (a @ b.T)
