"""L2 entry point (kept for the canonical repo layout).

The actual model definitions live in ``compile.models`` (glm/mlp/knn);
this module re-exports them plus the artifact spec table used by
``compile.aot``.
"""

from .models import (make_glm_trainer, make_mlp_trainer, make_knn_scorer,  # noqa: F401
                     glm_example_args, mlp_example_args, knn_example_args)  # noqa: F401
