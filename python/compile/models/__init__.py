# L2: JAX model trainers (build-time only; AOT-lowered to artifacts/).
from .glm import make_glm_trainer, glm_example_args  # noqa: F401
from .mlp import make_mlp_trainer, mlp_example_args  # noqa: F401
from .knn import make_knn_scorer, knn_example_args  # noqa: F401
