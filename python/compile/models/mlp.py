"""L2: one-hidden-layer MLP trainers (classifier + regressor).

Manual backprop with SGD + momentum inside a ``lax.scan``. The hidden
width is an architecture choice, so each width in shapes.MLP_HIDDEN is
compiled as its own artifact variant (mlp_softmax_h16, mlp_softmax_h64,
...); everything else (lr, l2, momentum, init seed, schedule/fidelity)
is a runtime input.

The output-layer residual reuses the same link math as the Pallas kernel
(via kernels.ref) so the two layers agree numerically; the MLP's 3-matmul
step is left to XLA fusion (see DESIGN.md §Perf L2).

Returns (val_scores, w1, b1, w2, b2): Rust predicts test sets natively.
"""

import jax
import jax.numpy as jnp

from .. import shapes
from ..kernels.ref import link_residual_ref


def make_mlp_trainer(link, hidden, *, d=None, c=None, n_train=None,
                     n_val=None, t_steps=None):
    assert link in ("softmax", "identity")
    d = d or shapes.D
    c = c or (shapes.C if link == "softmax" else shapes.C_REG)
    n_train = n_train or shapes.N_TRAIN
    n_val = n_val or shapes.N_VAL
    t_steps = t_steps or shapes.T_STEPS
    h = hidden

    def trainer(x, y, mask, cls_mask, xv, lr_sched, hypers, seed):
        lr, l2, mu = hypers[0, 0], hypers[0, 1], hypers[0, 2]
        n_eff = jnp.maximum(jnp.sum(mask), 1.0)
        inv_n = 1.0 / n_eff

        key = jax.random.PRNGKey(seed[0])
        k1, k2 = jax.random.split(key)
        # He-style init for the relu hidden layer.
        w1 = jax.random.normal(k1, (d, h), jnp.float32) * jnp.sqrt(2.0 / d)
        b1 = jnp.zeros((1, h), jnp.float32)
        w2 = jax.random.normal(k2, (h, c), jnp.float32) * jnp.sqrt(1.0 / h)
        b2 = jnp.zeros((1, c), jnp.float32)
        zeros = (jnp.zeros_like(w1), jnp.zeros_like(b1),
                 jnp.zeros_like(w2), jnp.zeros_like(b2))

        def step(carry, lrt):
            (w1, b1, w2, b2), vel = carry
            h1 = jnp.maximum(x @ w1 + b1, 0.0)           # (N, H)
            z = h1 @ w2 + b2                             # (N, C)
            r = link_residual_ref(z, y, link, cls_mask, 1.0)
            r = r * mask * inv_n
            gw2 = h1.T @ r + l2 * w2
            gb2 = jnp.sum(r, axis=0, keepdims=True)
            dh = (r @ w2.T) * (h1 > 0.0)
            gw1 = x.T @ dh + l2 * w1
            gb1 = jnp.sum(dh, axis=0, keepdims=True)
            step_lr = lr * lrt
            grads = (gw1, gb1, gw2, gb2)
            vel = tuple(mu * v - step_lr * g for v, g in zip(vel, grads))
            params = tuple(p + v for p, v in zip((w1, b1, w2, b2), vel))
            return (params, vel), ()

        ((w1, b1, w2, b2), _), _ = jax.lax.scan(
            step, ((w1, b1, w2, b2), zeros), lr_sched)
        hv = jnp.maximum(xv @ w1 + b1, 0.0)
        val_scores = hv @ w2 + b2
        return (val_scores, w1, b1, w2, b2)

    return trainer


def mlp_example_args(link, hidden, *, d=None, c=None, n_train=None,
                     n_val=None, t_steps=None):
    d = d or shapes.D
    c = c or (shapes.C if link == "softmax" else shapes.C_REG)
    n_train = n_train or shapes.N_TRAIN
    n_val = n_val or shapes.N_VAL
    t_steps = t_steps or shapes.T_STEPS
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((n_train, d), f32),   # x
        jax.ShapeDtypeStruct((n_train, c), f32),   # y
        jax.ShapeDtypeStruct((n_train, 1), f32),   # mask
        jax.ShapeDtypeStruct((1, c), f32),         # cls_mask
        jax.ShapeDtypeStruct((n_val, d), f32),     # xv
        jax.ShapeDtypeStruct((t_steps,), f32),     # lr_sched
        jax.ShapeDtypeStruct((1, 4), f32),         # hypers
        jax.ShapeDtypeStruct((1,), jnp.int32),     # seed
    ]
