"""L2: KNN scorer.

The expensive part — the (N_query x N_train) distance matrix — is the L1
Pallas kernel (kernels.distance). The artifact returns, for each query,
the K_MAX nearest distances and the targets of those neighbours; the Rust
side applies the actual hyper-parameters (k <= K_MAX, uniform vs
distance weighting) to the returned table, so one artifact serves the
whole KNN subspace.

Works for classification (y one-hot, C columns) and regression (C=1).
"""

import jax
import jax.numpy as jnp

from .. import shapes
from ..kernels.distance import pairwise_sq_dists


def make_knn_scorer(*, d=None, c=None, n_train=None, n_query=None,
                    k_max=None):
    d = d or shapes.D
    c = c or shapes.C
    n_train = n_train or shapes.N_TRAIN
    n_query = n_query or shapes.N_VAL
    k_max = k_max or shapes.K_MAX

    def scorer(xtr, ytr, mask, xq):
        dist = pairwise_sq_dists(xq, xtr, mask)        # (M, N), padded=BIG
        # NOTE: lax.top_k lowers to the `topk(..., largest=true)` HLO
        # attribute that xla_extension 0.5.1's text parser rejects, so
        # we sort ascending and slice the first K instead (lowers to the
        # classic `sort` HLO op).
        idx = jnp.broadcast_to(jnp.arange(n_train, dtype=jnp.int32),
                               dist.shape)
        sorted_d, sorted_i = jax.lax.sort_key_val(dist, idx, dimension=1)
        top_d = sorted_d[:, :k_max]                    # (M, K)
        top_i = sorted_i[:, :k_max]
        neigh_y = ytr[top_i]                           # (M, K, C)
        return (top_d, neigh_y)

    return scorer


def knn_example_args(*, d=None, c=None, n_train=None, n_query=None):
    d = d or shapes.D
    c = c or shapes.C
    n_train = n_train or shapes.N_TRAIN
    n_query = n_query or shapes.N_VAL
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((n_train, d), f32),   # xtr
        jax.ShapeDtypeStruct((n_train, c), f32),   # ytr (one-hot / values)
        jax.ShapeDtypeStruct((n_train, 1), f32),   # mask
        jax.ShapeDtypeStruct((n_query, d), f32),   # xq
    ]
