"""L2: generalized linear model trainers.

One jitted function per link covers a whole family of the paper's
algorithm arms:

  softmax  -> logistic regression (multinomial)
  hinge    -> linear SVM (one-vs-rest)
  identity -> ridge / lasso / elastic-net regression (l2/l1 are inputs)
  huber    -> linear SVR-style robust regression

The training loop is a ``lax.scan`` of ``T_STEPS`` fused Pallas gradient
steps (kernels.fused_grad), so the kernel lowers into the same HLO module
that Rust loads. All continuous hyper-parameters are runtime inputs:

  lr_sched (T,)  per-step learning-rate multiplier. Encodes both the
                 schedule (constant / cosine-annealing / step decay) and
                 the fidelity knob (zeros beyond the effective epoch
                 count) without recompilation.
  hypers (1, 4)  [lr, l2, l1, delta]

Returns (val_scores, w, b): Rust scores the validation split from
val_scores and predicts arbitrary test sets natively from (w, b).
"""

import jax
import jax.numpy as jnp

from .. import shapes
from ..kernels.fused_grad import fused_grad


def make_glm_trainer(link, *, d=None, c=None, n_train=None, n_val=None,
                     t_steps=None):
    d = d or shapes.D
    c = c or (shapes.C if link in ("softmax", "hinge") else shapes.C_REG)
    n_train = n_train or shapes.N_TRAIN
    n_val = n_val or shapes.N_VAL
    t_steps = t_steps or shapes.T_STEPS

    def trainer(x, y, mask, cls_mask, xv, lr_sched, hypers):
        lr = hypers[0, 0]
        n_eff = jnp.maximum(jnp.sum(mask), 1.0)
        scal = jnp.stack(
            [1.0 / n_eff, hypers[0, 1], hypers[0, 2], hypers[0, 3]]
        ).reshape(1, 4)

        w0 = jnp.zeros((d, c), jnp.float32)
        b0 = jnp.zeros((1, c), jnp.float32)

        def step(carry, lrt):
            w, b = carry
            gw, gb = fused_grad(x, y, w, b, mask, cls_mask, scal, link=link)
            step_lr = lr * lrt
            return (w - step_lr * gw, b - step_lr * gb), ()

        (w, b), _ = jax.lax.scan(step, (w0, b0), lr_sched)
        val_scores = xv @ w + b
        return (val_scores, w, b)

    return trainer


def glm_example_args(link, *, d=None, c=None, n_train=None, n_val=None,
                     t_steps=None):
    """ShapeDtypeStructs in the trainer's positional order."""
    d = d or shapes.D
    c = c or (shapes.C if link in ("softmax", "hinge") else shapes.C_REG)
    n_train = n_train or shapes.N_TRAIN
    n_val = n_val or shapes.N_VAL
    t_steps = t_steps or shapes.T_STEPS
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((n_train, d), f32),   # x
        jax.ShapeDtypeStruct((n_train, c), f32),   # y
        jax.ShapeDtypeStruct((n_train, 1), f32),   # mask
        jax.ShapeDtypeStruct((1, c), f32),         # cls_mask
        jax.ShapeDtypeStruct((n_val, d), f32),     # xv
        jax.ShapeDtypeStruct((t_steps,), f32),     # lr_sched
        jax.ShapeDtypeStruct((1, 4), f32),         # hypers
    ]
