"""Canonical static shapes for all AOT-compiled artifacts.

Rust pads/subsamples every dataset to these shapes (masking the padding),
so a single HLO artifact per algorithm family serves every hyper-parameter
configuration in its subspace. The subsample fraction doubles as the
multi-fidelity knob used by the Hyperband-family optimizers.

Keep these modest: the whole bench suite runs on one CPU core.
"""

# Training / validation canonical sizes (rows are masked beyond the
# actual dataset size).
N_TRAIN = 512
N_VAL = 256

# Feature dimension after feature engineering (Rust projects/pads to D).
D = 32

# Maximum number of classes (one-hot padded; a class mask disables the
# padding columns inside the kernels).
C = 8

# Regression uses a single output column.
C_REG = 1

# Gradient-descent steps compiled into the lax.scan training loop. The
# per-step learning-rate schedule is a runtime input, so fidelity
# (effective epochs) and schedules (e.g. cosine annealing) need no
# recompilation.
T_STEPS = 100

# KNN: number of neighbours returned by the artifact (Rust applies the
# actual k <= K_MAX and the vote weighting).
K_MAX = 25

# Pallas tile sizes. BN tiles the batch dimension of the fused gradient
# kernel; BM tiles the query dimension of the pairwise-distance kernel.
# At f32 these keep the per-step working set well under a TPU core's
# ~16 MiB VMEM (see DESIGN.md "Hardware-Adaptation"):
#   X tile (BN x D) + Y tile (BN x C) + W (D x C) + gW (D x C)
#   = 128*32 + 128*8 + 32*8 + 32*8 floats ~= 21 KiB.
BN = 128
BM = 64

# MLP hidden widths -> separate compiled variants.
MLP_HIDDEN = (16, 64)
