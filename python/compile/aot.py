"""AOT compile path: lower every L2 trainer to HLO *text* artifacts.

HLO text (NOT ``lowered.compiler_ir("hlo").serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the Rust
``xla`` crate) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--force]

Writes one ``<name>.hlo.txt`` per artifact plus ``manifest.json``
(shapes/dtypes/input order, read by the Rust runtime) and a source-hash
stamp so ``make artifacts`` is a no-op when inputs are unchanged.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import shapes
from .models import (glm_example_args, knn_example_args, make_glm_trainer,
                     make_knn_scorer, make_mlp_trainer, mlp_example_args)


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def artifact_specs():
    """(name, fn, example_args, meta) for every artifact."""
    specs = []
    for link in ("softmax", "hinge", "identity", "huber"):
        c = shapes.C if link in ("softmax", "hinge") else shapes.C_REG
        specs.append((
            f"glm_{link}",
            make_glm_trainer(link),
            glm_example_args(link),
            {"family": "glm", "link": link, "c": c,
             "outputs": ["val_scores", "w", "b"]},
        ))
    for link in ("softmax", "identity"):
        c = shapes.C if link == "softmax" else shapes.C_REG
        for h in shapes.MLP_HIDDEN:
            specs.append((
                f"mlp_{link}_h{h}",
                make_mlp_trainer(link, h),
                mlp_example_args(link, h),
                {"family": "mlp", "link": link, "c": c, "hidden": h,
                 "outputs": ["val_scores", "w1", "b1", "w2", "b2"]},
            ))
    for task, c in (("cls", shapes.C), ("reg", shapes.C_REG)):
        specs.append((
            f"knn_{task}",
            make_knn_scorer(c=c),
            knn_example_args(c=c),
            {"family": "knn", "task": task, "c": c,
             "outputs": ["dists", "neigh_y"]},
        ))
    return specs


def _source_hash():
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        if "__pycache__" in dirpath:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    stamp_path = os.path.join(args.out_dir, ".stamp")
    src_hash = _source_hash()
    if not args.force and not args.only and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read().strip() == src_hash:
                print("artifacts up to date; skipping (use --force)")
                return

    only = set(args.only.split(",")) if args.only else None
    # --only must merge into an existing manifest, not clobber it
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    prior_artifacts = {}
    if only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            prior_artifacts = json.load(f).get("artifacts", {})
    manifest = {
        "constants": {
            "n_train": shapes.N_TRAIN, "n_val": shapes.N_VAL,
            "d": shapes.D, "c": shapes.C, "c_reg": shapes.C_REG,
            "t_steps": shapes.T_STEPS, "k_max": shapes.K_MAX,
            "mlp_hidden": list(shapes.MLP_HIDDEN),
        },
        "artifacts": prior_artifacts,
    }
    for name, fn, ex_args, meta in artifact_specs():
        if only and name not in only:
            continue
        lowered = jax.jit(fn, keep_unused=True).lower(*ex_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.eval_shape(fn, *ex_args)
        ]
        manifest["artifacts"][name] = {
            **meta,
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in ex_args],
            "output_shapes": out_shapes,
        }
        print(f"  lowered {name}: {len(text)} chars, "
              f"{len(ex_args)} inputs, {len(out_shapes)} outputs")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if not only:
        with open(stamp_path, "w") as f:
            f.write(src_hash)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
