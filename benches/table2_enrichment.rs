//! Table 2: enriching the balancing stage with smote_balancer on the
//! five imbalanced datasets — AUSK vs VolcanoML⁻ (no enrichment) vs
//! VolcanoML (with enrichment).

use volcanoml::baselines::{run_system, BaseSpec, SystemKind};
use volcanoml::bench::{bench_scale, save_results, shrink_profile,
                       try_runtime, Table};
use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::util::json::Json;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let mut table = Table::new(
        "Table 2: test accuracy (%) with/without smote enrichment",
        &["dataset", "AUSK", "VolcanoML-", "VolcanoML+smote"]);
    let mut rows_json = Vec::new();
    for profile in registry::imbalanced() {
        let p = shrink_profile(profile, &scale);
        let ds = generate(&p);
        let spec = BaseSpec {
            scale: SpaceScale::Large,
            metric: Metric::Accuracy,
            max_evals: scale.evals,
            budget_secs: f64::INFINITY,
            workers: volcanoml::bench::bench_workers(),
            super_batch: volcanoml::bench::bench_super_batch(),
            pipeline_depth: volcanoml::bench::bench_pipeline_depth(),
            fe_cache_mb: volcanoml::bench::bench_fe_cache_mb(),
            seed: 42,
        };
        let ausk = run_system(SystemKind::AuskMinus, &ds, &spec, None,
                              runtime.as_ref())
            .map(|o| o.test_metric_value).unwrap_or(f64::NAN);
        let vminus = run_system(SystemKind::VolcanoMLMinus, &ds, &spec,
                                None, runtime.as_ref())
            .map(|o| o.test_metric_value).unwrap_or(f64::NAN);
        // VolcanoML with the smote-enriched balancing stage
        let cfg = VolcanoConfig {
            scale: SpaceScale::Large,
            metric: Metric::Accuracy,
            max_evals: scale.evals,
            enriched_smote: true,
            seed: 42,
            ..Default::default()
        };
        let venr = VolcanoML::new(cfg).run(&ds, runtime.as_ref())
            .map(|o| o.test_metric_value).unwrap_or(f64::NAN);
        table.row_f(&ds.name,
                    &[ausk * 100.0, vminus * 100.0, venr * 100.0], 2);
        rows_json.push(Json::obj(vec![
            ("dataset", Json::Str(ds.name.clone())),
            ("ausk", Json::Num(ausk)),
            ("volcano_minus", Json::Num(vminus)),
            ("volcano_smote", Json::Num(venr)),
        ]));
        eprintln!("  [{}] done", ds.name);
    }
    table.print();
    println!("(paper Table 2: enrichment helps most on pc2 — +3.57 \
              points over AUSK)");
    save_results("table2_enrichment", &Json::Arr(rows_json));
}
