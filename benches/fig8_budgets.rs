//! Fig 8: average test errors on four large classification datasets
//! under a ladder of budgets (the paper uses 2h..24h; we use an
//! evaluation-count ladder at the same ratios).

use volcanoml::baselines::SystemKind;
use volcanoml::bench::{bench_scale, render_curves, run_matrix,
                       save_results, shrink_profile, try_runtime};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let systems = [SystemKind::VolcanoMLMinus, SystemKind::AuskMinus,
                   SystemKind::Tpot];
    let names = ["higgs", "covertype", "mnist_784", "electricity"];
    let profiles: Vec<_> = registry::large_classification()
        .into_iter()
        .filter(|p| names.contains(&p.name.as_str()))
        .map(|p| shrink_profile(p, &scale))
        .collect();
    // budget ladder 1x / 2x / 4x (paper: 2h/4h/.../24h)
    let ladder = [scale.evals / 2, scale.evals, scale.evals * 2];

    let mut series: Vec<(String, Vec<(f64, f64)>)> = systems
        .iter()
        .map(|s| (s.name(), Vec::new()))
        .collect();
    for &evals in &ladder {
        eprintln!("== budget {evals} evals ==");
        let m = run_matrix(&profiles, &systems, SpaceScale::Large,
                           evals, 42, None, runtime.as_ref());
        for (si, serie) in series.iter_mut().enumerate() {
            // average test error over the four datasets
            let err: f64 = m.metric_value.iter()
                .map(|row| 1.0 - row[si])
                .sum::<f64>() / m.metric_value.len() as f64;
            serie.1.push((evals as f64, err));
        }
        save_results(&format!("fig8_budget{evals}"), &m.to_json());
    }
    print!("{}", render_curves(
        "Fig 8: avg test error vs budget (4 large CLS datasets)",
        "evaluation budget", &series));
    println!("(paper's shape: VolcanoML's curve sits below both \
              baselines at every budget; on Higgs its 4h point beats \
              their 24h points)");
}
