//! Fig 9 / Table 3: the six Kaggle competitions against the four
//! anonymised commercial platforms (strategy simulators — see
//! DESIGN.md Substitutions) plus VolcanoML⁻/VolcanoML.

use volcanoml::baselines::SystemKind;
use volcanoml::bench::{bench_scale, run_matrix, save_results,
                       shrink_profile, try_runtime, Table};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;
use volcanoml::meta::MetaCorpus;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let corpus = std::env::var("VOLCANO_CORPUS")
        .ok()
        .and_then(|p| MetaCorpus::load(std::path::Path::new(&p)).ok());
    let systems = [
        SystemKind::Platform(1), SystemKind::Platform(2),
        SystemKind::Platform(3), SystemKind::Platform(4),
        SystemKind::VolcanoMLMinus, SystemKind::VolcanoML,
    ];
    let profiles: Vec<_> = registry::kaggle()
        .into_iter()
        .map(|p| shrink_profile(p, &scale))
        .collect();
    let m = run_matrix(&profiles, &systems, SpaceScale::Large,
                       scale.evals, 42, corpus.as_ref(),
                       runtime.as_ref());

    let mut table = Table::new(
        "Fig 9 / Table 3: test error on Kaggle tasks (lower better)",
        &["competition", "Plat1", "Plat2", "Plat3", "Plat4",
          "VolcanoML-", "VolcanoML"]);
    for (d, row) in m.metric_value.iter().enumerate() {
        let errs: Vec<f64> = row.iter().map(|v| 1.0 - v).collect();
        table.row_f(&m.datasets[d], &errs, 4);
    }
    table.print();
    let ranks = m.average_ranks();
    println!("average ranks: {:?}",
             m.systems.iter().zip(&ranks)
                 .map(|(s, r)| format!("{s}={r:.2}"))
                 .collect::<Vec<_>>());
    println!("(paper: VolcanoML at least comparable to, often better \
              than, all four platforms)");
    save_results("fig9_platforms", &m.to_json());
}
