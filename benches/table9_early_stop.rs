//! Table 9: VolcanoML (SMAC joint blocks) and VolcanoML⁺ (MFES-HB
//! joint blocks) vs standalone Hyperband / BOHB / MFES-HB on five
//! classification and five regression datasets.

use volcanoml::baselines::SystemKind;
use volcanoml::bench::{bench_scale, run_matrix, save_results,
                       shrink_profile, try_runtime, Table};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let systems = [SystemKind::VolcanoMLMinus, SystemKind::VolcanoMLPlus,
                   SystemKind::Hyperband, SystemKind::Bohb,
                   SystemKind::MfesHb];
    let cls_names = ["puma8NH", "kin8nm", "cpu_act", "puma32H",
                     "phoneme"];
    let reg_names = ["puma8NH", "kin8nm", "cpu_small", "puma32H",
                     "cpu_act"];

    for (label, corpus, names) in [
        ("CLS (test accuracy %)",
         registry::medium_classification(), &cls_names),
        ("REG (test MSE)", registry::regression(), &reg_names),
    ] {
        let profiles: Vec<_> = corpus
            .into_iter()
            .filter(|p| names.contains(&p.name.as_str()))
            .map(|p| shrink_profile(p, &scale))
            .collect();
        eprintln!("== Table 9 {label} ==");
        let m = run_matrix(&profiles, &systems, SpaceScale::Large,
                           scale.evals, 42, None, runtime.as_ref());
        let mut table = Table::new(
            &format!("Table 9 {label}"),
            &["dataset", "VolcanoML", "VolcanoML+", "HyperBand",
              "BOHB", "MFES-HB"]);
        for (d, row) in m.metric_value.iter().enumerate() {
            let vals: Vec<f64> = if label.starts_with("CLS") {
                row.iter().map(|v| v * 100.0).collect()
            } else {
                row.clone()
            };
            table.row_f(&m.datasets[d], &vals, 3);
        }
        table.row_f("Average Rank", &m.average_ranks(), 2);
        table.print();
        save_results(&format!("table9_{}",
                              &label[..3].to_lowercase()),
                     &m.to_json());
    }
    println!("(paper Table 9: VolcanoML beats the standalone \
              early-stopping methods; VolcanoML+ is best on CLS — \
              decomposition and early-stopping compose)");
}
