//! Tables 4-6: average ranks of {TPOT, AUSK, VolcanoML} across the
//! three search spaces at three budget ladders (paper: 1800/5400,
//! 3600/10800, 7200/21600 seconds; here 1x / 2x / 4x the base
//! evaluation budget).

use volcanoml::baselines::SystemKind;
use volcanoml::bench::{bench_scale, run_matrix, save_results,
                       shrink_profile, try_runtime, Table};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let systems = [SystemKind::Tpot, SystemKind::AuskMinus,
                   SystemKind::VolcanoMLMinus];
    let cls: Vec<_> = registry::medium_classification()
        .into_iter().take(scale.datasets_cap)
        .map(|p| shrink_profile(p, &scale)).collect();
    let reg: Vec<_> = registry::regression()
        .into_iter().take(scale.datasets_cap)
        .map(|p| shrink_profile(p, &scale)).collect();

    // quick mode trims the grid (full mode runs the paper's 3x3)
    let full = std::env::var("VOLCANO_BENCH").as_deref() == Ok("full");
    let ladder: &[(usize, usize)] = if full {
        &[(4usize, 1usize), (5, 2), (6, 4)]
    } else {
        &[(4, 1), (5, 2)]
    };
    for &(t_idx, mult) in ladder {
        let evals = scale.evals * mult;
        let mut table = Table::new(
            &format!("Table {t_idx}: average ranks at {evals} evals \
                      (lower better)"),
            &["space-task", "TPOT", "AUSK", "VolcanoML"]);
        for (label, profiles) in [("CLS", &cls), ("REG", &reg)] {
            let spaces: &[SpaceScale] = if full {
                &[SpaceScale::Small, SpaceScale::Medium,
                  SpaceScale::Large]
            } else {
                &[SpaceScale::Small, SpaceScale::Large]
            };
            for &space in spaces {
                eprintln!("== T{t_idx} {} - {} ==", space.name(), label);
                let m = run_matrix(profiles, &systems, space, evals,
                                   42 + mult as u64, None,
                                   runtime.as_ref());
                table.row_f(&format!("{} - {}", space.name(), label),
                            &m.average_ranks(), 2);
                save_results(&format!("table{t_idx}_{}_{}",
                                      space.name(), label),
                             &m.to_json());
            }
        }
        table.print();
    }
    println!("(paper Tables 4-6: VolcanoML's rank advantage grows \
              with both budget and space size)");
}
