//! Table 10 + Fig 11: the ten large classification datasets — final
//! test balanced accuracy per system plus test-error-vs-budget curves
//! on four of them (the speed-up statistic the paper reports).

use volcanoml::baselines::SystemKind;
use volcanoml::bench::{bench_scale, peak_rss_bytes, render_curves,
                       run_matrix, save_bench_summary, save_results,
                       shrink_profile, try_runtime, Table};
use volcanoml::util::json::Json;
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let systems = [SystemKind::Tpot, SystemKind::AuskMinus,
                   SystemKind::VolcanoMLMinus];
    let profiles: Vec<_> = registry::large_classification()
        .into_iter()
        .take(scale.datasets_cap.max(4))
        .map(|p| shrink_profile(p, &scale))
        .collect();
    let m = run_matrix(&profiles, &systems, SpaceScale::Large,
                       scale.evals, 42, None, runtime.as_ref());

    let mut table = Table::new(
        "Table 10: test balanced accuracy on large datasets",
        &["dataset", "TPOT", "AUSK", "VolcanoML"]);
    let mut volcano_best = 0;
    for (d, row) in m.metric_value.iter().enumerate() {
        table.row_f(&m.datasets[d], row, 4);
        if row[2] >= row[0] && row[2] >= row[1] {
            volcano_best += 1;
        }
    }
    table.print();
    println!("VolcanoML best on {volcano_best}/{} (paper: 8/10)",
             m.datasets.len());
    save_results("table10_large", &m.to_json());

    // ---- Fig 11: validation-error-vs-time curves on 4 datasets -----
    println!("\n== Fig 11: test error vs time on four datasets ==");
    use volcanoml::baselines::{run_system, BaseSpec};
    use volcanoml::data::metrics::Metric;
    use volcanoml::data::synthetic::generate;
    let mut series = Vec::new();
    // per-phase wall-clock profile of the last VolcanoML run, for the
    // machine-readable summary (empty when VOLCANO_PROFILE=0)
    let mut profile = volcanoml::obs::profile::RunProfile::default();
    for p in profiles.iter().take(4) {
        let ds = generate(p);
        for &sys in &systems {
            let spec = BaseSpec {
                scale: SpaceScale::Large,
                metric: Metric::BalancedAccuracy,
                max_evals: scale.evals,
                budget_secs: f64::INFINITY,
                workers: volcanoml::bench::bench_workers(),
                super_batch: volcanoml::bench::bench_super_batch(),
                pipeline_depth:
                    volcanoml::bench::bench_pipeline_depth(),
                fe_cache_mb: volcanoml::bench::bench_fe_cache_mb(),
                seed: 43,
            };
            if let Ok(out) = run_system(sys, &ds, &spec, None,
                                        runtime.as_ref()) {
                let curve: Vec<(f64, f64)> = out.test_curve.iter()
                    .map(|(t, u)| (*t, 1.0 - u)).collect();
                series.push((format!("{}/{}", ds.name, sys.name()),
                             curve));
                if sys == SystemKind::VolcanoMLMinus {
                    profile = out.profile.clone();
                }
            }
        }
    }
    print!("{}", render_curves("Fig 11 curves (test error vs secs)",
                               "seconds", &series));
    println!("(paper: VolcanoML reaches the baselines' final error \
              4.3-10.5x faster than TPOT, 4.8-11x faster than AUSK)");

    // Machine-readable summary at the repo root for the CI artifact
    // step. Peak RSS is the columnar-substrate statistic: splits and
    // fidelity subsets are row views and FE stages share untouched
    // column chunks, so the high-water mark stays near one copy of
    // the data instead of one per materialised split/stage.
    let summary = Json::obj(vec![
        ("bench", Json::Str("table10_large".into())),
        ("matrix", m.to_json()),
        ("volcano_best", Json::Num(volcano_best as f64)),
        ("peak_rss_bytes", match peak_rss_bytes() {
            Some(b) => Json::Num(b as f64),
            None => Json::Null,
        }),
        ("profile", profile.to_json()),
    ]);
    save_bench_summary("table10", &summary);
}
