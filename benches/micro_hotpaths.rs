//! Micro-benchmarks of the hot paths (§Perf): one BO iteration
//! (surrogate refit + candidate scoring), native model fits, FE
//! operators, PJRT execute, and the coordinator's do_next dispatch
//! overhead. These are the numbers the EXPERIMENTS.md §Perf
//! before/after table tracks.

use volcanoml::bench::{bench, peak_rss_bytes, save_bench_summary,
                       timing_to_json, try_runtime, Table, Timing};
use volcanoml::coordinator::evaluator::PipelineEvaluator;
use volcanoml::coordinator::{joint_space, pipeline_for, roster_for,
                             SpaceScale};
use volcanoml::blocks::Objective;
use volcanoml::data::dataset::Task;
use volcanoml::data::metrics::Metric;
use volcanoml::data::synthetic::{generate, GenKind, Profile};
use volcanoml::data::Split;
use volcanoml::opt::{Optimizer, SmacBo};
use volcanoml::util::rng::Rng;

/// Render one timing as a table row and keep it for the
/// `BENCH_micro_hotpaths.json` summary.
fn record(table: &mut Table, timings: &mut Vec<Timing>, label: &str,
          t: Timing) {
    table.row(vec![label.to_string(), t.per_iter_label(),
                   t.iters.to_string()]);
    timings.push(Timing { name: label.to_string(), ..t });
}

fn main() {
    let mut table = Table::new("micro hot paths",
                               &["operation", "mean", "iters"]);
    let mut timings: Vec<Timing> = Vec::new();
    let mut rng = Rng::new(0);

    // ---- kernel layer: pre-port scalar forms vs lane kernels --------
    // Every run benches BOTH the pre-port loop shape ("(pre)") and the
    // util::kernels replacement ("(lanes)"), so the speedup ratio is
    // machine-independent evidence of the kernel layer's win; the
    // absolute medians are additionally gated against
    // BENCH_baseline.json by tools/benchdiff.
    {
        use volcanoml::util::kernels;
        let n = 1 << 16;
        let a: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin())
            .collect();
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.59).cos())
            .collect();

        let t = bench("dot_pre", 3, 40, || {
            let s: f64 =
                a.iter().zip(&b).map(|(x, y)| x * y).sum();
            std::hint::black_box(s);
        });
        record(&mut table, &mut timings, "kernel dot 64k f64 (pre)", t);
        let t = bench("dot_lanes", 3, 40, || {
            std::hint::black_box(kernels::dot(&a, &b));
        });
        record(&mut table, &mut timings, "kernel dot 64k f64 (lanes)",
               t);

        let col: Vec<f32> = (0..n).map(|i| (i as f64 * 0.13).sin()
            as f32).collect();
        let rows_idx: Vec<usize> = (0..n - n / 4).collect();
        let t = bench("moments_pre", 3, 40, || {
            // pre-port col_moments shape: two scalar passes
            let mut s = 0.0f64;
            for &i in &rows_idx {
                s += col[i] as f64;
            }
            let m = s / rows_idx.len() as f64;
            let mut q = 0.0f64;
            for &i in &rows_idx {
                let dlt = col[i] as f64 - m;
                q += dlt * dlt;
            }
            std::hint::black_box((m, q));
        });
        record(&mut table, &mut timings,
               "kernel moments 48k-row col (pre)", t);
        let t = bench("moments_lanes", 3, 40, || {
            std::hint::black_box(
                kernels::moments_indexed_f32(&col, &rows_idx));
        });
        record(&mut table, &mut timings,
               "kernel moments 48k-row col (lanes)", t);

        let (mr, mk, mc) = (96usize, 96usize, 96usize);
        let ma: Vec<f64> = (0..mr * mk)
            .map(|i| (i as f64 * 0.11).sin()).collect();
        let mb: Vec<f64> = (0..mk * mc)
            .map(|i| (i as f64 * 0.17).cos()).collect();
        let t = bench("matmul_pre", 2, 20, || {
            // pre-port Mat::matmul: ikj with the zero-skip branch
            let mut out = vec![0.0f64; mr * mc];
            for i in 0..mr {
                let arow = &ma[i * mk..(i + 1) * mk];
                let orow = &mut out[i * mc..(i + 1) * mc];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &mb[kk * mc..(kk + 1) * mc];
                    for j in 0..mc {
                        orow[j] += av * brow[j];
                    }
                }
            }
            std::hint::black_box(out);
        });
        record(&mut table, &mut timings, "kernel matmul 96^3 (pre)", t);
        let t = bench("matmul_lanes", 2, 20, || {
            std::hint::black_box(kernels::matmul(&ma, &mb, mr, mk, mc));
        });
        record(&mut table, &mut timings, "kernel matmul 96^3 (lanes)",
               t);

        let (tr, tc) = (384usize, 256usize);
        let tm: Vec<f64> = (0..tr * tc)
            .map(|i| (i as f64 * 0.23).sin()).collect();
        let t = bench("transpose_pre", 3, 40, || {
            // pre-port Mat::t(): naive strided writes
            let mut out = vec![0.0f64; tr * tc];
            for i in 0..tr {
                for j in 0..tc {
                    out[j * tr + i] = tm[i * tc + j];
                }
            }
            std::hint::black_box(out);
        });
        record(&mut table, &mut timings,
               "kernel transpose 384x256 (pre)", t);
        let t = bench("transpose_lanes", 3, 40, || {
            std::hint::black_box(kernels::transpose(&tm, tr, tc));
        });
        record(&mut table, &mut timings,
               "kernel transpose 384x256 (lanes)", t);

        let (gn, gd) = (8192usize, 16usize);
        let gcols: Vec<Vec<f32>> = (0..gd)
            .map(|j| (0..gn).map(|i| ((i * gd + j) as f64 * 0.29).sin()
                as f32).collect())
            .collect();
        let t = bench("gather_pre", 3, 40, || {
            // pre-port to_row_major: one full column walk per row
            let mut x = Vec::with_capacity(gn * gd);
            for i in 0..gn {
                x.extend(gcols.iter().map(|c| c[i]));
            }
            std::hint::black_box(x);
        });
        record(&mut table, &mut timings, "kernel gather 8192x16 (pre)",
               t);
        let gview: Vec<&[f32]> =
            gcols.iter().map(|c| c.as_slice()).collect();
        let t = bench("gather_lanes", 3, 40, || {
            let mut x = Vec::new();
            kernels::gather_range_rowmajor(&gview, 0, gn, &mut x);
            std::hint::black_box(x);
        });
        record(&mut table, &mut timings,
               "kernel gather 8192x16 (lanes)", t);
    }

    // ---- BO iteration on a 20-dim space with 60 observations -------
    let space = {
        let mut cs = volcanoml::space::ConfigSpace::new();
        for i in 0..20 {
            cs = cs.float(&format!("x{i}"), 0.0, 1.0, 0.5);
        }
        cs
    };
    let mut bo = SmacBo::new(space.clone(), 1);
    for _ in 0..60 {
        let cfg = space.sample(&mut rng);
        let y = cfg.f64_or("x0", 0.0);
        bo.observe(cfg, y);
    }
    let t = bench("bo_suggest", 2, 10, || {
        std::hint::black_box(bo.suggest(&mut rng));
    });
    record(&mut table, &mut timings,
           "BO suggest (refit+EI, 60 obs, 20d)", t);

    // ---- native algorithm fits --------------------------------------
    let ds = generate(&Profile {
        name: "micro".into(),
        task: Task::Classification { n_classes: 2 },
        gen: GenKind::Checker { cells: 3 },
        n: 800,
        d: 16,
        noise: 0.05,
        imbalance: 1.0,
        redundant: 2,
        wild_scales: false,
        seed: 5,
    });
    let train: Vec<usize> = (0..640).collect();
    for name in ["decision_tree", "random_forest", "lightgbm",
                 "gaussian_nb"] {
        let algo = volcanoml::algos::algo_by_name(name, ds.task)
            .unwrap();
        let cfg = algo.space().default_config();
        let t = bench(name, 1, 5, || {
            let mut ctx = volcanoml::algos::EvalContext::new(None, 7);
            std::hint::black_box(
                algo.fit(&ds, &train, &cfg, &mut ctx).unwrap());
        });
        record(&mut table, &mut timings,
               &format!("fit {name} (640x16)"), t);
    }

    // ---- FE operators ----------------------------------------------
    for op in ["standard", "quantile"] {
        let cfg = volcanoml::fe::ops::scaler_space(op).default_config();
        let t = bench(op, 1, 5, || {
            let f = volcanoml::fe::ops::fit_scaler(op, &ds, &train,
                                                   &cfg);
            std::hint::black_box(f.apply(&ds));
        });
        record(&mut table, &mut timings,
               &format!("scaler {op} (800x16)"), t);
    }
    {
        let cfg = volcanoml::fe::ops::transformer_space("pca")
            .default_config();
        let t = bench("pca", 1, 5, || {
            let mut r = Rng::new(1);
            let f = volcanoml::fe::ops::fit_transformer(
                "pca", &ds, &train, &cfg, &mut r);
            std::hint::black_box(f.apply(&ds));
        });
        record(&mut table, &mut timings, "transformer pca (800x16)",
               t);
    }

    // ---- FE artifact store: miss+publish vs hit ---------------------
    {
        use std::sync::Arc;
        use volcanoml::cache::{FeStore, Fingerprint, Resolved};
        let store = FeStore::new(256 * 1024 * 1024);
        let art_ds = Arc::new(ds.clone());
        let art_train = Arc::new(train.clone());
        let mut salt = 0u64;
        let t = bench("fe_store_miss", 2, 200, || {
            salt += 1;
            let fp = Fingerprint::new().push_u64(salt);
            match store.begin(fp) {
                Resolved::Compute(t) => {
                    std::hint::black_box(t.publish(
                        art_ds.clone(), art_train.clone()));
                }
                Resolved::Ready(_) => unreachable!("fresh key"),
            }
        });
        record(&mut table, &mut timings,
               "FE store miss+publish (800x16 artifact)", t);
        let hot = Fingerprint::new().push_str("hot");
        if let Resolved::Compute(tk) = store.begin(hot) {
            tk.publish(art_ds.clone(), art_train.clone());
        }
        let t = bench("fe_store_hit", 2, 200, || {
            std::hint::black_box(store.lookup(hot).unwrap());
        });
        record(&mut table, &mut timings,
               "FE store hit (lookup + LRU stamp)", t);
    }

    // ---- row-sharded FE apply over the worker pool ------------------
    {
        let big = generate(&Profile {
            name: "micro-big".into(),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Checker { cells: 3 },
            n: 20_000,
            d: 16,
            noise: 0.05,
            imbalance: 1.0,
            redundant: 2,
            wild_scales: false,
            seed: 6,
        });
        let btrain: Vec<usize> = (0..16_000).collect();
        let cfg = volcanoml::fe::ops::scaler_space("quantile")
            .default_config();
        let f = volcanoml::fe::ops::fit_scaler("quantile", &big,
                                               &btrain, &cfg);
        for workers in [1usize, 4] {
            let ex = volcanoml::runtime::executor::Executor::new(
                workers);
            let t = bench("apply_sharded", 1, 5, || {
                std::hint::black_box(f.apply_sharded(&big, &ex));
            });
            record(&mut table, &mut timings,
                   &format!("quantile apply row-sharded w={workers} \
                             (20000x16)"), t);
        }
    }

    // ---- full pipeline evaluation (the objective) --------------------
    let pipeline = pipeline_for(SpaceScale::Large, false, false);
    let algos = roster_for(SpaceScale::Large, ds.task, false);
    let jspace = joint_space(&pipeline, &algos);
    let split = Split::stratified(&ds, &mut Rng::new(2));
    let mut ev = PipelineEvaluator::new(&ds, split,
        Metric::BalancedAccuracy, &pipeline, &algos, None, 11);
    let cfg = jspace.default_config();
    let mut fid = 0.90;
    let t = bench("evaluate", 1, 5, || {
        // unique fidelity per call => cache miss (measures real work)
        fid += 1e-4;
        std::hint::black_box(ev.evaluate(&cfg, fid).unwrap());
    });
    record(&mut table, &mut timings, "pipeline evaluate (default cfg)",
           t);

    // ---- PJRT execute ------------------------------------------------
    if let Some(rt) = try_runtime() {
        let c = rt.constants().clone();
        let mk = |n: usize| vec![0.1f32; n];
        // warm compile
        let inputs = || {
            vec![
                volcanoml::runtime::Input::F32(mk(c.n_train * c.d),
                    vec![c.n_train, c.d]),
                volcanoml::runtime::Input::F32(mk(c.n_train * c.c),
                    vec![c.n_train, c.c]),
                volcanoml::runtime::Input::F32(mk(c.n_train),
                    vec![c.n_train, 1]),
                volcanoml::runtime::Input::F32(mk(c.c), vec![1, c.c]),
                volcanoml::runtime::Input::F32(mk(c.n_val * c.d),
                    vec![c.n_val, c.d]),
                volcanoml::runtime::Input::F32(mk(c.t_steps),
                    vec![c.t_steps]),
                volcanoml::runtime::Input::F32(
                    vec![0.1, 1e-4, 0.0, 0.5], vec![1, 4]),
            ]
        };
        let _ = rt.execute("glm_softmax", &inputs()).unwrap();
        let t = bench("pjrt", 1, 5, || {
            std::hint::black_box(
                rt.execute("glm_softmax", &inputs()).unwrap());
        });
        record(&mut table, &mut timings,
               &format!("PJRT glm_softmax ({} GD steps)", c.t_steps),
               t);
    }

    table.print();

    use volcanoml::util::json::Json;
    let summary = Json::obj(vec![
        ("bench", Json::Str("micro_hotpaths".into())),
        ("results",
         Json::Arr(timings.iter().map(timing_to_json).collect())),
        ("peak_rss_bytes", match peak_rss_bytes() {
            Some(b) => Json::Num(b as f64),
            None => Json::Null,
        }),
    ]);
    save_bench_summary("micro_hotpaths", &summary);
}
