//! Fig 13 (appendix): joint-BO validation error on pc4 as the number
//! of hyper-parameters grows — the scalability failure that motivates
//! decomposition. We grow the joint space (small -> medium -> large)
//! and run AUSK-style plan J vs VolcanoML's plan CA at a fixed budget.

use volcanoml::bench::{bench_scale, render_curves, save_results,
                       try_runtime};
use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::{joint_space, pipeline_for, roster_for,
                             SpaceScale};
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::plan::PlanKind;
use volcanoml::util::json::Json;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let mut p = registry::by_name("pc4").unwrap();
    p.n = p.n.min(scale.n_cap);
    let ds = generate(&p);

    let mut series = vec![
        ("Plan J (auto-sklearn style)".to_string(), Vec::new()),
        ("Plan CA (VolcanoML)".to_string(), Vec::new()),
    ];
    let mut json_rows = Vec::new();
    for space_scale in [SpaceScale::Small, SpaceScale::Medium,
                        SpaceScale::Large] {
        let pipeline = pipeline_for(space_scale, false, false);
        let algos = roster_for(space_scale, ds.task,
                               runtime.is_some());
        let n_hps = joint_space(&pipeline, &algos).len();
        for (si, plan) in [PlanKind::J, PlanKind::CA].iter()
            .enumerate() {
            let cfg = VolcanoConfig {
                plan: *plan,
                scale: space_scale,
                max_evals: scale.evals,
                seed: 42,
                ..Default::default()
            };
            let out = VolcanoML::new(cfg).run(&ds, runtime.as_ref())
                .expect("run");
            let err = 1.0 - out.best_valid_utility;
            series[si].1.push((n_hps as f64, err));
            json_rows.push(Json::obj(vec![
                ("plan", Json::Str(series[si].0.clone())),
                ("n_hyperparameters", Json::Num(n_hps as f64)),
                ("valid_error", Json::Num(err)),
            ]));
        }
        eprintln!("  [{} hyper-parameters] done", n_hps);
    }
    print!("{}", render_curves(
        "Fig 13: validation error vs #hyper-parameters on pc4",
        "#hyper-parameters", &series));
    println!("(paper Fig 13: joint BO degrades as the space grows; \
              decomposition holds up — the motivating observation)");
    save_results("fig13_space_growth", &Json::Arr(json_rows));
}
