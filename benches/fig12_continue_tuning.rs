//! Fig 12: continue tuning vs restarting when new algorithms are
//! added mid-run (pc4 case study): the trend of active algorithms in
//! the conditioning block, plus an elimination on/off ablation.

use volcanoml::bench::{bench_scale, save_results, try_runtime, Table};
use volcanoml::blocks::{Arm, BuildingBlock, ConditioningBlock, Env};
use volcanoml::blocks::Objective;
use volcanoml::coordinator::evaluator::PipelineEvaluator;
use volcanoml::coordinator::{joint_space, pipeline_for, roster_for,
                             SpaceScale};
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::data::Split;
use volcanoml::plan::{EngineKind, PlanBuilder, PlanKind};
use volcanoml::util::json::Json;
use volcanoml::util::rng::Rng;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let mut p = registry::by_name("pc4").unwrap();
    p.n = p.n.min(scale.n_cap);
    let ds = generate(&p);

    let pipeline = pipeline_for(SpaceScale::Large, false, false);
    let algos = roster_for(SpaceScale::Large, ds.task,
                           runtime.is_some());
    let space = joint_space(&pipeline, &algos);
    let names: Vec<String> =
        algos.iter().map(|a| a.name().to_string()).collect();
    let split_at = names.len().saturating_sub(3);
    let (initial, added) = names.split_at(split_at);

    let phase1 = 3;
    let phase2 = 6;
    let mut results = Vec::new();
    let mut table = Table::new(
        "Fig 12: continue tuning vs restarting on pc4",
        &["strategy", "final best (valid)", "evals",
          "arms after add"]);

    for (label, continue_tuning) in [("continue", true),
                                     ("restart", false)] {
        let mut rng = Rng::new(42);
        let split = Split::stratified(&ds, &mut rng);
        let mut ev = PipelineEvaluator::new(
            &ds, split, Metric::BalancedAccuracy, &pipeline, &algos,
            runtime.as_ref(), 42)
            .with_budget(scale.evals * 3, f64::INFINITY);
        let mut builder = PlanBuilder::new(&space, EngineKind::Bo, 42);
        builder.arm_filter = Some(initial.to_vec());
        let mut root = builder.build(PlanKind::CA);
        let mut trend: Vec<(usize, usize)> = Vec::new();

        for _ in 0..phase1 {
            let mut env = Env::new(&mut ev, &mut rng);
            root.do_next(&mut env).unwrap();
            drop(env);
            trend.push((ev.n_evals(), root.active_children()));
        }

        if continue_tuning {
            // extend surviving candidate set (§3.3.6)
            let mut ab = PlanBuilder::new(&space, EngineKind::Bo, 43);
            ab.arm_filter = Some(added.to_vec());
            let new_arms: Vec<Arm> = ab.ca_arms();
            let cond = root.as_any_mut()
                .downcast_mut::<ConditioningBlock>().unwrap();
            cond.add_arms(new_arms);
        } else {
            // restart over the full roster (loses pruning progress)
            let b2 = PlanBuilder::new(&space, EngineKind::Bo, 44);
            root = b2.build(PlanKind::CA);
        }
        let arms_after_add = root.active_children();

        for _ in 0..phase2 {
            if ev.exhausted() {
                break;
            }
            let mut env = Env::new(&mut ev, &mut rng);
            root.do_next(&mut env).unwrap();
            drop(env);
            trend.push((ev.n_evals(), root.active_children()));
        }
        let best = ev.best.as_ref().map(|(_, u)| *u).unwrap_or(0.0);
        println!("\n{label}: active-arm trend (evals, arms): {trend:?}");
        table.row(vec![
            label.to_string(),
            format!("{best:.4}"),
            ev.n_evals().to_string(),
            arms_after_add.to_string(),
        ]);
        results.push(Json::obj(vec![
            ("strategy", Json::Str(label.into())),
            ("best", Json::Num(best)),
            ("trend_evals", Json::arr_f64(&trend.iter()
                .map(|t| t.0 as f64).collect::<Vec<_>>())),
            ("trend_arms", Json::arr_f64(&trend.iter()
                .map(|t| t.1 as f64).collect::<Vec<_>>())),
        ]));
    }
    table.print();
    println!("(paper Fig 12: continue tuning re-converges to 1 arm \
              ~2.5x faster than restarting and ends more accurate — \
              86.44%% vs 84.74%%)");

    // ---- ablation: elimination off ---------------------------------
    let mut rng = Rng::new(45);
    let split = Split::stratified(&ds, &mut rng);
    let mut ev = PipelineEvaluator::new(
        &ds, split, Metric::BalancedAccuracy, &pipeline, &algos,
        runtime.as_ref(), 45)
        .with_budget(scale.evals, f64::INFINITY);
    let builder = PlanBuilder::new(&space, EngineKind::Bo, 45);
    let mut root = builder.build(PlanKind::CA);
    root.as_any_mut().downcast_mut::<ConditioningBlock>()
        .unwrap().eliminate = false;
    while !ev.exhausted() {
        let mut env = Env::new(&mut ev, &mut rng);
        root.do_next(&mut env).unwrap();
    }
    println!("\nablation (elimination off): best valid = {:.4}, arms \
              stay at {}",
             ev.best.map(|(_, u)| u).unwrap_or(0.0),
             root.active_children());
    save_results("fig12_continue_tuning", &Json::Arr(results));
}
