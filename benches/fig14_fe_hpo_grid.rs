//! Fig 14 (appendix): the FE x HPO performance grid on fri_c1 with
//! random forest — the observation motivating alternating
//! optimization: with FE fixed, better HPO configs stay better (and
//! vice versa), i.e. the two subspaces are approximately independent.

use volcanoml::bench::{bench_scale, save_results, try_runtime};
use volcanoml::coordinator::evaluator::PipelineEvaluator;
use volcanoml::coordinator::{joint_space, pipeline_for, roster_for,
                             SpaceScale};
use volcanoml::blocks::Objective;
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::data::Split;
use volcanoml::space::{Config, Value};
use volcanoml::util::json::Json;
use volcanoml::util::rng::Rng;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let grid = std::env::var("GRID").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(8usize);
    let mut p = registry::fri_c1();
    p.n = p.n.min(scale.n_cap);
    let ds = generate(&p);

    let pipeline = pipeline_for(SpaceScale::Large, false, false);
    let algos = roster_for(SpaceScale::Large, ds.task, false);
    let space = joint_space(&pipeline, &algos);
    let fe_space = space.subspace_prefixed("fe:");
    let hp_space = space.subspace_prefixed("alg.random_forest:");
    let mut rng = Rng::new(0);
    let fe_cfgs: Vec<Config> =
        (0..grid).map(|_| fe_space.sample(&mut rng)).collect();
    let hp_cfgs: Vec<Config> =
        (0..grid).map(|_| hp_space.sample(&mut rng)).collect();

    let split = Split::stratified(&ds, &mut rng);
    let mut ev = PipelineEvaluator::new(
        &ds, split, Metric::BalancedAccuracy, &pipeline, &algos,
        runtime.as_ref(), 3);

    let mut matrix = vec![vec![0.0f64; grid]; grid];
    for (i, fe) in fe_cfgs.iter().enumerate() {
        for (j, hp) in hp_cfgs.iter().enumerate() {
            let cfg = Config::new()
                .with("algorithm", Value::C("random_forest".into()))
                .merged(fe)
                .merged(hp);
            matrix[i][j] = ev.evaluate(&cfg, 1.0).unwrap_or(0.0);
        }
        eprintln!("  row {}/{} done", i + 1, grid);
    }

    println!("\n== Fig 14: FE (rows) x HPO (cols) balanced accuracy \
              on fri_c1 / random forest ==");
    for row in &matrix {
        let cells: Vec<String> =
            row.iter().map(|v| format!("{v:.3}")).collect();
        println!("  {}", cells.join(" "));
    }

    // rank-consistency check (Observation 2): Spearman-ish agreement
    // of column orderings across rows and row orderings across cols
    let col_consistency = avg_rank_agreement(&matrix, false);
    let row_consistency = avg_rank_agreement(&matrix, true);
    // sensitivity (Observation 3): variance explained by FE vs HPO
    let fe_var = axis_variance(&matrix, true);
    let hpo_var = axis_variance(&matrix, false);
    println!("\nrank agreement: HPO ordering consistent across FE rows \
              = {col_consistency:.3}; FE ordering across HPO cols = \
              {row_consistency:.3} (1.0 = perfectly independent)");
    println!("sensitivity: FE-axis variance {fe_var:.5} vs HPO-axis \
              variance {hpo_var:.5}");
    println!("(paper Fig 14: orderings are largely consistent — the \
              alternating block's independence assumption — and FE \
              matters more than HPO for RF on fri_c1)");
    save_results("fig14_fe_hpo_grid", &Json::Arr(matrix.iter()
        .map(|r| Json::arr_f64(r)).collect()));
}

/// Mean pairwise-ordering agreement between consecutive rows
/// (transpose for columns).
fn avg_rank_agreement(m: &[Vec<f64>], transpose: bool) -> f64 {
    let n = m.len();
    let get = |i: usize, j: usize| if transpose { m[j][i] } else { m[i][j] };
    let mut agree = 0.0f64;
    let mut total = 0.0f64;
    for r in 0..n - 1 {
        for a in 0..n {
            for b in a + 1..n {
                total += 1.0;
                if (get(r, a) > get(r, b))
                    == (get(r + 1, a) > get(r + 1, b)) {
                    agree += 1.0;
                }
            }
        }
    }
    agree / total.max(1.0)
}

/// Variance of axis means (how much the axis choice moves the score).
fn axis_variance(m: &[Vec<f64>], rows: bool) -> f64 {
    let n = m.len();
    let means: Vec<f64> = (0..n)
        .map(|i| {
            (0..n).map(|j| if rows { m[i][j] } else { m[j][i] })
                .sum::<f64>() / n as f64
        })
        .collect();
    volcanoml::util::stats::variance(&means)
}
