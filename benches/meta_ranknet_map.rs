//! §6.6: RankNet vs a gradient-boosting ranker for conditioning-block
//! arm prediction, measured by mAP@5 under 10-fold cross-validation on
//! the meta-corpus (paper: RankNet 0.87 vs LightGBM 0.62).

use volcanoml::algos::boosting::{Gbm, GbmParams};
use volcanoml::bench::{save_results, Table};
use volcanoml::data::dataset::{Dataset, Task};
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::meta::{meta_features, RankNet};
use volcanoml::meta::ranknet::triples_from_scores;
use volcanoml::util::json::Json;
use volcanoml::util::rng::Rng;
use volcanoml::util::stats::map_at_k;

/// Build a meta-dataset: for each synthetic task, the true arm
/// ranking comes from quick evaluations of default-config arms.
fn build_meta_world(n_tasks: usize, rng: &mut Rng)
    -> (Vec<Vec<f64>>, Vec<Vec<(usize, f64)>>, usize) {
    use volcanoml::algos::{roster, EvalContext};
    use volcanoml::data::Split;
    let mut feats = Vec::new();
    let mut scores = Vec::new();
    let mut n_arms = 0;
    for (i, mut profile) in registry::meta_corpus(n_tasks, 0)
        .into_iter().enumerate() {
        profile.n = profile.n.min(400);
        let ds = generate(&profile);
        let algos = roster(ds.task, false);
        n_arms = algos.len();
        let split = Split::stratified(&ds, rng);
        let y_valid: Vec<f32> =
            split.valid.iter().map(|&i| ds.y[i]).collect();
        let mut arm_scores = Vec::new();
        for (a, algo) in algos.iter().enumerate() {
            let mut ctx = EvalContext::new(None, i as u64);
            let cfg = algo.space().default_config();
            if let Ok(m) = algo.fit(&ds, &split.train, &cfg, &mut ctx) {
                let preds = m.predict(&ds, &split.valid, &mut ctx);
                let u = volcanoml::data::metrics::Metric::
                    BalancedAccuracy.utility(&y_valid, &preds);
                arm_scores.push((a, u));
            }
        }
        feats.push(meta_features(&ds));
        scores.push(arm_scores);
    }
    (feats, scores, n_arms)
}

fn relevant_top(scores: &[(usize, f64)], k: usize) -> Vec<usize> {
    let mut s = scores.to_vec();
    s.sort_by(|a, b| b.1.partial_cmp(&a.1)
        .unwrap_or(std::cmp::Ordering::Equal));
    s.into_iter().take(k).map(|(a, _)| a).collect()
}

fn main() {
    let mut rng = Rng::new(0);
    let n_tasks = std::env::var("META_TASKS").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(40);
    eprintln!("building meta-world over {n_tasks} tasks...");
    let (feats, scores, n_arms) = build_meta_world(n_tasks, &mut rng);
    let meta_dim = feats[0].len();
    let folds = 10.min(n_tasks);

    let mut ranknet_preds: Vec<Vec<usize>> = Vec::new();
    let mut gbm_preds: Vec<Vec<usize>> = Vec::new();
    let mut relevant: Vec<Vec<usize>> = Vec::new();

    for fold in 0..folds {
        let test_idx: Vec<usize> = (0..n_tasks)
            .filter(|i| i % folds == fold).collect();
        let train_idx: Vec<usize> = (0..n_tasks)
            .filter(|i| i % folds != fold).collect();

        // RankNet on pairwise triples
        let mut triples = Vec::new();
        for &i in &train_idx {
            triples.extend(triples_from_scores(&feats[i], &scores[i],
                                               1e-4));
        }
        let mut net = RankNet::new(meta_dim, n_arms, 24, &mut rng);
        net.train(&triples, 30, &mut rng);

        // GBM ranker: regression on (meta-features ++ arm one-hot)
        // -> utility (the LightGBM-as-binary-classifier stand-in)
        let d_in = meta_dim + n_arms;
        let mut gds = Dataset::new("meta", Task::Regression, d_in);
        for &i in &train_idx {
            for &(a, u) in &scores[i] {
                let mut row: Vec<f32> =
                    feats[i].iter().map(|&v| v as f32).collect();
                let mut onehot = vec![0.0f32; n_arms];
                onehot[a] = 1.0;
                row.extend(onehot);
                gds.push_row(&row, u as f32);
            }
        }
        let rows: Vec<usize> = (0..gds.n).collect();
        let gbm = Gbm::fit(&gds, &rows, &GbmParams {
            n_estimators: 40, ..Default::default()
        }, &mut rng);

        for &i in &test_idx {
            relevant.push(relevant_top(&scores[i], 5));
            ranknet_preds.push(net.rank_arms(&feats[i]));
            // gbm ranking: score each arm
            let mut qds = Dataset::new("q", Task::Regression, d_in);
            for a in 0..n_arms {
                let mut row: Vec<f32> =
                    feats[i].iter().map(|&v| v as f32).collect();
                let mut onehot = vec![0.0f32; n_arms];
                onehot[a] = 1.0;
                row.extend(onehot);
                qds.push_row(&row, 0.0);
            }
            let qrows: Vec<usize> = (0..n_arms).collect();
            let preds = gbm.predict(&qds, &qrows);
            let vals = preds.values();
            let mut order: Vec<usize> = (0..n_arms).collect();
            order.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a])
                .unwrap_or(std::cmp::Ordering::Equal));
            gbm_preds.push(order);
        }
    }

    let map_rank = map_at_k(&ranknet_preds, &relevant, 5);
    let map_gbm = map_at_k(&gbm_preds, &relevant, 5);
    let mut table = Table::new("§6.6: arm-ranking quality (mAP@5)",
                               &["ranker", "mAP@5"]);
    table.row_f("RankNet", &[map_rank], 3);
    table.row_f("GBM (LightGBM stand-in)", &[map_gbm], 3);
    table.print();
    println!("(paper: RankNet 0.87 vs LightGBM 0.62)");
    save_results("meta_ranknet_map", &Json::obj(vec![
        ("ranknet", Json::Num(map_rank)),
        ("gbm", Json::Num(map_gbm)),
    ]));
}
