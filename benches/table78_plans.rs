//! Tables 7-8: the five execution plans (J/C/A/AC/CA) plus the
//! nested CC variant, TPOT and AUSK on classification and regression
//! tasks — the paper's central decomposition ablation. The plan runs
//! honour the `--super-batch` / `--pipeline-depth` / `--workers`
//! knobs (and their `VOLCANO_*` env equivalents), so the nested
//! plans' cross-level batching win shows up in the wall-clock
//! trajectory. Also includes the §3.3.3 design-choice ablation: CA
//! with round-robin alternation instead of EUI routing.

use volcanoml::baselines::{run_system, BaseSpec, SystemKind};
use volcanoml::bench::{bench_scale, bench_workers, save_results,
                       shrink_profile, try_runtime, Table};
use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::plan::PlanKind;
use volcanoml::util::json::Json;
use volcanoml::util::stats::average_ranks;

fn main() {
    let scale = bench_scale();
    let workers = bench_workers();
    let runtime = try_runtime();
    if workers > 1 {
        println!("[batched evaluation on {workers} workers]");
    }
    for (t_label, profiles, header_metric) in [
        ("Table 7 (CLS, test accuracy)",
         registry::medium_classification(), Metric::Accuracy),
        ("Table 8 (REG, test MSE)", registry::regression(),
         Metric::Mse),
    ] {
        let profiles: Vec<_> = profiles
            .into_iter()
            .take(scale.datasets_cap)
            .map(|p| shrink_profile(p, &scale))
            .collect();
        let mut table = Table::new(
            t_label,
            &["dataset", "Plan1 J", "Plan2 C", "Plan3 A", "Plan4 AC",
              "Plan5 CA", "CC (nested)", "TPOT", "AUSK"]);
        let mut utilities: Vec<Vec<f64>> = Vec::new();
        for p in &profiles {
            let ds = generate(p);
            let mut row_vals = Vec::new();
            let mut row_utils = Vec::new();
            for kind in PlanKind::with_nested() {
                let cfg = VolcanoConfig {
                    plan: kind,
                    scale: SpaceScale::Large,
                    metric: header_metric,
                    max_evals: scale.evals,
                    workers,
                    super_batch: volcanoml::bench::bench_super_batch(),
                    pipeline_depth:
                        volcanoml::bench::bench_pipeline_depth(),
                    fe_cache_mb:
                        volcanoml::bench::bench_fe_cache_mb(),
                    seed: 42,
                    ..Default::default()
                };
                match VolcanoML::new(cfg).run(&ds, runtime.as_ref()) {
                    Ok(o) => {
                        row_vals.push(o.test_metric_value);
                        row_utils.push(o.ensemble_test_utility
                            .max(o.test_utility));
                    }
                    Err(_) => {
                        row_vals.push(f64::NAN);
                        row_utils.push(f64::NEG_INFINITY);
                    }
                }
            }
            let spec = BaseSpec {
                scale: SpaceScale::Large,
                metric: header_metric,
                max_evals: scale.evals,
                budget_secs: f64::INFINITY,
                workers,
                super_batch: volcanoml::bench::bench_super_batch(),
                pipeline_depth:
                    volcanoml::bench::bench_pipeline_depth(),
                fe_cache_mb:
                    volcanoml::bench::bench_fe_cache_mb(),
                seed: 42,
            };
            for sys in [SystemKind::Tpot, SystemKind::AuskMinus] {
                match run_system(sys, &ds, &spec, None,
                                 runtime.as_ref()) {
                    Ok(o) => {
                        row_vals.push(o.test_metric_value);
                        row_utils.push(o.ensemble_test_utility
                            .max(o.test_utility));
                    }
                    Err(_) => {
                        row_vals.push(f64::NAN);
                        row_utils.push(f64::NEG_INFINITY);
                    }
                }
            }
            table.row_f(&ds.name, &row_vals, 4);
            utilities.push(row_utils);
            eprintln!("  [{}] done", ds.name);
        }
        let ranks = average_ranks(&utilities, true, 1e-4);
        table.row_f("Average Rank", &ranks, 2);
        table.print();
        save_results(&t_label.split(' ').next().unwrap().to_lowercase(),
                     &Json::Arr(utilities.iter()
                         .map(|r| Json::arr_f64(r)).collect()));
    }
    println!("(paper: Plan 5 / CA achieves the best average rank — \
              2.58 CLS, 2.20 REG — ahead of J-based TPOT and AUSK)");

    // ---- ablation: EUI-driven vs round-robin alternation -----------
    println!("\n-- ablation: CA alternation policy (EUI vs \
              round-robin) on 3 datasets --");
    ablation_eui(&scale, runtime.as_ref());
}

fn ablation_eui(scale: &volcanoml::bench::BenchScale,
                runtime: Option<&volcanoml::runtime::Runtime>) {
    use volcanoml::blocks::{BuildingBlock, ConditioningBlock, Env,
                            Objective};
    use volcanoml::coordinator::evaluator::PipelineEvaluator;
    use volcanoml::coordinator::{joint_space, pipeline_for, roster_for};
    use volcanoml::data::Split;
    use volcanoml::plan::{EngineKind, PlanBuilder};
    use volcanoml::util::rng::Rng;

    let mut table = Table::new(
        "CA alternation ablation (valid utility)",
        &["dataset", "EUI-driven", "round-robin"]);
    for name in ["quake", "segment", "phoneme"] {
        let mut p = registry::by_name(name).unwrap();
        p.n = p.n.min(scale.n_cap);
        let ds = generate(&p);
        let mut vals = Vec::new();
        for eui in [true, false] {
            let pipeline = pipeline_for(SpaceScale::Large, false,
                                        false);
            let algos = roster_for(SpaceScale::Large, ds.task,
                                   runtime.is_some());
            let space = joint_space(&pipeline, &algos);
            let builder = PlanBuilder::new(&space, EngineKind::Bo, 42);
            let mut root = builder.build(PlanKind::CA);
            // flip every alternating child to round-robin
            if !eui {
                if let Some(cond) = root.as_any_mut()
                    .downcast_mut::<ConditioningBlock>() {
                    for arm in &mut cond.arms {
                        if let Some(alt) = arm.block.as_any_mut()
                            .downcast_mut::<volcanoml::blocks::AlternatingBlock>() {
                            alt.eui_driven = false;
                        }
                    }
                }
            }
            let split = Split::stratified(&ds, &mut Rng::new(1));
            let mut ev = PipelineEvaluator::new(
                &ds, split, Metric::BalancedAccuracy, &pipeline,
                &algos, runtime, 42)
                .with_budget(scale.evals, f64::INFINITY);
            let mut rng = Rng::new(2);
            while !ev.exhausted() {
                let mut env = Env::new(&mut ev, &mut rng);
                root.do_next(&mut env).unwrap();
            }
            vals.push(ev.best.map(|(_, u)| u).unwrap_or(f64::NAN));
        }
        table.row_f(name, &vals, 4);
    }
    table.print();
}
