//! Fig 7: end-to-end comparison of VolcanoML⁻ vs AUSK⁻ vs TPOT on the
//! 30 OpenML-like classification datasets and 20 regression datasets.
//! Prints per-dataset improvement (accuracy delta for CLS, the paper's
//! relative-MSE Δ for REG) and the win counts the paper headlines.
//!
//! Scale via VOLCANO_BENCH=quick|std|full (see bench::bench_scale).
//! Ablation: VOLCANO_NO_ENSEMBLE=1 disables ensembling for VolcanoML.

use volcanoml::baselines::SystemKind;
use volcanoml::bench::{bench_scale, bench_workers, run_matrix,
                       save_results, shrink_profile, try_runtime,
                       Table};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::metrics::relative_mse_improvement;
use volcanoml::data::registry;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let systems = [SystemKind::VolcanoMLMinus, SystemKind::AuskMinus,
                   SystemKind::Tpot];

    for (label, profiles, is_cls) in [
        ("CLS", registry::medium_classification(), true),
        ("REG", registry::regression(), false),
    ] {
        let profiles: Vec<_> = profiles
            .into_iter()
            .take(scale.datasets_cap)
            .map(|p| shrink_profile(p, &scale))
            .collect();
        println!("\n=== Fig 7 ({label}): {} datasets, {} evals each, \
                  {} worker(s) ===",
                 profiles.len(), scale.evals, bench_workers());
        let m = run_matrix(&profiles, &systems, SpaceScale::Large,
                           scale.evals, 42, None, runtime.as_ref());

        let mut table = Table::new(
            &format!("Fig 7 {label}: improvement of VolcanoML- over \
                      baselines"),
            &["dataset", "V- vs AUSK-", "V- vs TPOT"]);
        let (mut wins_ausk, mut wins_tpot) = (0, 0);
        for (d, row) in m.metric_value.iter().enumerate() {
            let (v, a, t) = (row[0], row[1], row[2]);
            let (d_a, d_t) = if is_cls {
                ((v - a) * 100.0, (v - t) * 100.0) // accuracy points
            } else {
                (relative_mse_improvement(v, a) * 100.0,
                 relative_mse_improvement(v, t) * 100.0)
            };
            if d_a > 0.0 {
                wins_ausk += 1;
            }
            if d_t > 0.0 {
                wins_tpot += 1;
            }
            table.row(vec![
                m.datasets[d].clone(),
                format!("{d_a:+.2}%"),
                format!("{d_t:+.2}%"),
            ]);
        }
        table.print();
        println!("VolcanoML- beats AUSK- on {wins_ausk}/{} and TPOT on \
                  {wins_tpot}/{} {label} datasets",
                 m.datasets.len(), m.datasets.len());
        println!("(paper: 25/30 and 23/30 CLS; 17/20 and 15/20 REG)");
        save_results(&format!("fig7_{label}"), &m.to_json());
    }
}
