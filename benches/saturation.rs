//! Saturation bench: one shared [`SearchService`] serving 1, 8 and
//! 64 concurrent searches on a fixed worker-pool size. Reports, per
//! concurrency level, the total wall-clock, aggregate evaluation
//! throughput, and the max/min per-tenant throughput ratio (1.0 =
//! perfectly fair; the fair-share scheduler should keep equal-weight
//! tenants close). Saves `BENCH_saturation.json`.
//!
//! Knobs: `--workers N` / VOLCANO_WORKERS (pool threads; default 4
//! here — a saturation bench on a serial pool measures nothing),
//! `--fe-cache-mb N` / VOLCANO_FE_CACHE_MB (shared store; default
//! 256), `--evals N` (per search; default 10).

use std::time::Instant;

use volcanoml::bench::{bench_fe_cache_mb, bench_workers,
                       save_results};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::synthetic::{generate, GenKind, Profile};
use volcanoml::data::{Dataset, Task};
use volcanoml::plan::PlanKind;
use volcanoml::service::{JobSpec, SearchService, ServiceConfig};
use volcanoml::util::json::Json;

fn job_ds(seed: u64) -> Dataset {
    generate(&Profile {
        name: format!("sat-{seed}"),
        task: Task::Classification { n_classes: 2 },
        gen: GenKind::Blobs { sep: 1.7 },
        n: 160,
        d: 5,
        noise: 0.05,
        imbalance: 1.0,
        redundant: 0,
        wild_scales: false,
        seed,
    })
}

fn main() {
    let workers = {
        let w = bench_workers();
        if w > 1 { w } else { 4 }
    };
    let fe_mb = {
        let mb = bench_fe_cache_mb();
        if mb > 0 { mb } else { 256 }
    };
    let evals = volcanoml::cli::Args::from_env()
        .ok()
        .and_then(|a| a.usize_or("evals", 10).ok())
        .unwrap_or(10);

    println!("=== Saturation: shared pool of {workers} worker(s), \
              {fe_mb} MB FE store, {evals} evals/search ===");
    let mut levels = Vec::new();
    for concurrent in [1usize, 8, 64] {
        let svc = SearchService::new(ServiceConfig {
            workers,
            fe_cache_mb: fe_mb,
            max_active: concurrent,
            pending_cap: concurrent,
        });
        let t0 = Instant::now();
        let handles: Vec<_> = (0..concurrent)
            .map(|i| {
                let spec = JobSpec {
                    name: format!("sat{i}"),
                    dataset: "synthetic".to_string(),
                    plan: PlanKind::CA,
                    scale: SpaceScale::Small,
                    max_evals: evals,
                    eval_batch: 2,
                    seed: 1000 + i as u64,
                    ..JobSpec::default()
                };
                svc.submit_on(spec, job_ds(i as u64))
                    .expect("admission refused below max_active")
            })
            .collect();
        // per-tenant throughput over each search's own wall time
        let mut thr: Vec<f64> = Vec::with_capacity(concurrent);
        let mut total_evals = 0usize;
        for h in handles {
            let out = h.wait().expect("search failed");
            total_evals += out.n_evals;
            thr.push(out.n_evals as f64
                     / out.elapsed_secs.max(1e-9));
        }
        svc.wait_idle();
        let wall = t0.elapsed().as_secs_f64();
        let (min, max) = thr.iter().fold(
            (f64::INFINITY, 0.0f64),
            |(lo, hi), &t| (lo.min(t), hi.max(t)));
        let fairness = max / min.max(1e-9);
        println!("  {concurrent:>2} concurrent: {wall:>7.2}s wall, \
                  {:>7.1} evals/s aggregate, max/min per-tenant \
                  throughput {fairness:.2}x",
                 total_evals as f64 / wall.max(1e-9));
        levels.push(Json::obj(vec![
            ("concurrent", Json::Num(concurrent as f64)),
            ("wall_secs", Json::Num(wall)),
            ("total_evals", Json::Num(total_evals as f64)),
            ("aggregate_evals_per_sec",
             Json::Num(total_evals as f64 / wall.max(1e-9))),
            ("tenant_throughput_max", Json::Num(max)),
            ("tenant_throughput_min", Json::Num(min)),
            ("tenant_throughput_ratio", Json::Num(fairness)),
        ]));
    }

    save_results("BENCH_saturation", &Json::obj(vec![
        ("workers", Json::Num(workers as f64)),
        ("fe_cache_mb", Json::Num(fe_mb as f64)),
        ("evals_per_search", Json::Num(evals as f64)),
        ("levels", Json::Arr(levels)),
    ]));
}
