//! Table 1: average ranks over three search-space scales for
//! {TPOT, AUSK-, AUSK, VolcanoML-, VolcanoML} (meta-learning variants
//! use the collected corpus; without a corpus they degrade to their
//! minus variants, which the output flags).
//!
//! Scale via VOLCANO_BENCH; corpus path via VOLCANO_CORPUS.

use volcanoml::baselines::SystemKind;
use volcanoml::bench::{bench_scale, run_matrix, save_results,
                       shrink_profile, try_runtime, Table};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::registry;
use volcanoml::meta::MetaCorpus;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let corpus = std::env::var("VOLCANO_CORPUS")
        .ok()
        .and_then(|p| MetaCorpus::load(std::path::Path::new(&p)).ok());
    if corpus.is_none() {
        eprintln!("note: no VOLCANO_CORPUS — AUSK/VolcanoML run \
                   without meta-learning (== their minus variants)");
    }
    let systems = [SystemKind::Tpot, SystemKind::AuskMinus,
                   SystemKind::Ausk, SystemKind::VolcanoMLMinus,
                   SystemKind::VolcanoML];

    let mut table = Table::new(
        "Table 1: average ranks (lower is better)",
        &["space-task", "TPOT", "AUSK-", "AUSK", "VolcanoML-",
          "VolcanoML"]);
    for (task_label, profiles) in [
        ("CLS", registry::medium_classification()),
        ("REG", registry::regression()),
    ] {
        let profiles: Vec<_> = profiles
            .into_iter()
            .take(scale.datasets_cap)
            .map(|p| shrink_profile(p, &scale))
            .collect();
        let full = std::env::var("VOLCANO_BENCH").as_deref()
            == Ok("full");
        let spaces: &[SpaceScale] = if full {
            &[SpaceScale::Small, SpaceScale::Medium, SpaceScale::Large]
        } else {
            &[SpaceScale::Medium, SpaceScale::Large]
        };
        for &space in spaces {
            eprintln!("== {} - {} ==", space.name(), task_label);
            let m = run_matrix(&profiles, &systems, space, scale.evals,
                               42, corpus.as_ref(), runtime.as_ref());
            let ranks = m.average_ranks();
            table.row_f(&format!("{} - {}", space.name(), task_label),
                        &ranks, 2);
            save_results(&format!("table1_{}_{}", space.name(),
                                  task_label), &m.to_json());
        }
    }
    table.print();
    println!("(paper Table 1: VolcanoML best everywhere; gap widens \
              with space size — e.g. Large-CLS 1.65 vs AUSK 3.57)");
}
