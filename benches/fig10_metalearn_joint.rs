//! Fig 10: meta-learning (RGPE) warm-start in a joint block — the
//! first 50 evaluations of BO on quake / space_ga-like tasks with the
//! LibSVM-analogue arm (linear_svc / linear_svr), with and without the
//! RGPE surrogate built from prior-task histories.

use volcanoml::bench::{render_curves, save_results, try_runtime};
use volcanoml::blocks::{BuildingBlock, Env, JointBlock, JointEngine,
                        Objective};
use volcanoml::coordinator::evaluator::PipelineEvaluator;
use volcanoml::coordinator::{joint_space, pipeline_for, roster_for,
                             SpaceScale};
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::data::Split;
use volcanoml::meta::Rgpe;
use volcanoml::opt::SmacBo;
use volcanoml::space::{Config, Value};
use volcanoml::util::json::Json;
use volcanoml::util::rng::Rng;

const N_EVALS: usize = 50;
const N_PRIORS: usize = 6;

fn main() {
    let runtime = try_runtime();
    let mut all_series = Vec::new();
    for target_name in ["quake", "space_ga"] {
        let profile = registry::by_name(target_name).unwrap();
        let task_is_cls = profile.task.is_classification();
        let algo = if task_is_cls { "linear_svc" } else { "linear_svr" };
        let metric = if task_is_cls { Metric::BalancedAccuracy }
                     else { Metric::Mse };
        // need the PJRT arm; fall back to a native arm without it
        let algo = if runtime.is_some() { algo }
                   else if task_is_cls { "lda" } else { "ridge" };

        // ---- collect prior histories on sibling synthetic tasks ----
        let mut priors: Vec<(Vec<Vec<f64>>, Vec<f64>)> = Vec::new();
        let scale = SpaceScale::Large;
        let pipeline = pipeline_for(scale, false, false);
        for i in 0..N_PRIORS {
            let mut p = profile.clone();
            p.name = format!("{target_name}-prior{i}");
            p.seed ^= 0x1000 + i as u64;
            p.n = p.n.min(600);
            let ds = generate(&p);
            let algos = roster_for(scale, ds.task, runtime.is_some());
            let space = joint_space(&pipeline, &algos);
            let hp = space.subspace_prefixed(&format!("alg.{algo}:"));
            let split = Split::stratified(&ds, &mut Rng::new(i as u64));
            let mut ev = PipelineEvaluator::new(
                &ds, split, metric, &pipeline, &algos,
                runtime.as_ref(), i as u64)
                .with_budget(30, f64::INFINITY);
            let fixed = Config::new()
                .with("algorithm", Value::C(algo.into()))
                .merged(&space.subspace_prefixed("fe:")
                    .default_config());
            let mut block = JointBlock::bo("prior", hp.clone(),
                                           fixed, i as u64);
            let mut rng = Rng::new(100 + i as u64);
            while !ev.exhausted() {
                let mut env = Env::new(&mut ev, &mut rng);
                block.do_next(&mut env).unwrap();
            }
            let hist: (Vec<Vec<f64>>, Vec<f64>) = block
                .observations()
                .iter()
                .map(|(c, y)| (hp.to_features(c), *y))
                .unzip();
            priors.push(hist);
        }

        // ---- target task: vanilla vs RGPE ---------------------------
        let mut target = profile.clone();
        target.n = target.n.min(800);
        let ds = generate(&target);
        let algos = roster_for(scale, ds.task, runtime.is_some());
        let space = joint_space(&pipeline, &algos);
        let hp = space.subspace_prefixed(&format!("alg.{algo}:"));
        let fixed = Config::new()
            .with("algorithm", Value::C(algo.into()))
            .merged(&space.subspace_prefixed("fe:").default_config());

        let mut series = Vec::new();
        for (label, use_rgpe) in [("VolcanoML- (vanilla BO)", false),
                                  ("VolcanoML (RGPE)", true)] {
            let split = Split::stratified(&ds, &mut Rng::new(7));
            let mut ev = PipelineEvaluator::new(
                &ds, split, metric, &pipeline, &algos,
                runtime.as_ref(), 7)
                .with_budget(N_EVALS, f64::INFINITY);
            let engine = if use_rgpe {
                JointEngine::Bo(SmacBo::with_surrogate(
                    hp.clone(), Box::new(Rgpe::new(&priors, 9))))
            } else {
                JointEngine::Bo(SmacBo::new(hp.clone(), 9))
            };
            let mut block = JointBlock::with_engine(
                "target", hp.clone(), fixed.clone(), engine);
            let mut rng = Rng::new(11);
            let mut curve = Vec::new();
            let mut best = f64::NEG_INFINITY;
            for i in 0..N_EVALS {
                if ev.exhausted() {
                    break;
                }
                {
                    let mut env = Env::new(&mut ev, &mut rng);
                    block.do_next(&mut env).unwrap();
                }
                best = block.current_best().map(|(_, y)| y)
                    .unwrap_or(best);
                // validation error = 1 - utility (cls) or -utility
                let err = if task_is_cls { 1.0 - best } else { -best };
                curve.push(((i + 1) as f64, err));
            }
            series.push((format!("{target_name}: {label}"), curve));
        }
        print!("{}", render_curves(
            &format!("Fig 10: first {N_EVALS} evaluations on \
                      {target_name} ({algo})"),
            "evaluations", &series));
        all_series.push(Json::obj(vec![
            ("dataset", Json::Str(target_name.into())),
            ("curves", Json::Arr(series.iter().map(|(n, pts)| {
                Json::obj(vec![
                    ("name", Json::Str(n.clone())),
                    ("y", Json::arr_f64(&pts.iter().map(|p| p.1)
                        .collect::<Vec<_>>())),
                ])
            }).collect())),
        ]));
    }
    println!("\n(paper Fig 10: RGPE drops validation error sharply in \
              the first ~10 evals; ~8x fewer evals to match vanilla \
              on quake, ~2x on space_ga)");
    save_results("fig10_metalearn", &Json::Arr(all_series));
}
