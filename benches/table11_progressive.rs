//! Table 11: progressive (top-down) optimization vs the original
//! bandit-based strategy on five CLS + five REG tasks (§4.3).

use volcanoml::bench::{bench_scale, save_results, shrink_profile,
                       try_runtime, Table};
use volcanoml::coordinator::automl::{VolcanoConfig, VolcanoML};
use volcanoml::coordinator::SpaceScale;
use volcanoml::data::metrics::Metric;
use volcanoml::data::registry;
use volcanoml::data::synthetic::generate;
use volcanoml::util::json::Json;

fn main() {
    let scale = bench_scale();
    let runtime = try_runtime();
    let cls_names = ["puma8NH", "kin8nm", "cpu_act", "puma32H",
                     "phoneme"];
    let reg_names = ["puma8NH", "kin8nm", "cpu_small", "puma32H",
                     "cpu_act"];
    let mut results = Vec::new();

    for (label, corpus, names, metric) in [
        ("CLS (test accuracy %)", registry::medium_classification(),
         &cls_names, Metric::Accuracy),
        ("REG (test MSE)", registry::regression(), &reg_names,
         Metric::Mse),
    ] {
        let mut table = Table::new(
            &format!("Table 11 {label}"),
            &["dataset", "Original (CA)", "Progressive"]);
        let mut orig_wins = 0;
        let mut n = 0;
        for p in corpus.into_iter()
            .filter(|p| names.contains(&p.name.as_str())) {
            let p = shrink_profile(p, &scale);
            let ds = generate(&p);
            let mut vals = Vec::new();
            for progressive in [false, true] {
                let cfg = VolcanoConfig {
                    scale: SpaceScale::Large,
                    metric,
                    max_evals: scale.evals,
                    progressive,
                    seed: 42,
                    ..Default::default()
                };
                let v = VolcanoML::new(cfg).run(&ds, runtime.as_ref())
                    .map(|o| o.test_metric_value).unwrap_or(f64::NAN);
                vals.push(if metric == Metric::Accuracy { v * 100.0 }
                          else { v });
            }
            let orig_better = if metric == Metric::Mse {
                vals[0] <= vals[1]
            } else {
                vals[0] >= vals[1]
            };
            if orig_better {
                orig_wins += 1;
            }
            n += 1;
            table.row_f(&ds.name, &vals, 4);
            results.push(Json::obj(vec![
                ("dataset", Json::Str(ds.name.clone())),
                ("original", Json::Num(vals[0])),
                ("progressive", Json::Num(vals[1])),
            ]));
            eprintln!("  [{}] done", ds.name);
        }
        table.print();
        println!("original strategy wins {orig_wins}/{n} \
                  (paper: 8/10 overall)");
    }
    save_results("table11_progressive", &Json::Arr(results));
}
