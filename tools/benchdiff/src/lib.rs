//! Perf-trajectory gate: diff the current `BENCH_*.json` summaries
//! against the committed `BENCH_baseline.json`.
//!
//! The comparison is *machine-normalized*: per operation we take the
//! ratio `current_median / baseline_median`, then divide every ratio
//! by the median of all ratios. A uniformly slower (or faster) CI
//! machine moves every ratio by the same factor and washes out of the
//! normalized value; only operations that regressed *relative to the
//! rest of the suite* stand out. Thresholds:
//!
//! * normalized ratio > [`WARN_REL`]  -> warning (non-blocking)
//! * normalized ratio > [`FAIL_REL`]  -> failure (CI-blocking)
//!
//! `BENCH_table10.json` contributes one absolute gate: peak RSS of the
//! large-data run against the baseline value ([`RSS_WARN`] /
//! [`RSS_FAIL`]), since memory high-water marks do not scale with CPU
//! speed.
//!
//! ## Baseline lifecycle
//!
//! The committed `BENCH_baseline.json` starts life as a *seeded
//! estimate* (`seeded_estimate: true`): numbers from a cost model,
//! not a machine. While seeded, failures downgrade to warnings —
//! failing hard against estimates would be noise. Every CI bench run
//! emits a measured `BENCH_baseline.next.json` (`--emit-baseline`)
//! built from the fresh summaries; promoting it over the seed arms
//! the blocking gate with measured numbers:
//!
//! ```text
//! cargo run -p benchdiff -- --promote BENCH_baseline.next.json
//! ```
//!
//! `--promote` refuses a still-seeded or empty source
//! ([`validate_measured_baseline`]) so an estimate can never be
//! promoted by accident, and the seed is never edited by hand —
//! measured numbers only enter the committed baseline through this
//! path. Until a maintainer commits the promoted file, CI self-arms
//! within a run: it re-measures `micro_hotpaths` and runs a blocking
//! diff against the same run's `BENCH_baseline.next.json`, so a
//! regression introduced *by the current change* still blocks even
//! while the committed baseline is an estimate.

use volcanoml::util::json::Json;

/// Non-blocking threshold on the machine-normalized median ratio.
pub const WARN_REL: f64 = 1.10;
/// Blocking threshold on the machine-normalized median ratio.
pub const FAIL_REL: f64 = 2.0;
/// Non-blocking threshold on the peak-RSS ratio (table10).
pub const RSS_WARN: f64 = 1.5;
/// Blocking threshold on the peak-RSS ratio (table10).
pub const RSS_FAIL: f64 = 3.0;
/// Fewer common operations than this and the ratio gate is skipped
/// (the normalization median would be meaningless).
pub const MIN_COMMON_OPS: usize = 3;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Fail,
}

impl Severity {
    fn tag(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "WARN",
            Severity::Fail => "FAIL",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Line {
    pub severity: Severity,
    pub text: String,
}

#[derive(Debug, Default)]
pub struct DiffReport {
    pub lines: Vec<Line>,
}

impl DiffReport {
    fn push(&mut self, severity: Severity, text: String) {
        self.lines.push(Line { severity, text });
    }

    pub fn failed(&self) -> bool {
        self.lines.iter().any(|l| l.severity == Severity::Fail)
    }

    pub fn warned(&self) -> bool {
        self.lines.iter().any(|l| l.severity == Severity::Warn)
    }

    pub fn render(&self) -> String {
        let mut out = String::from("== benchdiff: perf trajectory vs \
                                    BENCH_baseline.json ==\n");
        for l in &self.lines {
            out.push_str(&format!("[{}] {}\n", l.severity.tag(),
                                  l.text));
        }
        out.push_str(&format!(
            "verdict: {}\n",
            if self.failed() {
                "FAIL (blocking regression > 2.0x normalized)"
            } else if self.warned() {
                "WARN (non-blocking drift > 1.10x normalized)"
            } else {
                "clean"
            }
        ));
        out
    }
}

/// Median of a sample set; 0.0 on empty (callers guard).
pub fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    match s.len() {
        0 => 0.0,
        n if n % 2 == 1 => s[n / 2],
        n => 0.5 * (s[n / 2 - 1] + s[n / 2]),
    }
}

/// Extract `(operation, median_s)` rows from a bench summary's
/// `results` array. Falls back to `mean_s` for summaries written
/// before the median field existed. Non-positive timings are dropped
/// (a zero would poison the ratio).
pub fn op_medians(summary: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let Some(results) = summary.get("results").and_then(Json::as_arr)
    else {
        return out;
    };
    for row in results {
        let Some(op) = row.get("operation").and_then(Json::as_str)
        else {
            continue;
        };
        let t = row
            .get("median_s")
            .and_then(Json::as_f64)
            .or_else(|| row.get("mean_s").and_then(Json::as_f64));
        if let Some(t) = t {
            if t > 0.0 && t.is_finite() {
                out.push((op.to_string(), t));
            }
        }
    }
    out
}

fn lookup<'a>(rows: &'a [(String, f64)], op: &str) -> Option<f64> {
    rows.iter().find(|(o, _)| o == op).map(|&(_, t)| t)
}

/// Diff the current summaries against the baseline. `baseline` holds a
/// `micro_hotpaths` object (same shape as the live summary) and an
/// optional `table10` object with `peak_rss_bytes`.
pub fn diff(baseline: &Json, micro: Option<&Json>,
            table10: Option<&Json>) -> DiffReport {
    let mut rep = DiffReport::default();

    // A baseline stamped `seeded_estimate` was committed before any
    // CI machine measured it (the bootstrap state): it can flag
    // drift, but failing hard against estimated numbers would be
    // noise. CI uploads a measured `--emit-baseline` artifact each
    // run; committing that in place of the seed arms the blocking
    // gate.
    let seeded = baseline
        .get("seeded_estimate")
        .and_then(Json::as_bool)
        .unwrap_or(false);

    if let Some(micro) = micro {
        let base = baseline
            .get("micro_hotpaths")
            .map(op_medians)
            .unwrap_or_default();
        let cur = op_medians(micro);
        if base.is_empty() {
            rep.push(Severity::Warn,
                     "baseline has no micro_hotpaths results; \
                      ratio gate skipped".into());
        } else {
            diff_micro(&mut rep, &base, &cur);
        }
    } else {
        rep.push(Severity::Warn,
                 "BENCH_micro_hotpaths.json not found; \
                  ratio gate skipped".into());
    }

    diff_rss(&mut rep, baseline, table10);

    if seeded {
        for l in &mut rep.lines {
            if l.severity == Severity::Fail {
                l.severity = Severity::Warn;
            }
        }
        rep.push(Severity::Info,
                 "baseline is a seeded estimate \
                  (seeded_estimate=true): failures downgraded to \
                  warnings until a measured baseline is committed"
                     .into());
    }
    rep
}

/// Build a measured baseline from the current summaries (the
/// `--emit-baseline` output CI uploads so a maintainer can replace
/// the seeded estimate with real numbers).
pub fn make_baseline(micro: Option<&Json>, table10: Option<&Json>)
    -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("bench", Json::Str("baseline".into())),
        ("seeded_estimate", Json::Bool(false)),
    ];
    if let Some(m) = micro {
        let rows = op_medians(m)
            .into_iter()
            .map(|(op, t)| Json::obj(vec![
                ("operation", Json::Str(op)),
                ("median_s", Json::Num(t)),
            ]))
            .collect();
        pairs.push(("micro_hotpaths", Json::obj(vec![
            ("results", Json::Arr(rows)),
        ])));
    }
    if let Some(rss) = table10
        .and_then(|t| t.get("peak_rss_bytes"))
        .and_then(Json::as_f64)
    {
        pairs.push(("table10", Json::obj(vec![
            ("peak_rss_bytes", Json::Num(rss)),
        ])));
    }
    Json::obj(pairs)
}

/// Gate on `--promote`: the source must be a *measured* baseline —
/// explicitly stamped `seeded_estimate: false` and carrying at least
/// [`MIN_COMMON_OPS`] micro-hotpath rows — so a seeded estimate or a
/// truncated artifact can never overwrite the committed baseline.
pub fn validate_measured_baseline(b: &Json) -> Result<(), String> {
    match b.get("seeded_estimate").and_then(Json::as_bool) {
        Some(false) => {}
        Some(true) => return Err(
            "source is a seeded estimate (seeded_estimate=true); \
             only measured baselines may be promoted".into()),
        None => return Err(
            "source lacks the seeded_estimate stamp; expected a \
             baseline emitted by --emit-baseline".into()),
    }
    let rows = b
        .get("micro_hotpaths")
        .map(|m| op_medians(m).len())
        .unwrap_or(0);
    if rows < MIN_COMMON_OPS {
        return Err(format!(
            "source has {rows} micro_hotpaths operation(s); a \
             measured baseline needs at least {MIN_COMMON_OPS}"));
    }
    Ok(())
}

fn diff_micro(rep: &mut DiffReport, base: &[(String, f64)],
              cur: &[(String, f64)]) {
    let mut ratios: Vec<(String, f64)> = Vec::new();
    for (op, b) in base {
        match lookup(cur, op) {
            Some(c) => ratios.push((op.clone(), c / b)),
            None => rep.push(Severity::Warn, format!(
                "operation disappeared from current run: {op}")),
        }
    }
    for (op, _) in cur {
        if lookup(base, op).is_none() {
            rep.push(Severity::Info, format!(
                "new operation (not in baseline yet): {op}"));
        }
    }
    if ratios.len() < MIN_COMMON_OPS {
        rep.push(Severity::Warn, format!(
            "only {} operation(s) common with baseline \
             (need {MIN_COMMON_OPS}); ratio gate skipped",
            ratios.len()));
        return;
    }
    let raw: Vec<f64> = ratios.iter().map(|&(_, r)| r).collect();
    let norm = median(&raw);
    rep.push(Severity::Info, format!(
        "machine-normalization factor (median raw ratio): {norm:.3}"));
    for (op, r) in &ratios {
        let rel = r / norm;
        let sev = if rel > FAIL_REL {
            Severity::Fail
        } else if rel > WARN_REL {
            Severity::Warn
        } else {
            Severity::Info
        };
        rep.push(sev, format!(
            "{op}: raw {r:.3}x, normalized {rel:.3}x"));
    }
}

fn diff_rss(rep: &mut DiffReport, baseline: &Json,
            table10: Option<&Json>) {
    let base_rss = baseline
        .get("table10")
        .and_then(|t| t.get("peak_rss_bytes"))
        .and_then(Json::as_f64);
    let cur_rss = table10
        .and_then(|t| t.get("peak_rss_bytes"))
        .and_then(Json::as_f64);
    match (base_rss, cur_rss) {
        (Some(b), Some(c)) if b > 0.0 => {
            let r = c / b;
            let sev = if r > RSS_FAIL {
                Severity::Fail
            } else if r > RSS_WARN {
                Severity::Warn
            } else {
                Severity::Info
            };
            rep.push(sev, format!(
                "table10 peak RSS: {:.0} MB vs baseline {:.0} MB \
                 ({r:.2}x)",
                c / (1024.0 * 1024.0), b / (1024.0 * 1024.0)));
        }
        (Some(_), None) => rep.push(Severity::Info,
            "BENCH_table10.json absent or lacks peak_rss_bytes; \
             RSS gate skipped".into()),
        _ => rep.push(Severity::Info,
            "baseline lacks table10 peak_rss_bytes; \
             RSS gate skipped".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(rows: &[(&str, f64)]) -> Json {
        let body: Vec<String> = rows
            .iter()
            .map(|(op, t)| format!(
                "{{\"operation\":\"{op}\",\"median_s\":{t}}}"))
            .collect();
        Json::parse(&format!("{{\"results\":[{}]}}",
                             body.join(","))).unwrap()
    }

    fn baseline(rows: &[(&str, f64)], rss: Option<f64>) -> Json {
        let micro = summary(rows).to_string();
        let mut b = format!("{{\"bench\":\"baseline\",\
                             \"micro_hotpaths\":{micro}");
        if let Some(r) = rss {
            b.push_str(&format!(",\"table10\":{{\
                                 \"peak_rss_bytes\":{r}}}"));
        }
        b.push('}');
        Json::parse(&b).unwrap()
    }

    const OPS: [(&str, f64); 4] = [
        ("dot", 1e-5), ("matmul", 2e-4), ("gather", 5e-5),
        ("transpose", 7e-5),
    ];

    #[test]
    fn identical_run_is_clean() {
        let rep = diff(&baseline(&OPS, None), Some(&summary(&OPS)),
                       None);
        assert!(!rep.failed() && !rep.warned(), "{}", rep.render());
    }

    #[test]
    fn uniformly_slower_machine_is_clean() {
        let cur: Vec<(&str, f64)> =
            OPS.iter().map(|&(o, t)| (o, t * 3.0)).collect();
        let rep = diff(&baseline(&OPS, None), Some(&summary(&cur)),
                       None);
        assert!(!rep.failed() && !rep.warned(), "{}", rep.render());
    }

    #[test]
    fn single_op_drift_warns_but_does_not_fail() {
        let mut cur = OPS.to_vec();
        cur[1].1 *= 1.5; // matmul 50% slower, others unchanged
        let rep = diff(&baseline(&OPS, None), Some(&summary(&cur)),
                       None);
        assert!(rep.warned(), "{}", rep.render());
        assert!(!rep.failed(), "{}", rep.render());
    }

    #[test]
    fn single_op_blowup_fails() {
        let mut cur = OPS.to_vec();
        cur[0].1 *= 3.0; // dot 3x slower vs a stable rest
        let rep = diff(&baseline(&OPS, None), Some(&summary(&cur)),
                       None);
        assert!(rep.failed(), "{}", rep.render());
    }

    #[test]
    fn missing_operation_warns() {
        let cur = &OPS[..2];
        let rep = diff(&baseline(&OPS, None), Some(&summary(cur)),
                       None);
        assert!(rep.warned(), "{}", rep.render());
        assert!(!rep.failed());
    }

    #[test]
    fn missing_micro_summary_warns_only() {
        let rep = diff(&baseline(&OPS, None), None, None);
        assert!(rep.warned() && !rep.failed(), "{}", rep.render());
    }

    #[test]
    fn rss_gate_fires_on_blowup() {
        let t10 = Json::parse(
            "{\"peak_rss_bytes\":700000000}").unwrap();
        let rep = diff(&baseline(&OPS, Some(2.0e8)),
                       Some(&summary(&OPS)), Some(&t10));
        assert!(rep.failed(), "{}", rep.render());
    }

    #[test]
    fn rss_gate_warns_between_thresholds() {
        let t10 = Json::parse(
            "{\"peak_rss_bytes\":400000000}").unwrap();
        let rep = diff(&baseline(&OPS, Some(2.0e8)),
                       Some(&summary(&OPS)), Some(&t10));
        assert!(rep.warned() && !rep.failed(), "{}", rep.render());
    }

    #[test]
    fn seeded_baseline_downgrades_failures_to_warnings() {
        let mut cur = OPS.to_vec();
        cur[0].1 *= 3.0;
        let mut base = baseline(&OPS, None);
        if let Json::Obj(m) = &mut base {
            m.insert("seeded_estimate".into(), Json::Bool(true));
        }
        let rep = diff(&base, Some(&summary(&cur)), None);
        assert!(!rep.failed(), "{}", rep.render());
        assert!(rep.warned(), "{}", rep.render());
    }

    #[test]
    fn emitted_baseline_round_trips_through_diff() {
        let micro = summary(&OPS);
        let t10 = Json::parse(
            "{\"peak_rss_bytes\":200000000}").unwrap();
        let b = make_baseline(Some(&micro), Some(&t10));
        assert_eq!(b.get("seeded_estimate").and_then(Json::as_bool),
                   Some(false));
        let rep = diff(&b, Some(&micro), Some(&t10));
        assert!(!rep.failed() && !rep.warned(), "{}", rep.render());
    }

    #[test]
    fn promotion_accepts_only_measured_baselines() {
        // the --emit-baseline product passes
        let good = make_baseline(Some(&summary(&OPS)), None);
        assert!(validate_measured_baseline(&good).is_ok());
        // a seeded estimate is refused
        let mut seeded = good.clone();
        if let Json::Obj(m) = &mut seeded {
            m.insert("seeded_estimate".into(), Json::Bool(true));
        }
        assert!(validate_measured_baseline(&seeded)
            .unwrap_err().contains("seeded"));
        // an unstamped file is refused (not an emit-baseline product)
        let unstamped = baseline(&OPS, None);
        assert!(validate_measured_baseline(&unstamped)
            .unwrap_err().contains("stamp"));
        // a truncated measurement is refused
        let thin = make_baseline(Some(&summary(&OPS[..1])), None);
        assert!(validate_measured_baseline(&thin)
            .unwrap_err().contains("operation"));
    }

    #[test]
    fn mean_fallback_for_old_summaries() {
        let old = Json::parse(
            "{\"results\":[{\"operation\":\"dot\",\
             \"mean_s\":1e-5}]}").unwrap();
        assert_eq!(op_medians(&old), vec![("dot".to_string(), 1e-5)]);
    }

    #[test]
    fn median_is_order_statistic() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }
}
