//! CLI entry point: `cargo run -p benchdiff [-- FLAGS]`.
//!
//! Flags (all optional; defaults resolve against the workspace root):
//!   --baseline PATH   committed baseline   (BENCH_baseline.json)
//!   --micro PATH      current micro run    (BENCH_micro_hotpaths.json)
//!   --table10 PATH    current large run    (BENCH_table10.json)
//!   --report PATH     where to write the text report
//!                     (bench_diff_report.txt)
//!   --emit-baseline PATH   also write a measured baseline built from
//!                     the current summaries (CI uploads this so a
//!                     maintainer can replace a seeded estimate)
//!   --promote PATH    copy a *measured* baseline (the
//!                     --emit-baseline product, e.g.
//!                     BENCH_baseline.next.json) over the committed
//!                     baseline at --baseline, then exit. Refuses
//!                     seeded estimates and truncated files; this is
//!                     the only sanctioned way measured numbers enter
//!                     BENCH_baseline.json (see the lib docs,
//!                     "Baseline lifecycle").
//!
//! Exit codes: 0 clean or warnings only (warnings are non-blocking),
//! 1 blocking regression (> 2.0x normalized, or RSS > 3x), 2 usage /
//! missing baseline / parse error / refused promotion. CI runs this
//! in the bench-artifacts job right after the bench targets and
//! uploads the report next to the `BENCH_*.json` artifacts.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use volcanoml::util::json::Json;

fn workspace_root() -> PathBuf {
    let local = PathBuf::from("BENCH_baseline.json");
    if local.is_file() {
        return PathBuf::from(".");
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..")
}

struct Cli {
    baseline: PathBuf,
    micro: PathBuf,
    table10: PathBuf,
    report: PathBuf,
    emit_baseline: Option<PathBuf>,
    promote: Option<PathBuf>,
}

fn parse_args() -> Result<Cli, String> {
    let root = workspace_root();
    let mut cli = Cli {
        baseline: root.join("BENCH_baseline.json"),
        micro: root.join("BENCH_micro_hotpaths.json"),
        table10: root.join("BENCH_table10.json"),
        report: root.join("bench_diff_report.txt"),
        emit_baseline: None,
        promote: None,
    };
    let mut args = std::env::args().skip(1);
    while let Some(flag) = args.next() {
        let v = PathBuf::from(args.next()
            .ok_or_else(|| format!("{flag} needs a value"))?);
        match flag.as_str() {
            "--baseline" => cli.baseline = v,
            "--micro" => cli.micro = v,
            "--table10" => cli.table10 = v,
            "--report" => cli.report = v,
            "--emit-baseline" => cli.emit_baseline = Some(v),
            "--promote" => cli.promote = Some(v),
            other => return Err(format!("unknown flag: {other}")),
        }
    }
    Ok(cli)
}

/// Load an optional summary: absent file -> None (the diff degrades
/// to a warning), unparseable file -> hard error.
fn load_optional(path: &Path) -> Result<Option<Json>, String> {
    if !path.is_file() {
        return Ok(None);
    }
    Json::parse_file(path)
        .map(Some)
        .map_err(|e| format!("{}: {e}", path.display()))
}

fn main() -> ExitCode {
    let cli = match parse_args() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("benchdiff: {e}");
            return ExitCode::from(2);
        }
    };
    // Promotion mode: validate and install the measured baseline,
    // then exit — no diff runs against the file being replaced.
    if let Some(src) = &cli.promote {
        let measured = match Json::parse_file(src) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("benchdiff: cannot read {}: {e}",
                          src.display());
                return ExitCode::from(2);
            }
        };
        if let Err(e) = benchdiff::validate_measured_baseline(
            &measured)
        {
            eprintln!("benchdiff: refusing to promote {}: {e}",
                      src.display());
            return ExitCode::from(2);
        }
        if let Err(e) = std::fs::write(&cli.baseline,
                                       measured.to_string()) {
            eprintln!("benchdiff: cannot write {}: {e}",
                      cli.baseline.display());
            return ExitCode::from(2);
        }
        println!("[promoted {} -> {}]", src.display(),
                 cli.baseline.display());
        println!("the blocking gate now runs against measured \
                  numbers; commit the updated baseline");
        return ExitCode::SUCCESS;
    }

    let baseline = match Json::parse_file(&cli.baseline) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("benchdiff: cannot read baseline {}: {e}",
                      cli.baseline.display());
            return ExitCode::from(2);
        }
    };
    let (micro, table10) = match (load_optional(&cli.micro),
                                  load_optional(&cli.table10)) {
        (Ok(m), Ok(t)) => (m, t),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("benchdiff: {e}");
            return ExitCode::from(2);
        }
    };

    let rep = benchdiff::diff(&baseline, micro.as_ref(),
                              table10.as_ref());
    let rendered = rep.render();
    print!("{rendered}");
    if let Err(e) = std::fs::write(&cli.report, &rendered) {
        eprintln!("benchdiff: cannot write report {}: {e}",
                  cli.report.display());
    } else {
        println!("[report -> {}]", cli.report.display());
    }
    if let Some(emit) = &cli.emit_baseline {
        let b = benchdiff::make_baseline(micro.as_ref(),
                                         table10.as_ref());
        if let Err(e) = std::fs::write(emit, b.to_string()) {
            eprintln!("benchdiff: cannot write baseline {}: {e}",
                      emit.display());
        } else {
            println!("[measured baseline -> {}]", emit.display());
        }
    }
    if rep.failed() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
