//! detlint — the repo's determinism lint.
//!
//! The search trajectory must be a pure function of `(dataset, space,
//! budget, seed)` (ROADMAP north star; enforced end-to-end by the
//! determinism suites). Most regressions that break that property are
//! textually recognisable long before a flaky test catches them, so
//! this lint walks `rust/src` and rejects:
//!
//! - **hash-iter** — `HashMap`/`HashSet` in the search-path modules
//!   (`blocks/`, `coordinator/`, `opt/`, `space/`, `fe/`). Hash
//!   iteration order is randomised per process; any map whose order
//!   can leak into scores, candidate lists or block construction must
//!   be a `BTreeMap`/`BTreeSet`. Lookup-only maps may stay hashed
//!   with a `// DETLINT: allow(hash-iter): <why>` note.
//! - **wall-clock** — `Instant::now` / `SystemTime` outside the
//!   deadline and bench whitelist. Clock reads on the search path are
//!   hidden nondeterminism; telemetry-only reads take
//!   `// DETLINT: allow(wall-clock): <why>`.
//! - **unsafe-no-safety** — any `unsafe` without a `// SAFETY:`
//!   argument in the surrounding comment paragraph.
//! - **relaxed-no-sync** — any `Ordering::Relaxed` without a
//!   `// SYNC:` note arguing why the weakest ordering suffices.
//! - **kernel-scalar** — hand-rolled scalar float reductions in the
//!   kernel-owned hot files (`util/linalg.rs`, `fe/ops.rs`): a plain
//!   scalar accumulator (`s += x * y`, `acc += v as f64`) or an
//!   iterator `.sum()` fold. Reduction order is the bit-determinism
//!   contract, and `util/kernels` owns it — route the loop through a
//!   kernel, or justify with `// DETLINT: allow(kernel-scalar):
//!   <why this loop cannot use a kernel>`. Element-wise indexed
//!   updates (`w[j] += …`) are not reductions and are exempt.
//! - **obs-clock** — raw clock reads (`Instant::now` / `SystemTime`)
//!   in the observability modules (`obs/`) outside the one sanctioned
//!   choke point, `obs/clock.rs`. Every obs timestamp flows through
//!   `obs::clock::now_ns()` so the neutrality audit has a single
//!   site to inspect; a scattered clock read is either redundant or
//!   a new epoch that breaks trace merging. Justify exceptions with
//!   `// DETLINT: allow(obs-clock): <why this read cannot use
//!   obs::clock>`.
//!
//! Suppression markers are *paragraph-scoped*: a marker counts if it
//! appears in the comments of the flagged line or of any contiguous
//! non-blank line above it (bounded lookback). A blank line ends the
//! paragraph, so a stale marker cannot silently cover code added
//! below it.
//!
//! `#[cfg(test)]` regions are skipped entirely — tests may use hash
//! maps, clocks and relaxed counters freely.
//!
//! The scanner is a line-oriented lexer, not a parser: it strips
//! comments (nested block comments included), string/char/byte
//! literals and raw strings, distinguishes lifetimes from char
//! literals, and then pattern-matches the surviving code text. That
//! is exactly enough to make the rules precise on this codebase
//! without a syntax-tree dependency.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Search-path directories (relative to the source root) where hash
/// collections are rejected.
pub const HASH_SCOPED_DIRS: [&str; 5] =
    ["blocks/", "coordinator/", "opt/", "space/", "fe/"];

/// Files (relative to the source root) allowed to read the wall
/// clock: the budget/deadline owner, the reporting binaries, and the
/// observability layer's single clock choke point.
pub const WALL_CLOCK_WHITELIST: [&str; 4] =
    ["bench.rs", "main.rs", "coordinator/evaluator.rs",
     "obs/clock.rs"];

/// Directory (relative to the source root) where raw clock reads are
/// rejected in favour of the `obs::clock` choke point.
pub const OBS_CLOCK_DIR: &str = "obs/";

/// The one file inside [`OBS_CLOCK_DIR`] that may read the raw clock.
pub const OBS_CLOCK_CHOKE_POINT: &str = "obs/clock.rs";

/// Files (relative to the source root) where hand-rolled scalar float
/// reductions are rejected: their reductions define trajectory bits
/// and belong to `util/kernels`.
pub const KERNEL_SCOPED_FILES: [&str; 2] =
    ["util/linalg.rs", "fe/ops.rs"];

/// Bounded lookback (in lines) of the paragraph marker scan.
const PARAGRAPH_LOOKBACK: usize = 40;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    HashIter,
    WallClock,
    UnsafeNoSafety,
    RelaxedNoSync,
    KernelScalar,
    ObsClock,
}

impl Rule {
    pub fn tag(self) -> &'static str {
        match self {
            Rule::HashIter => "hash-iter",
            Rule::WallClock => "wall-clock",
            Rule::UnsafeNoSafety => "unsafe-no-safety",
            Rule::RelaxedNoSync => "relaxed-no-sync",
            Rule::KernelScalar => "kernel-scalar",
            Rule::ObsClock => "obs-clock",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Violation {
    /// Path relative to the linted source root.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}",
               self.file, self.line, self.rule.tag(), self.msg)
    }
}

/// Result of linting a tree: how much was covered, and what failed.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub files: usize,
    pub violations: Vec<Violation>,
}

// ---------------------------------------------------------------------
// lexing: split each line into code text and comment text
// ---------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct SplitLine {
    code: String,
    comment: String,
}

impl SplitLine {
    fn is_blank(&self) -> bool {
        self.code.trim().is_empty() && self.comment.trim().is_empty()
    }
}

/// Lexer state that survives a newline (block comments and strings
/// may span lines; everything else is line-local).
#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    LineComment,
    /// Nesting depth (Rust block comments nest).
    BlockComment(u32),
    Str,
    /// Number of `#`s in the `r#…"` opener.
    RawStr(u32),
    CharLit,
}

/// Strip literals and separate comments: per input line, the code
/// text (literals blanked to a single space) and the comment text.
fn split_lines(src: &str) -> Vec<SplitLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut cur = SplitLine::default();
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            out.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        let next = chars.get(i + 1).copied().unwrap_or('\0');
        match mode {
            Mode::Code => {
                let prev_word = i > 0 && is_word_char(chars[i - 1]);
                if c == '/' && next == '/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == '*' {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Str;
                    cur.code.push(' ');
                    i += 1;
                } else if !prev_word
                    && (c == 'r' || c == 'b')
                    && raw_str_hashes(&chars, i).is_some()
                {
                    // r"…", r#"…"#, br#"…"# (b consumed en route)
                    let (hashes, skip) =
                        raw_str_hashes(&chars, i).unwrap();
                    mode = Mode::RawStr(hashes);
                    cur.code.push(' ');
                    i += skip;
                } else if !prev_word && c == 'b' && next == '"' {
                    mode = Mode::Str;
                    cur.code.push(' ');
                    i += 2;
                } else if !prev_word && c == 'b' && next == '\'' {
                    // byte-char literal b'x' — never a lifetime
                    mode = Mode::CharLit;
                    cur.code.push(' ');
                    i += 2;
                } else if c == '\'' {
                    // char literal vs lifetime: a literal either
                    // escapes ('\n') or closes two chars on ('x');
                    // anything else ('env, 'static) is a lifetime
                    if next == '\\' {
                        mode = Mode::CharLit;
                        cur.code.push(' ');
                        i += 1;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        cur.code.push(' ');
                        i += 3;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == '/' && next == '*' {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == '/' {
                    mode = if depth == 1 {
                        Mode::Code
                    } else {
                        Mode::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    mode = Mode::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            Mode::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.is_blank() {
        out.push(cur);
    }
    out
}

fn is_word_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// At `chars[i]` sitting on `r` or `b`: if an `r`/`br` raw-string
/// opener starts here, the `#` count and the opener's length.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Does the `"` at `chars[i]` close a raw string with `hashes` `#`s?
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize)
        .all(|k| chars.get(i + k) == Some(&'#'))
}

// ---------------------------------------------------------------------
// word-boundary matching on the code text
// ---------------------------------------------------------------------

fn is_word_byte(b: u8) -> bool {
    // non-ASCII conservatively counts as a word byte
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// `needle` (ASCII) occurs in `hay` with word boundaries on both
/// sides — so `unsafe_op_in_unsafe_fn` does not contain the word
/// `unsafe`.
fn contains_word(hay: &str, needle: &str) -> bool {
    let hb = hay.as_bytes();
    let nb = needle.as_bytes();
    if nb.is_empty() || hb.len() < nb.len() {
        return false;
    }
    hb.windows(nb.len()).enumerate().any(|(pos, w)| {
        w == nb
            && (pos == 0 || !is_word_byte(hb[pos - 1]))
            && (pos + nb.len() == hb.len()
                || !is_word_byte(hb[pos + nb.len()]))
    })
}

/// A `cfg` attribute-ish call whose argument mentions `test`:
/// `#[cfg(test)]`, `#[cfg(all(test, …))]`. `cfg!(…)` (expression
/// position) and `cfg_attr` deliberately do not match.
fn has_cfg_test(code: &str) -> bool {
    if !contains_word(code, "test") {
        return false;
    }
    let b = code.as_bytes();
    b.windows(3).enumerate().any(|(pos, w)| {
        w == b"cfg"
            && (pos == 0 || !is_word_byte(b[pos - 1]))
            && {
                let mut j = pos + 3;
                while j < b.len() && b[j].is_ascii_whitespace() {
                    j += 1;
                }
                j < b.len() && b[j] == b'('
            }
    })
}

// ---------------------------------------------------------------------
// region and paragraph analysis
// ---------------------------------------------------------------------

/// Mark every line belonging to a `#[cfg(test)]`-gated item. From the
/// attribute line, the gated item is brace-tracked to its closing
/// `}`; a `;` before any `{` means a braceless item (`#[cfg(test)]
/// use …;`) that ends the region on that line.
fn test_regions(lines: &[SplitLine]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !has_cfg_test(&lines[i].code) {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        'scan: while j < lines.len() {
            in_test[j] = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            break 'scan;
                        }
                    }
                    ';' if !opened => break 'scan,
                    _ => {}
                }
            }
            j += 1;
        }
        i = j + 1;
    }
    in_test
}

/// Paragraph-scoped marker: `marker` occurs in the comment text of
/// line `idx` or of a contiguous non-blank line above it (bounded
/// lookback). A blank line ends the paragraph.
fn paragraph_has_marker(lines: &[SplitLine], idx: usize,
                        marker: &str) -> bool {
    let lo = idx.saturating_sub(PARAGRAPH_LOOKBACK);
    let mut j = idx;
    loop {
        let l = &lines[j];
        if l.is_blank() {
            return false;
        }
        if l.comment.contains(marker) {
            return true;
        }
        if j == lo {
            return false;
        }
        j -= 1;
    }
}

/// Does this code line carry an order-defining scalar float
/// reduction? Two shapes:
///
/// * an iterator fold: `.sum()` / `.sum::<f64>()`;
/// * a compound assignment onto a *plain* accumulator (`s`, `*sj`,
///   `self.acc`) whose right side multiplies or widens (`x * y`,
///   `v as f64`) — the signature of a running dot/moment. An indexed
///   left side (`w[j] += …`) is an element-wise update whose order
///   never reassociates a float sum, so it is exempt.
fn is_scalar_reduction(code: &str) -> bool {
    if code.contains(".sum()") || code.contains(".sum::<") {
        return true;
    }
    let Some(pos) = code.find("+=").or_else(|| code.find("-="))
    else {
        return false;
    };
    let (lhs, rhs) = code.split_at(pos);
    let rhs = &rhs[2..];
    if !(rhs.contains(" * ")
        || rhs.contains(" as f64")
        || rhs.contains(" as f32"))
    {
        return false;
    }
    let lhs = lhs.trim();
    let lhs = lhs.strip_prefix('*').unwrap_or(lhs).trim();
    !lhs.is_empty()
        && lhs.chars().all(|c| {
            c.is_alphanumeric() || c == '_' || c == '.'
        })
}

fn is_import_line(code: &str) -> bool {
    let t = code.trim_start();
    t.starts_with("use ")
        || t.starts_with("pub use ")
        || t.starts_with("pub(crate) use ")
}

// ---------------------------------------------------------------------
// the lint proper
// ---------------------------------------------------------------------

/// Lint one file's source. `rel` is the path relative to the source
/// root with `/` separators (it selects the directory-scoped rules).
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let lines = split_lines(src);
    let in_test = test_regions(&lines);
    let hash_scoped =
        HASH_SCOPED_DIRS.iter().any(|d| rel.starts_with(d));
    let clock_ok = WALL_CLOCK_WHITELIST.contains(&rel);
    let kernel_scoped = KERNEL_SCOPED_FILES.contains(&rel);
    let obs_scoped = rel.starts_with(OBS_CLOCK_DIR)
        && rel != OBS_CLOCK_CHOKE_POINT;
    let mut out = Vec::new();
    let mut push = |line: usize, rule: Rule, msg: String| {
        out.push(Violation { file: rel.to_string(), line, rule, msg });
    };
    for (i, l) in lines.iter().enumerate() {
        if in_test[i] || l.code.trim().is_empty() {
            continue;
        }
        let code = l.code.as_str();
        let n = i + 1;
        if hash_scoped
            && (contains_word(code, "HashMap")
                || contains_word(code, "HashSet"))
            && !is_import_line(code)
            && !paragraph_has_marker(
                &lines, i, "DETLINT: allow(hash-iter)")
        {
            push(n, Rule::HashIter,
                 "hash collection on the search path: iteration \
                  order is process-random — use BTreeMap/BTreeSet, \
                  or mark the paragraph `// DETLINT: \
                  allow(hash-iter): <why order never leaks>`"
                     .to_string());
        }
        let clock_read = code.contains("Instant::now")
            || contains_word(code, "SystemTime");
        if obs_scoped
            && clock_read
            && !paragraph_has_marker(
                &lines, i, "DETLINT: allow(obs-clock)")
        {
            push(n, Rule::ObsClock,
                 "raw clock read in obs/ outside obs/clock.rs: \
                  every observability timestamp flows through \
                  obs::clock::now_ns() so the neutrality audit has \
                  one site to inspect — route through obs::clock, \
                  or mark the paragraph `// DETLINT: \
                  allow(obs-clock): <why obs::clock cannot serve \
                  this read>`"
                     .to_string());
        } else if !clock_ok
            && clock_read
            && !paragraph_has_marker(
                &lines, i, "DETLINT: allow(wall-clock)")
        {
            push(n, Rule::WallClock,
                 "wall-clock read outside the deadline/bench \
                  whitelist: clocks on the search path are hidden \
                  nondeterminism — route through the evaluator's \
                  budget clock, or mark the paragraph `// DETLINT: \
                  allow(wall-clock): <why it cannot steer the \
                  search>`"
                     .to_string());
        }
        if contains_word(code, "unsafe")
            && !paragraph_has_marker(&lines, i, "SAFETY:")
        {
            push(n, Rule::UnsafeNoSafety,
                 "`unsafe` without a `// SAFETY:` argument in the \
                  surrounding comment paragraph"
                     .to_string());
        }
        if code.contains("Ordering::Relaxed")
            && !paragraph_has_marker(&lines, i, "SYNC:")
        {
            push(n, Rule::RelaxedNoSync,
                 "`Ordering::Relaxed` without a `// SYNC:` note \
                  arguing why the weakest ordering suffices"
                     .to_string());
        }
        if kernel_scoped
            && is_scalar_reduction(code)
            && !paragraph_has_marker(
                &lines, i, "DETLINT: allow(kernel-scalar)")
        {
            push(n, Rule::KernelScalar,
                 "scalar float reduction in a kernel-owned hot file: \
                  reduction order defines trajectory bits and \
                  util/kernels owns it — route through a lane \
                  kernel, or mark the paragraph `// DETLINT: \
                  allow(kernel-scalar): <why no kernel fits>`"
                     .to_string());
        }
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<io::Result<_>>()?;
    // sorted walk: the report (and any first-failure exit) is stable
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `src_root` (sorted walk).
pub fn lint_tree(src_root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    let mut report = Report::default();
    for f in &files {
        let rel = f
            .strip_prefix(src_root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        report.violations.extend(
            lint_source(&rel, &fs::read_to_string(f)?));
        report.files += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<Rule> {
        lint_source(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn hash_map_flagged_only_in_scoped_dirs() {
        let src = "fn f() { let m: HashMap<u32, u32> = \
                   HashMap::new(); }\n";
        assert_eq!(rules("opt/mod.rs", src),
                   vec![Rule::HashIter]);
        assert_eq!(rules("coordinator/evaluator.rs", src),
                   vec![Rule::HashIter]);
        // outside the search-path dirs the same line is fine
        assert!(rules("util/json.rs", src).is_empty());
        assert!(rules("runtime/executor.rs", src).is_empty());
    }

    #[test]
    fn hash_set_flagged_and_import_lines_exempt() {
        assert_eq!(
            rules("space/mod.rs",
                  "fn f() { let s = HashSet::new(); }\n"),
            vec![Rule::HashIter]);
        assert!(rules(
            "space/mod.rs",
            "use std::collections::{HashMap, HashSet};\n")
            .is_empty());
        assert!(rules(
            "space/mod.rs",
            "pub use std::collections::HashMap;\n")
            .is_empty());
    }

    #[test]
    fn hash_iter_marker_suppresses_within_paragraph() {
        let ok = "// DETLINT: allow(hash-iter): lookups only\n\
                  let s = HashSet::new();\n";
        assert!(rules("fe/mod.rs", ok).is_empty());
        // a trailing same-line comment also counts
        let trailing = "let s = HashSet::new(); \
                        // DETLINT: allow(hash-iter): lookups only\n";
        assert!(rules("fe/mod.rs", trailing).is_empty());
        // a blank line ends the paragraph: the marker must not leak
        let stale = "// DETLINT: allow(hash-iter): old note\n\
                     \n\
                     let s = HashSet::new();\n";
        assert_eq!(rules("fe/mod.rs", stale), vec![Rule::HashIter]);
    }

    #[test]
    fn hash_words_in_strings_and_comments_do_not_count() {
        let src = "// a HashMap would be wrong here\n\
                   fn f() { log(\"HashMap order\"); }\n\
                   /* HashSet in a block comment */\n";
        assert!(rules("blocks/mod.rs", src).is_empty());
        // word boundary: MyHashMapLike is not HashMap
        assert!(rules("blocks/mod.rs",
                      "fn f(m: &MyHashMapLike) {}\n")
            .is_empty());
    }

    #[test]
    fn wall_clock_flagged_outside_whitelist() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(rules("opt/mod.rs", src), vec![Rule::WallClock]);
        assert_eq!(rules("util/rng.rs", src), vec![Rule::WallClock]);
        // the deadline owner and the binaries are whitelisted
        assert!(rules("coordinator/evaluator.rs", src).is_empty());
        assert!(rules("bench.rs", src).is_empty());
        assert!(rules("main.rs", src).is_empty());
        assert_eq!(
            rules("fe/mod.rs",
                  "let t = SystemTime::now();\n"),
            vec![Rule::WallClock]);
        let marked =
            "// DETLINT: allow(wall-clock): telemetry only\n\
             let t = std::time::Instant::now();\n";
        assert!(rules("runtime/mod.rs", marked).is_empty());
    }

    #[test]
    fn obs_clock_routes_through_the_choke_point() {
        let src = "fn f() { let t = Instant::now(); }\n";
        // raw reads in obs/ fire obs-clock (not wall-clock): the
        // layer has its own choke point
        assert_eq!(rules("obs/trace.rs", src), vec![Rule::ObsClock]);
        assert_eq!(rules("obs/profile.rs", src),
                   vec![Rule::ObsClock]);
        assert_eq!(
            rules("obs/metrics.rs",
                  "let t = SystemTime::now();\n"),
            vec![Rule::ObsClock]);
        // the choke point itself is the sanctioned reader
        assert!(rules("obs/clock.rs", src).is_empty());
        // the wall-clock marker does not cover obs-clock: the rules
        // have distinct markers so a telemetry waiver cannot bless a
        // second epoch
        let wrong_marker =
            "// DETLINT: allow(wall-clock): telemetry only\n\
             let t = Instant::now();\n";
        assert_eq!(rules("obs/trace.rs", wrong_marker),
                   vec![Rule::ObsClock]);
        let ok = "// DETLINT: allow(obs-clock): calibration read,\n\
                  // compared against obs::clock in a test helper\n\
                  let t = Instant::now();\n";
        assert!(rules("obs/trace.rs", ok).is_empty());
        // calls through the choke point are what the rule demands —
        // they must not match
        assert!(rules(
            "obs/trace.rs",
            "let ts = crate::obs::clock::now_ns();\n")
            .is_empty());
    }

    #[test]
    fn unsafe_requires_safety_paragraph() {
        assert_eq!(
            rules("runtime/executor.rs",
                  "let p = unsafe { std::mem::transmute(q) };\n"),
            vec![Rule::UnsafeNoSafety]);
        let ok = "// SAFETY: the handle joins before 'env dies,\n\
                  // so the erased lifetime never dangles.\n\
                  let p = unsafe { std::mem::transmute(q) };\n";
        assert!(rules("runtime/executor.rs", ok).is_empty());
        // the lint-gate identifier is not the keyword
        assert!(rules("lib.rs",
                      "#![deny(unsafe_op_in_unsafe_fn)]\n")
            .is_empty());
    }

    #[test]
    fn relaxed_requires_sync_paragraph() {
        assert_eq!(
            rules("cache/mod.rs",
                  "self.hits.fetch_add(1, Ordering::Relaxed);\n"),
            vec![Rule::RelaxedNoSync]);
        let ok = "// SYNC: Relaxed — monotone stats counter\n\
                  self.hits.fetch_add(1, Ordering::Relaxed);\n";
        assert!(rules("cache/mod.rs", ok).is_empty());
        // stronger orderings need no note
        assert!(rules(
            "cache/mod.rs",
            "self.bytes.load(Ordering::Acquire);\n")
            .is_empty());
    }

    #[test]
    fn kernel_scalar_flags_accumulators_in_scoped_files() {
        let dotloop = "fn f(a: &[f64], b: &[f64]) -> f64 {\n\
                       let mut s = 0.0;\n\
                       for i in 0..a.len() {\n\
                       s += a[i] * b[i];\n\
                       }\n\
                       s\n\
                       }\n";
        assert_eq!(rules("util/linalg.rs", dotloop),
                   vec![Rule::KernelScalar]);
        assert_eq!(rules("fe/ops.rs", dotloop),
                   vec![Rule::KernelScalar]);
        // the same loop is fine outside the kernel-owned files
        assert!(rules("opt/mod.rs", dotloop).is_empty());
        assert!(rules("util/stats.rs", dotloop).is_empty());
        // widening accumulation counts (deref'd accumulator too)
        assert_eq!(
            rules("fe/ops.rs", "*sj += c[i] as f64;\n"),
            vec![Rule::KernelScalar]);
        // iterator folds count
        assert_eq!(
            rules("util/linalg.rs",
                  "let t: f64 = xs.iter().map(|x| x * x).sum();\n"),
            vec![Rule::KernelScalar]);
        assert_eq!(
            rules("util/linalg.rs",
                  "let t = xs.iter().sum::<f64>();\n"),
            vec![Rule::KernelScalar]);
    }

    #[test]
    fn kernel_scalar_exempts_elementwise_and_counters() {
        // indexed LHS: element-wise update, not a reduction
        assert!(rules("fe/ops.rs",
                      "w[j] += lr * g;\n").is_empty());
        assert!(rules("fe/ops.rs",
                      "acc[i % 8] += x * y;\n").is_empty());
        // no multiply / widen on the RHS: counters and steps
        assert!(rules("util/linalg.rs", "i += 1;\n").is_empty());
        assert!(rules("util/linalg.rs", "s += v;\n").is_empty());
        // centering loop: subtraction without multiply
        assert!(rules("util/linalg.rs", "*x -= mu;\n").is_empty());
    }

    #[test]
    fn kernel_scalar_marker_suppresses_within_paragraph() {
        let ok = "// DETLINT: allow(kernel-scalar): column-strided\n\
                  // access no kernel covers; ≤ MAX_WIDTH terms\n\
                  s += l[(k, i)] * l[(k, j)];\n";
        assert!(rules("util/linalg.rs", ok).is_empty());
        let stale = "// DETLINT: allow(kernel-scalar): old note\n\
                     \n\
                     s += a[i] * b[i];\n";
        assert_eq!(rules("util/linalg.rs", stale),
                   vec![Rule::KernelScalar]);
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = "fn prod() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       use std::collections::HashMap;\n\
                       #[test]\n\
                       fn t() {\n\
                           let m = HashMap::new();\n\
                           let t0 = Instant::now();\n\
                           x.store(1, Ordering::Relaxed);\n\
                       }\n\
                   }\n";
        assert!(rules("opt/mod.rs", src).is_empty());
        // cfg(all(test, …)) gates a region too
        let all = "#[cfg(all(test, feature = \"slow\"))]\n\
                   mod tests { fn t() { HashSet::new(); } }\n";
        assert!(rules("opt/mod.rs", all).is_empty());
    }

    #[test]
    fn braceless_cfg_test_item_ends_at_semicolon() {
        // the gated `use` is exempt, but the region must not swallow
        // the real code after it
        let src = "#[cfg(test)]\n\
                   use std::collections::HashMap;\n\
                   fn prod() { let m = HashMap::new(); }\n";
        assert_eq!(rules("opt/mod.rs", src), vec![Rule::HashIter]);
    }

    #[test]
    fn cfg_expression_macro_is_not_a_region() {
        // cfg!(test) in expression position gates nothing textually
        let src = "fn f() {\n\
                   let n = if cfg!(test) { 1 } else { 2 };\n\
                   let m = HashSet::new();\n\
                   }\n";
        assert_eq!(rules("opt/mod.rs", src), vec![Rule::HashIter]);
    }

    #[test]
    fn lexer_handles_literals_braces_and_lifetimes() {
        // byte-char braces must not corrupt the brace tracking that
        // bounds a test region (this is util/json.rs's idiom)
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { expect(b'{')?; expect(b'}')?; }\n\
                       fn u() { let m = HashMap::new(); }\n\
                   }\n\
                   fn prod() { let m = HashMap::new(); }\n";
        let got = lint_source("fe/mod.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].line, 6);
        // char escapes, lifetimes and raw strings all lex as
        // non-code; the raw string's quote must not open a string
        // that eats the following flagged line
        let src2 = "fn f<'env>(c: char) -> &'env str {\n\
                    let q = '\\'';\n\
                    let r = r#\"Instant::now() \"quoted\"\"#;\n\
                    let t = Instant::now();\n\
                    unreachable!()\n\
                    }\n";
        let got2 = lint_source("opt/mod.rs", src2);
        assert_eq!(got2.len(), 1, "{got2:?}");
        assert_eq!(got2[0].rule, Rule::WallClock);
        assert_eq!(got2[0].line, 4);
        // nested block comments close correctly
        let src3 = "/* outer /* inner */ still comment:\n\
                    HashMap::new() */\n\
                    fn f() {}\n";
        assert!(rules("opt/mod.rs", src3).is_empty());
    }

    #[test]
    fn violations_render_with_file_line_and_rule() {
        let v = lint_source(
            "opt/mod.rs",
            "fn f() { let m = HashMap::new(); }\n");
        let s = v[0].to_string();
        assert!(s.starts_with("opt/mod.rs:1: [hash-iter]"), "{s}");
    }

    /// The lint must hold on the actual tree: every hash collection
    /// on the search path is a BTree or annotated, every clock read
    /// is whitelisted or annotated, every `unsafe` argues SAFETY,
    /// every Relaxed argues SYNC. This is the same invocation CI
    /// runs (`cargo run -p detlint`), as a test.
    #[test]
    fn tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../../rust/src");
        let report = lint_tree(&root).expect("walk rust/src");
        assert!(report.files > 10, "walked {} files", report.files);
        let rendered: Vec<String> = report
            .violations
            .iter()
            .map(|v| v.to_string())
            .collect();
        assert!(rendered.is_empty(),
                "determinism lint violations:\n{}",
                rendered.join("\n"));
    }
}
