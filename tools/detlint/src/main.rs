//! CLI entry point: `cargo run -p detlint [SRC_ROOT]`.
//!
//! Lints `rust/src` (or the given root) with the determinism rules
//! in [`detlint`] and exits non-zero on any violation — CI runs this
//! as a blocking job, and `tools/detlint/src/lib.rs` runs the same
//! walk as a unit test (`tree_is_clean`).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        None => {
            // from the workspace root (the CI invocation) rust/src
            // is right there; otherwise anchor on this crate
            let cwd = PathBuf::from("rust/src");
            if cwd.is_dir() {
                cwd
            } else {
                PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                    .join("../../rust/src")
            }
        }
    };
    let report = match detlint::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: cannot walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for v in &report.violations {
        println!("{v}");
    }
    if report.violations.is_empty() {
        println!("detlint: clean ({} files)", report.files);
        ExitCode::SUCCESS
    } else {
        println!("detlint: {} violation(s) in {} files",
                 report.violations.len(), report.files);
        ExitCode::FAILURE
    }
}
