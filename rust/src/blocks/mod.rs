//! VolcanoML building blocks (§3.2–3.3): the joint, conditioning and
//! alternating blocks with the paper's interfaces — `do_next!`,
//! `get_current_best`, `get_eu` (expected-utility interval, used by
//! the rising-bandit elimination), `get_eui` (expected utility
//! improvement, used by the alternating block) and `set_var`.
//!
//! Blocks optimise a black-box [`Objective`] over *subspaces*: each
//! block owns a free subspace plus a `fixed` partial assignment
//! (`f[x̄_g/c̄_g]` in the paper); evaluations always submit the merged
//! full configuration.
//!
//! `do_next!` is a *batched* pull: each leaf proposes `Env::batch`
//! candidates per invocation and submits them through
//! [`Objective::evaluate_batch`], which may evaluate them on a worker
//! pool (see `runtime::executor`). Results come back in proposal
//! order and observations are applied in that order, so the search
//! trajectory depends only on the batch size — never on the worker
//! count. `batch == 1` reproduces the original one-candidate-per-pull
//! Volcano semantics exactly.
//!
//! The pull itself is split into two halves — [`BuildingBlock::propose`]
//! plans the requests without evaluating them, and
//! [`BuildingBlock::observe`] commits the utilities — so a *parent*
//! can lift batching above the leaf: with `Env::super_batch != 1` the
//! conditioning block gathers proposals from several leaf pulls of one
//! elimination round (up to `plays_per_round × active arms` of them)
//! and submits them through a **single** `evaluate_batch` call,
//! parallelising across arms instead of only within one leaf pull.
//! Results are still committed back in proposal order, so worker
//! count never changes the trajectory; the super-batch size (like the
//! leaf batch size) is a semantic knob, and `super_batch == 1`
//! (the default) reproduces the leaf-level batching exactly.
//!
//! `propose`/`observe` are **total over the block algebra**: joint
//! leaves, alternating blocks and conditioning blocks all implement
//! them, so gathering recurses through the whole plan tree. A
//! conditioning block used as a *child* proposes one chunk of its own
//! elimination round per pull (`Env::super_batch` pulls; 0 = the
//! whole round), recursively proposing from its arms; its `observe`
//! commits the results back, runs elimination when the chunk that
//! completes a round lands, and drops observations of arms eliminated
//! while the pull was speculated ahead. Every round — at every level —
//! runs through one scheduler, [`ConditioningBlock::do_next_pipelined`]
//! (the synchronous path is the same loop with an empty speculation
//! window); the plain serial round-robin survives only where a
//! chunk-of-one gather is *not* bit-identical to it: an alternating
//! arm still in warmup (one propose covers one half, not both) and a
//! nested conditioning arm at the default knobs (one propose covers
//! one chunk, not a whole inner round).

use std::collections::VecDeque;

use anyhow::Result;

use crate::opt::multifidelity::{HyperbandFamily, MfOptimizer};
use crate::opt::{Evolutionary, Optimizer, RandomSearch, SmacBo};
use crate::space::{Config, ConfigSpace};
use crate::util::rng::Rng;

/// The black-box function f(x; D): evaluate a full configuration at a
/// fidelity, returning a *utility* (higher is better).
pub trait Objective {
    fn evaluate(&mut self, cfg: &Config, fidelity: f64) -> Result<f64>;

    /// Batched pull: evaluate a slice of (config, fidelity) requests
    /// and return utilities for a **prefix** of them, in request
    /// order. The returned vector may be shorter than `reqs` when the
    /// evaluation budget runs out mid-batch — callers must only
    /// observe the returned prefix, which is how batched `do_next`
    /// preserves exact budget accounting.
    ///
    /// The default implementation evaluates sequentially, stopping at
    /// budget exhaustion between requests; parallel objectives (see
    /// `coordinator::evaluator`) fan the batch out across a worker
    /// pool while committing results in request order, so the output
    /// is identical for any worker count.
    fn evaluate_batch(&mut self, reqs: &[(Config, f64)])
        -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(reqs.len());
        for (cfg, fid) in reqs.iter() {
            // checked before *every* request (including the first):
            // a batch of 1 at zero remaining budget evaluates nothing
            if self.exhausted() {
                break;
            }
            out.push(self.evaluate(cfg, *fid)?);
        }
        Ok(out)
    }

    /// Like [`evaluate_batch`](Self::evaluate_batch), but hands the
    /// submitting thread back to the caller while the batch is in
    /// flight: `overlap` runs on the caller's thread concurrently
    /// with the evaluations (parallel objectives start the batch on
    /// their worker pool first, then invoke `overlap`, then join).
    /// This is the hook behind the async pipeline depth
    /// ([`Env::pipeline_depth`]): the conditioning block uses the
    /// window to speculatively propose the next round.
    ///
    /// Contract: `overlap` must not call back into this objective
    /// (the budget/cache state is mid-batch), and it can never
    /// observe the batch's results — whatever it proposes is based
    /// on pre-batch state only. The returned utilities follow the
    /// exact `evaluate_batch` prefix/budget semantics.
    ///
    /// The default implementation runs `overlap` first, then
    /// evaluates serially — the same "speculation never sees the
    /// results" ordering as a real overlapped pool, so trajectories
    /// are identical whether or not an objective truly overlaps.
    fn evaluate_batch_overlapped(&mut self, reqs: &[(Config, f64)],
                                 overlap: &mut dyn FnMut())
        -> Result<Vec<f64>> {
        overlap();
        self.evaluate_batch(reqs)
    }

    /// True when the budget is exhausted; blocks stop issuing work.
    fn exhausted(&self) -> bool;
}

/// Placeholder objective handed to *speculative* `propose` calls
/// (async pipeline depth): proposals must depend only on rng and
/// block state, so touching the objective mid-speculation is a bug —
/// this guard turns it into a loud panic instead of a torn read of
/// in-flight budget/cache state.
struct SpeculationGuard;

impl Objective for SpeculationGuard {
    fn evaluate(&mut self, _cfg: &Config, _fidelity: f64) -> Result<f64> {
        unreachable!("speculative propose must not evaluate")
    }

    fn exhausted(&self) -> bool {
        unreachable!("speculative propose must not consult the budget")
    }
}

pub struct Env<'a> {
    pub obj: &'a mut dyn Objective,
    pub rng: &'a mut Rng,
    /// Candidates proposed per leaf-block pull (>= 1). With 1 every
    /// leaf `do_next` evaluates exactly one configuration — the
    /// original strictly-serial Volcano semantics.
    pub batch: usize,
    /// Cross-leaf super-batching: how many *leaf pulls* a conditioning
    /// block coalesces into one `evaluate_batch` submission when
    /// playing its round. `1` (the default) disables it — every leaf
    /// pull is its own batch, the PR-1 leaf-level semantics. `0` means
    /// the whole round (`plays_per_round × active arms` pulls) goes
    /// out as a single super-batch; `n > 1` gathers chunks of `n`
    /// pulls. Like `batch`, this is a semantic knob: proposals inside
    /// one super-batch cannot see each other's results.
    pub super_batch: usize,
    /// Async pipeline depth: how many gathered chunks may be proposed
    /// ahead of the chunk currently evaluating. `1` (the default) is
    /// fully synchronous — propose, evaluate, observe, repeat — and
    /// preserves today's trajectories bit for bit. `d > 1` lets the
    /// conditioning block *speculatively* propose up to `d - 1`
    /// future chunks (crossing elimination-round boundaries) while a
    /// chunk is in flight on the worker pool, reconciling or
    /// discarding the speculation when the observations land. Like
    /// `batch`/`super_batch` this is a semantic knob (speculative
    /// proposals cannot see the in-flight results), and for any fixed
    /// depth the trajectory is still worker-count invariant.
    pub pipeline_depth: usize,
}

impl<'a> Env<'a> {
    /// Serial environment (batch of 1).
    pub fn new(obj: &'a mut dyn Objective, rng: &'a mut Rng) -> Env<'a> {
        Env::with_batch(obj, rng, 1)
    }

    pub fn with_batch(obj: &'a mut dyn Objective, rng: &'a mut Rng,
                      batch: usize) -> Env<'a> {
        Env::with_super_batch(obj, rng, batch, 1)
    }

    pub fn with_super_batch(obj: &'a mut dyn Objective,
                            rng: &'a mut Rng, batch: usize,
                            super_batch: usize) -> Env<'a> {
        Env::with_pipeline(obj, rng, batch, super_batch, 1)
    }

    pub fn with_pipeline(obj: &'a mut dyn Objective, rng: &'a mut Rng,
                         batch: usize, super_batch: usize,
                         pipeline_depth: usize) -> Env<'a> {
        Env {
            obj,
            rng,
            batch: batch.max(1),
            super_batch,
            pipeline_depth: pipeline_depth.max(1),
        }
    }
}

// ====================================================================
// Split pulls: propose / observe
// ====================================================================

/// A planned-but-unevaluated pull: the (full config, fidelity)
/// requests a block wants evaluated, plus the block-private
/// bookkeeping needed to commit the results. Produced by
/// [`BuildingBlock::propose`], consumed by [`BuildingBlock::observe`];
/// the caller owns scheduling in between (typically concatenating
/// several proposals into one [`Objective::evaluate_batch`] call).
pub struct Proposal {
    /// (full config, fidelity) requests, in proposal order.
    pub reqs: Vec<(Config, f64)>,
    payload: Payload,
}

enum Payload {
    /// Nothing to commit.
    Empty,
    /// Single-fidelity joint engines: the subspace configs behind
    /// `reqs` (same order).
    Joint(Vec<Config>),
    /// Multi-fidelity joint engine: subspace (config, fidelity) picks.
    JointMf(Vec<(Config, f64)>),
    /// Alternating block: which side proposed (and whether this was a
    /// warmup half); the side's own payload rides along and is handed
    /// back down with the shared `reqs`.
    Alt { first: bool, warmup: bool, inner: Box<Payload> },
    /// Conditioning block proposing as a *child*: one chunk of its own
    /// elimination round — `(arm index, request count, arm payload)`
    /// per pull, in pull order, plus whether this chunk completes the
    /// round (elimination runs when it is observed).
    Cond { pulls: Vec<(usize, usize, Payload)>, ends_round: bool },
}

impl Proposal {
    pub fn empty() -> Proposal {
        Proposal { reqs: Vec::new(), payload: Payload::Empty }
    }

    fn joint(fixed: &Config, subs: Vec<Config>) -> Proposal {
        let reqs = subs.iter().map(|s| (fixed.merged(s), 1.0)).collect();
        Proposal { reqs, payload: Payload::Joint(subs) }
    }

    fn joint_mf(fixed: &Config, picks: Vec<(Config, f64)>) -> Proposal {
        let reqs = picks
            .iter()
            .map(|(s, fid)| (fixed.merged(s), *fid))
            .collect();
        Proposal { reqs, payload: Payload::JointMf(picks) }
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }
}

pub trait BuildingBlock {
    fn name(&self) -> String;
    /// One Volcano-style iteration (recursively invokes children).
    fn do_next(&mut self, env: &mut Env) -> Result<()>;
    /// True when this block can split a pull into
    /// [`propose`](Self::propose) / [`observe`](Self::observe) —
    /// required for a parent to gather it into a cross-leaf
    /// super-batch.
    fn supports_propose(&self) -> bool {
        false
    }
    /// First half of a split pull: plan up to `env.batch` candidate
    /// requests *without* evaluating them. Implementations must not
    /// touch `env.obj` (the parent owns scheduling), so the planned
    /// requests depend only on the rng and block state.
    ///
    /// The default **errors**: a block advertising
    /// [`supports_propose`](Self::supports_propose) must override it.
    /// (It used to return `Proposal::empty()`, which made a forgotten
    /// override yield zero-request pulls that burned rounds without
    /// ever evaluating anything.)
    fn propose(&mut self, _env: &mut Env) -> Result<Proposal> {
        anyhow::bail!(
            "{}: propose() is not implemented — supports_propose() \
             must return false for this block (a silently empty \
             proposal would burn pulls without evaluating anything)",
            self.name())
    }
    /// True when one [`propose`](Self::propose) call covers exactly
    /// the work of one serial [`do_next`](Self::do_next) — the
    /// condition under which a parent's chunk-of-one gathering is
    /// bit-identical to the plain round-robin, letting the unified
    /// scheduler absorb the serial path at the default knobs. Leaf
    /// blocks are pull-granular; an alternating block in warmup
    /// proposes one *half* per pull (its `do_next` plays both), and a
    /// conditioning block proposes one *chunk* of its round (its
    /// `do_next` plays the whole round), so both report false there.
    fn pull_granular(&self) -> bool {
        true
    }
    /// Re-filter a previously planned (but not yet evaluated) pull
    /// against *current* block state. A pull buffered in a parent's
    /// speculation window can outlive a decision that invalidates
    /// part of it: an inner conditioning block eliminates an arm
    /// while the pull waits, and the eliminated arm's requests would
    /// be evaluated only for their observations to be dropped.
    /// Parents call `revise` on every buffered pull just before
    /// submission, so those requests are filtered out — never
    /// evaluated, never charged. Implementations must keep the
    /// proposal's bookkeeping consistent with the surviving requests
    /// (an [`observe`](Self::observe) of the revised proposal commits
    /// exactly them). The default keeps the proposal unchanged —
    /// leaf blocks cannot invalidate their own plans between propose
    /// and evaluate. At `pipeline_depth` 1 nothing is ever buffered
    /// across a decision point, so `revise` is the identity there
    /// and default-knob trajectories are untouched.
    fn revise(&mut self, prop: Proposal) -> Proposal {
        prop
    }
    /// Second half: commit the utilities of a **prefix** of the
    /// proposal's requests (`ys` shorter than `prop.reqs` means the
    /// evaluation budget ran out mid-batch; only the prefix is
    /// observed, mirroring [`Objective::evaluate_batch`]).
    fn observe(&mut self, _prop: Proposal, _ys: &[f64]) {}
    /// Best (full config, utility) observed in this subtree.
    fn current_best(&self) -> Option<(Config, f64)>;
    /// Expected-utility interval after `k` more iterations
    /// (rising-bandit bounds, see §3.3.2 / [53]).
    fn get_eu(&self, k: f64) -> (f64, f64);
    /// Expected utility improvement (mean of observed improvements,
    /// Levine et al. rotting bandits).
    fn get_eui(&self) -> f64;
    /// Fix variables of the *enclosing* decomposition (paper's
    /// `set_var`): merged into every future evaluation.
    fn set_var(&mut self, fixed: &Config);
    fn n_evals(&self) -> usize;
    /// Number of live arms (1 for non-conditioning blocks) — drives
    /// the Fig 12 active-arm trend.
    fn active_children(&self) -> usize {
        1
    }
    /// All (full config, utility) observations in this subtree, in
    /// evaluation order (feeds the ensemble and meta-corpus).
    fn observations(&self) -> Vec<(Config, f64)>;
    /// Downcasting hook (continue-tuning drivers need the concrete
    /// ConditioningBlock to extend its arms, §3.3.6).
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

// ====================================================================
// Joint block
// ====================================================================

/// Which engine a joint block runs (§3.3.1: vanilla BO by default;
/// VolcanoML+ uses MFES-HB; random is a testing baseline).
pub enum JointEngine {
    Bo(SmacBo),
    Random(RandomSearch),
    /// TPOT-style evolutionary search (genetic pipeline optimizer).
    Evo(Evolutionary),
    Mf(HyperbandFamily),
}

pub struct JointBlock {
    pub label: String,
    space: ConfigSpace,
    fixed: Config,
    engine: JointEngine,
    /// (full config, utility) in evaluation order.
    history: Vec<(Config, f64)>,
    /// best-so-far curve (same length as history).
    best_curve: Vec<f64>,
}

impl JointBlock {
    pub fn bo(label: &str, space: ConfigSpace, fixed: Config, seed: u64)
        -> JointBlock {
        let engine = JointEngine::Bo(SmacBo::new(space.clone(), seed));
        JointBlock::with_engine(label, space, fixed, engine)
    }

    pub fn with_engine(label: &str, space: ConfigSpace, fixed: Config,
                       engine: JointEngine) -> JointBlock {
        JointBlock {
            label: label.to_string(),
            space,
            fixed,
            engine,
            history: Vec::new(),
            best_curve: Vec::new(),
        }
    }

    pub fn space(&self) -> &ConfigSpace {
        &self.space
    }

    fn record(&mut self, full: Config, y: f64) {
        let prev = self.best_curve.last().copied()
            .unwrap_or(f64::NEG_INFINITY);
        self.best_curve.push(prev.max(y));
        self.history.push((full, y));
    }
}

impl BuildingBlock for JointBlock {
    fn name(&self) -> String {
        format!("joint[{}]", self.label)
    }

    fn do_next(&mut self, env: &mut Env) -> Result<()> {
        if env.obj.exhausted() {
            return Ok(());
        }
        // a leaf pull is propose -> evaluate -> observe; parents doing
        // cross-leaf super-batching call the two halves directly and
        // schedule the evaluation themselves
        let prop = self.propose(env)?;
        let ys = env.obj.evaluate_batch(&prop.reqs)?;
        self.observe(prop, &ys);
        Ok(())
    }

    fn supports_propose(&self) -> bool {
        true
    }

    fn propose(&mut self, env: &mut Env) -> Result<Proposal> {
        let k = env.batch.max(1);
        Ok(match &mut self.engine {
            JointEngine::Bo(bo) => {
                Proposal::joint(&self.fixed, bo.suggest_batch(env.rng, k))
            }
            JointEngine::Random(rs) => {
                Proposal::joint(&self.fixed, rs.suggest_batch(env.rng, k))
            }
            JointEngine::Evo(ev) => {
                Proposal::joint(&self.fixed, ev.suggest_batch(env.rng, k))
            }
            JointEngine::Mf(mf) => {
                Proposal::joint_mf(&self.fixed,
                                   mf.suggest_batch(env.rng, k))
            }
        })
    }

    fn observe(&mut self, prop: Proposal, ys: &[f64]) {
        let Proposal { reqs, payload } = prop;
        // (full config, utility, counts toward the best curve);
        // observations are applied in proposal order, so reward
        // updates are independent of how the objective scheduled the
        // evaluations. `ys` may be a prefix of the requests (budget
        // exhaustion): the zips below observe exactly that prefix.
        let mut recs: Vec<(Config, f64, bool)> =
            Vec::with_capacity(ys.len());
        match (payload, &mut self.engine) {
            (Payload::Joint(subs), JointEngine::Bo(bo)) => {
                for ((sub, (full, _)), &y) in
                    subs.into_iter().zip(reqs).zip(ys) {
                    bo.observe(sub, y);
                    recs.push((full, y, true));
                }
            }
            (Payload::Joint(subs), JointEngine::Random(rs)) => {
                for ((sub, (full, _)), &y) in
                    subs.into_iter().zip(reqs).zip(ys) {
                    rs.observe(sub, y);
                    recs.push((full, y, true));
                }
            }
            (Payload::Joint(subs), JointEngine::Evo(ev)) => {
                for ((sub, (full, _)), &y) in
                    subs.into_iter().zip(reqs).zip(ys) {
                    ev.observe(sub, y);
                    recs.push((full, y, true));
                }
            }
            (Payload::JointMf(picks), JointEngine::Mf(mf)) => {
                for (((sub, fid), (full, _)), &y) in
                    picks.into_iter().zip(reqs).zip(ys) {
                    mf.observe(sub, fid, y);
                    // only count full-fidelity results toward the best
                    recs.push((full, y, fid >= 1.0));
                }
            }
            _ => debug_assert!(false, "proposal/engine mismatch"),
        }
        for (full, y, counts) in recs {
            if counts {
                self.record(full, y);
            } else {
                let prev = self.best_curve.last().copied()
                    .unwrap_or(f64::NEG_INFINITY);
                self.best_curve.push(prev);
                self.history.push((full, f64::NEG_INFINITY.max(y)));
                // history keeps the low-fidelity value for the record
                // but best_curve ignores it
            }
        }
    }

    fn current_best(&self) -> Option<(Config, f64)> {
        let (mut best, mut by) = (None, f64::NEG_INFINITY);
        for (i, (cfg, y)) in self.history.iter().enumerate() {
            // skip low-fidelity entries (best_curve didn't move and y
            // below it)
            let curve = self.best_curve[i];
            if *y >= curve - 1e-12 && *y > by {
                by = *y;
                best = Some(cfg.clone());
            }
        }
        best.map(|c| (c, by))
    }

    fn get_eu(&self, k: f64) -> (f64, f64) {
        let n = self.best_curve.len();
        if n == 0 {
            return (f64::NEG_INFINITY, f64::INFINITY);
        }
        let best = self.best_curve[n - 1];
        // rising-bandit extrapolation: recent per-iteration gain rate
        let w = 10.min(n - 1).max(1);
        let gain = if n > 1 {
            ((self.best_curve[n - 1] - self.best_curve[n - 1 - w])
                / w as f64)
                .max(0.0)
        } else {
            f64::INFINITY
        };
        // `best + inf * 0.0` is NaN (one observation, zero lookahead):
        // keep the interval well-defined for every (n, k)
        let upper = if k <= 0.0 {
            best
        } else if gain.is_infinite() {
            f64::INFINITY
        } else {
            best + gain * k
        };
        (best, upper)
    }

    fn get_eui(&self) -> f64 {
        let n = self.best_curve.len();
        if n < 2 {
            return f64::INFINITY; // unexplored blocks are promising
        }
        // mean of observed improvements (rotting-bandit estimate)
        let mut imps = Vec::with_capacity(n - 1);
        for i in 1..n {
            imps.push(self.best_curve[i] - self.best_curve[i - 1]);
        }
        crate::util::stats::mean(&imps)
    }

    fn set_var(&mut self, fixed: &Config) {
        self.fixed = self.fixed.merged(fixed);
    }

    fn n_evals(&self) -> usize {
        self.history.len()
    }

    fn observations(&self) -> Vec<(Config, f64)> {
        self.history.clone()
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

// ====================================================================
// Conditioning block (Algorithm 1 + rising-bandit elimination)
// ====================================================================

pub struct Arm {
    pub value: String,
    pub block: Box<dyn BuildingBlock>,
    pub active: bool,
}

/// One speculatively proposed chunk: `(arm index, proposal)` pairs in
/// pull order. Buffered in [`ConditioningBlock`] until its turn to be
/// evaluated, reconciled against eliminations at every round
/// boundary, and discarded unevaluated if the budget dies first.
type SpecChunk = Vec<(usize, Proposal)>;

/// Parent-driven round bookkeeping: when a [`ConditioningBlock`] is a
/// *child* of a gathering parent, each [`BuildingBlock::propose`]
/// call covers one chunk of this block's own elimination round; the
/// pull schedule of the round currently being proposed and the cursor
/// into it live here between calls. `None` between rounds.
struct ExtRound {
    sched: Vec<usize>,
    cursor: usize,
}

/// The `Env` knobs a speculative proposal still needs (everything but
/// the objective, which speculation must not touch).
#[derive(Clone, Copy)]
struct PullKnobs {
    batch: usize,
    super_batch: usize,
    pipeline_depth: usize,
}

/// Plan one pull of `arm` for the speculative pipeline: the proposal
/// may only depend on rng and block state, so the environment carries
/// a [`SpeculationGuard`] instead of the (mid-batch) real objective.
fn propose_pull(arm: &mut Arm, rng: &mut Rng, knobs: PullKnobs)
    -> Result<Proposal> {
    let mut guard = SpeculationGuard;
    let mut env = Env {
        obj: &mut guard,
        rng,
        batch: knobs.batch,
        super_batch: knobs.super_batch,
        pipeline_depth: knobs.pipeline_depth,
    };
    arm.block.propose(&mut env)
}

/// Propose the next chunk of the (conceptually infinite) pull stream
/// `full[g % full.len()]` starting at `cursor`: up to `chunk` pulls,
/// never crossing the next round boundary (elimination runs between
/// rounds). Returns the new cursor and the planned chunk. Shared by
/// the pipelined loop's synchronous fallback and its speculation
/// window, so the round-capping arithmetic cannot diverge between
/// them.
fn propose_chunk(arms: &mut [Arm], rng: &mut Rng, full: &[usize],
                 cursor: usize, chunk: usize, knobs: PullKnobs)
    -> Result<(usize, SpecChunk)> {
    let n = full.len();
    let round_end = ((cursor / n) + 1) * n;
    let end = (cursor + chunk).min(round_end);
    let mut c: SpecChunk = Vec::with_capacity(end - cursor);
    for g in cursor..end {
        let ai = full[g % n];
        c.push((ai, propose_pull(&mut arms[ai], rng, knobs)?));
    }
    Ok((end, c))
}

pub struct ConditioningBlock {
    pub var: String,
    pub arms: Vec<Arm>,
    /// Times each arm is played per do_next (paper: L = 5).
    pub plays_per_round: usize,
    /// Lookahead (in iterations) used for the EU interval.
    pub eu_lookahead: f64,
    /// Disable elimination (ablation flag).
    pub eliminate: bool,
    /// Minimum evaluations an arm must receive before it can be
    /// eliminated — guards freshly added (continue-tuning) arms whose
    /// EU interval is still over-pessimistic (§3.3.2 Remark).
    pub elimination_grace: usize,
    rounds: usize,
    /// Speculative-proposal buffer (async pipeline depth): chunks
    /// proposed ahead of the currently evaluating one, each tagged
    /// with how many *round boundaries* ahead it lies (0 = the round
    /// being played). Reconciled after every elimination (tags
    /// decrement, pulls of eliminated arms are dropped) and cleared
    /// whenever a round is abandoned — buffered proposals are never
    /// evaluated or charged once the budget is gone.
    spec: VecDeque<(usize, SpecChunk)>,
    /// Round-in-progress state for the parent-driven propose/observe
    /// path (this block as a child of a gathering parent).
    ext: Option<ExtRound>,
}

impl ConditioningBlock {
    pub fn new(var: &str, arms: Vec<Arm>) -> ConditioningBlock {
        ConditioningBlock {
            var: var.to_string(),
            arms,
            plays_per_round: 5,
            eu_lookahead: 10.0,
            eliminate: true,
            elimination_grace: 12,
            rounds: 0,
            spec: VecDeque::new(),
            ext: None,
        }
    }

    /// Continue-tuning (§3.3.6): extend the surviving candidate set
    /// with new arms; they join the round-robin immediately. Any
    /// speculatively proposed rounds are discarded — they were
    /// planned for the old arm set. (Like all discarded speculation
    /// this leaves the surviving arms' proposal bookkeeping advanced
    /// — deterministically — by the dropped pulls; drivers that mix
    /// continue-tuning with `pipeline_depth > 1` accept that shift,
    /// and depth 1 is unaffected.)
    pub fn add_arms(&mut self, arms: Vec<Arm>) {
        self.spec.clear();
        self.ext = None;
        self.arms.extend(arms);
    }

    pub fn active_values(&self) -> Vec<String> {
        self.arms
            .iter()
            .filter(|a| a.active)
            .map(|a| a.value.clone())
            .collect()
    }

    /// The pull schedule of one elimination round: every active arm's
    /// index, `plays_per_round` times over. Shared by the self-driven
    /// scheduler ([`Self::do_next_pipelined`]) and the parent-driven
    /// propose path so the two can never disagree on round shape.
    fn round_sched(&self) -> Vec<usize> {
        let active: Vec<usize> = self
            .arms
            .iter()
            .enumerate()
            .filter(|(_, a)| a.active)
            .map(|(i, _)| i)
            .collect();
        let mut sched: Vec<usize> =
            Vec::with_capacity(active.len() * self.plays_per_round);
        for _ in 0..self.plays_per_round {
            sched.extend(&active);
        }
        sched
    }

    /// Driver hook: run one round through the unified scheduler with
    /// an explicit chunk size (bypassing `Env::super_batch` at *this*
    /// level only — nested arms still size their own chunks from
    /// `Env::super_batch`, the knob that recurses) at the
    /// environment's pipeline depth. This is
    /// [`Self::do_next_pipelined`] at `Env::pipeline_depth` — depth 1
    /// is the synchronous gather (the pipelined loop with an empty
    /// speculation window), whose chunk-1 form is bit-identical to
    /// the plain `do_next` round-robin when every arm is
    /// pull-granular (property-tested in `tests/super_batch.rs` and
    /// `tests/async_depth.rs`; see [`BuildingBlock::pull_granular`]
    /// for the alternating-warmup and nested-conditioning caveats).
    pub fn do_next_gathered(&mut self, env: &mut Env, chunk: usize)
        -> Result<()> {
        let depth = env.pipeline_depth.max(1);
        self.do_next_pipelined(env, chunk, depth)
    }

    /// The unified round scheduler: play one elimination round with
    /// an explicit chunk size and pipeline depth (bypassing the `Env`
    /// knobs). Every round — synchronous or speculative, at every
    /// decomposition level — runs through this one loop. `depth == 1`
    /// is the synchronous gather: the pipelined loop with an empty
    /// speculation window proposes, evaluates and observes exactly
    /// like the former `gather_round` (pinned bit for bit by
    /// `tests/async_depth.rs`, which let that duplicate path be
    /// deleted). `depth > 1` keeps up to `depth - 1` chunks proposed
    /// ahead of the one in flight, spilling across round boundaries;
    /// the speculation is reconciled against eliminations when the
    /// round's observations land and discarded — never evaluated,
    /// never charged — when the budget dies first.
    pub fn do_next_pipelined(&mut self, env: &mut Env, chunk: usize,
                             depth: usize) -> Result<()> {
        // self-driven rounds invalidate any parent-driven bookkeeping
        self.ext = None;
        let window = depth.max(1) - 1;
        if window == 0 {
            // synchronous rounds never consume speculation: drop any
            // buffer left over from a depth change between pulls
            self.spec.clear();
        }
        self.rounds += 1;
        let _round = crate::obs::span!("round", "elimination_round",
                                       "round" => self.rounds,
                                       "depth" => depth);
        if !self.pipelined_round(env, chunk, window)? {
            // round abandoned at a chunk boundary: elimination is
            // skipped, exactly like the synchronous gather path
            crate::obs::event!("round", "abandoned",
                               "round" => self.rounds);
            return Ok(());
        }
        if self.eliminate {
            let before =
                self.arms.iter().filter(|a| a.active).count();
            self.eliminate_dominated();
            let after =
                self.arms.iter().filter(|a| a.active).count();
            crate::obs::event!("round", "eliminate",
                               "active_before" => before,
                               "active_after" => after);
        }
        self.reconcile_spec();
        Ok(())
    }

    /// Play one elimination round with a speculation window of
    /// `window` chunks: while a chunk is in flight (inside
    /// [`Objective::evaluate_batch_overlapped`]) the submitting
    /// thread proposes ahead — first the rest of this round, then
    /// speculatively into future rounds — so surrogate refits and
    /// acquisition optimisation run off the evaluation hot path.
    /// Returns false when the budget is exhausted at a chunk
    /// boundary (round abandoned; all speculation discarded, exactly
    /// like the serial loop's early return at its pull boundaries),
    /// true when the round completed — possibly truncated inside its
    /// final chunk, like the serial loop when the budget dies in its
    /// last pull; elimination then still runs, with the elimination
    /// grace applying as usual.
    fn pipelined_round(&mut self, env: &mut Env, chunk: usize,
                       window: usize) -> Result<bool> {
        let Env { obj, rng, batch, super_batch, pipeline_depth } = env;
        let knobs = PullKnobs {
            batch: *batch,
            super_batch: *super_batch,
            pipeline_depth: *pipeline_depth,
        };
        let full = self.round_sched();
        let arms = &mut self.arms;
        let spec = &mut self.spec;
        let n = full.len();
        if n == 0 {
            spec.clear();
            return Ok(true);
        }
        let chunk = if chunk == 0 { n } else { chunk };
        // The buffer covers a prefix of the pull stream (this round
        // first, then future rounds): reconciliation preserves that —
        // filtering eliminated arms out of a prefix of the old round
        // yields exactly a prefix of the new one — so the proposal
        // cursor resumes right after everything already proposed.
        let mut cursor: usize = spec.iter().map(|(_, c)| c.len()).sum();
        // chunks already proposed for *this* round
        let mut ready: VecDeque<SpecChunk> = VecDeque::new();
        while matches!(spec.front(), Some((0, _))) {
            ready.push_back(spec.pop_front().expect("front checked").1);
        }
        let mut spec_err: Option<anyhow::Error> = None;
        let mut done = 0usize; // pulls of this round observed
        while done < n {
            if obj.exhausted() {
                // budget died at a chunk boundary: abandon the round
                // and discard every speculative proposal unevaluated
                spec.clear();
                return Ok(false);
            }
            let cur: SpecChunk = match ready.pop_front() {
                Some(c) => c,
                None => {
                    // nothing buffered: propose the next chunk now
                    // (this is the whole loop when the window is 0 —
                    // the synchronous gather semantics; the cursor is
                    // always inside round 0 here, so the helper's
                    // round cap reduces to `n`)
                    let _p = crate::obs::span!("chunk", "propose",
                                               "cursor" => cursor);
                    let (end, c) = propose_chunk(arms, &mut **rng,
                                                 &full, cursor, chunk,
                                                 knobs)?;
                    cursor = end;
                    c
                }
            };
            // revise buffered pulls against current state: a nested
            // arm may have eliminated inner arms while this chunk sat
            // in the speculation window — their requests are filtered
            // out here instead of being evaluated for observations
            // the observe would drop. Freshly proposed chunks (and
            // everything at window 0) revise to themselves, keeping
            // the synchronous path bit-identical.
            let cur: SpecChunk = cur
                .into_iter()
                .map(|(ai, p)| {
                    let p = arms[ai].block.revise(p);
                    (ai, p)
                })
                .collect();
            if cur.is_empty() {
                // Defensive guard, unreachable today: reconcile_spec
                // prunes emptied chunks and the propose branch always
                // covers >= 1 pull. If a future change lets an empty
                // chunk through, skipping it (it counts toward
                // neither `done` nor the round length) beats the
                // alternative — a zero-progress iteration that would
                // spin this loop forever.
                continue;
            }
            let mut reqs: Vec<(Config, f64)> = Vec::new();
            for (_, p) in &cur {
                reqs.extend_from_slice(&p.reqs);
            }
            // While this chunk is in flight, top the speculation
            // window back up: the rest of this round first, then
            // future rounds (tagged with their distance so the round
            // boundary — elimination — is honoured when they play).
            let ys = obj.evaluate_batch_overlapped(&reqs, &mut || {
                let _s = crate::obs::span!("chunk", "speculate",
                                           "cursor" => cursor);
                while spec_err.is_none()
                    && ready.len() + spec.len() < window
                {
                    let round = cursor / n;
                    match propose_chunk(arms, &mut **rng, &full,
                                        cursor, chunk, knobs) {
                        Ok((end, c)) => {
                            if round == 0 {
                                ready.push_back(c);
                            } else {
                                spec.push_back((round, c));
                            }
                            cursor = end;
                        }
                        Err(e) => {
                            spec_err = Some(e);
                            return;
                        }
                    }
                }
            })?;
            // commit in proposal order; each arm observes the prefix
            // of its slice that the budget allowed (possibly empty)
            let _c = crate::obs::span!("chunk", "commit",
                                       "pulls" => reqs.len());
            let mut off = 0;
            for (ai, p) in cur {
                let m = p.reqs.len();
                let lo = off.min(ys.len());
                let hi = (off + m).min(ys.len());
                arms[ai].block.observe(p, &ys[lo..hi]);
                off += m;
                done += 1;
            }
            if let Some(e) = spec_err.take() {
                return Err(e);
            }
        }
        Ok(true)
    }

    /// Round-boundary reconciliation of the speculative buffer: every
    /// chunk moves one round closer to play, and proposals of arms
    /// eliminated this round are dropped — never evaluated, never
    /// charged. Discarding is deterministic but not side-effect-free:
    /// proposing advanced the arm's rng and any stateful proposal
    /// bookkeeping (an alternating arm's warmup/toggle schedule, a
    /// Hyperband engine's rung queue). That is part of the
    /// depth-`d > 1` semantics — an eliminated arm never plays again,
    /// and for any fixed depth the effect is identical on every run.
    /// Chunks emptied entirely are pruned.
    fn reconcile_spec(&mut self) {
        let arms = &self.arms;
        for (delta, chunk) in self.spec.iter_mut() {
            *delta = delta.saturating_sub(1);
            chunk.retain(|(ai, _)| arms[*ai].active);
        }
        self.spec.retain(|(_, c)| !c.is_empty());
    }

    /// Lines 5-7 of Algorithm 1: deactivate arms whose EU upper bound
    /// is dominated by the best lower bound (with the grace period),
    /// never eliminating everything.
    fn eliminate_dominated(&mut self) {
        let bounds: Vec<Option<(f64, f64)>> = self
            .arms
            .iter()
            .map(|a| {
                if a.active {
                    Some(a.block.get_eu(self.eu_lookahead))
                } else {
                    None
                }
            })
            .collect();
        let max_lower = bounds
            .iter()
            .flatten()
            .map(|(l, _)| *l)
            .fold(f64::NEG_INFINITY, f64::max);
        let grace = self.elimination_grace;
        for (arm, b) in self.arms.iter_mut().zip(&bounds) {
            if let Some((_, u)) = b {
                if *u < max_lower && arm.block.n_evals() >= grace {
                    arm.active = false;
                }
            }
        }
        // never eliminate everything
        if self.arms.iter().all(|a| !a.active) {
            if let Some(best) = self
                .arms
                .iter_mut()
                .max_by(|a, b| {
                    let ya = a.block.current_best()
                        .map(|(_, y)| y).unwrap_or(f64::NEG_INFINITY);
                    let yb = b.block.current_best()
                        .map(|(_, y)| y).unwrap_or(f64::NEG_INFINITY);
                    ya.partial_cmp(&yb)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
            {
                best.active = true;
            }
        }
    }
}

impl BuildingBlock for ConditioningBlock {
    fn name(&self) -> String {
        format!("conditioning[{}]", self.var)
    }

    fn do_next(&mut self, env: &mut Env) -> Result<()> {
        // Unified scheduler: route the round through the pipelined
        // loop whenever every active arm can split its pull — always
        // when super-batching or pipelining is on (the knobs'
        // documented semantic shifts apply; elimination rounds then
        // parallelise across arms, recursively through nested
        // blocks), and at the default knobs whenever chunk-of-one
        // gathering is bit-identical to the plain round-robin (every
        // active arm pull-granular). The serial loop below survives
        // only for the granularity fallbacks: an alternating arm in
        // warmup (a pull is one half, not a full round-robin pass)
        // and a nested conditioning arm at default knobs (a pull is
        // one chunk, not a whole inner round).
        let any_active = self.arms.iter().any(|a| a.active);
        let all_propose = self
            .arms
            .iter()
            .filter(|a| a.active)
            .all(|a| a.block.supports_propose());
        let all_granular = self
            .arms
            .iter()
            .filter(|a| a.active)
            .all(|a| a.block.pull_granular());
        if any_active
            && all_propose
            && (env.super_batch != 1
                || env.pipeline_depth > 1
                || all_granular)
        {
            let chunk = env.super_batch;
            return self.do_next_gathered(env, chunk);
        }
        // the plain round-robin never consumes speculation, and
        // invalidates any parent-driven round bookkeeping
        self.spec.clear();
        self.ext = None;
        self.rounds += 1;
        // lines 2-4: play each active arm L times (round-robin); with
        // super-batching off each arm pull is its own batch
        for _ in 0..self.plays_per_round {
            for arm in self.arms.iter_mut().filter(|a| a.active) {
                if env.obj.exhausted() {
                    return Ok(());
                }
                arm.block.do_next(env)?;
            }
        }
        // lines 5-7: eliminate arms dominated under the EU intervals
        if self.eliminate {
            self.eliminate_dominated();
        }
        Ok(())
    }

    fn supports_propose(&self) -> bool {
        // total over the block algebra: a conditioning block can
        // split its pull whenever every active arm can — which makes
        // nested conditioning/alternating plans gatherable by their
        // parents instead of forcing the serial fallback
        self.arms
            .iter()
            .filter(|a| a.active)
            .all(|a| a.block.supports_propose())
    }

    fn pull_granular(&self) -> bool {
        // one propose is one chunk of a round; one do_next is a whole
        // round plus elimination — never the same granularity
        false
    }

    /// One parent-level pull = one chunk (`env.super_batch` pulls;
    /// 0 = the whole round) of this block's own elimination round,
    /// recursively proposed from the arms. Round bookkeeping rides in
    /// the payload: the chunk that completes the round is marked, and
    /// [`observe`](Self::observe) runs elimination there. A parent
    /// proposing ahead of its observations (speculation) makes this
    /// block plan future rounds against the pre-elimination arm set —
    /// the same cross-round speculation semantics as the block's own
    /// pipeline, reconciled when the observations land.
    fn propose(&mut self, env: &mut Env) -> Result<Proposal> {
        if self.ext.is_none() {
            let sched = self.round_sched();
            if sched.is_empty() {
                // no active arms: nothing to pull, nothing to commit
                return Ok(Proposal::empty());
            }
            self.ext = Some(ExtRound { sched, cursor: 0 });
        }
        let (pull_idx, ends_round) = {
            let ext = self.ext.as_mut().expect("ensured above");
            let n = ext.sched.len();
            let chunk = if env.super_batch == 0 {
                n
            } else {
                env.super_batch.max(1)
            };
            // When elimination pruned away the entire unproposed tail
            // of a speculated round (observe's ext reconciliation),
            // the cursor already sits at the schedule's end: this
            // emits a zero-pull chunk that still carries the
            // `ends_round` marker, so the round's elimination runs at
            // its true boundary — one empty parent pull, by design.
            let end = (ext.cursor + chunk).min(n);
            let idx = ext.sched[ext.cursor..end].to_vec();
            ext.cursor = end;
            (idx, end >= n)
        };
        if ends_round {
            self.ext = None;
        }
        let mut pulls: Vec<(usize, usize, Payload)> =
            Vec::with_capacity(pull_idx.len());
        let mut reqs: Vec<(Config, f64)> = Vec::new();
        for ai in pull_idx {
            let p = self.arms[ai].block.propose(env)?;
            pulls.push((ai, p.reqs.len(), p.payload));
            reqs.extend(p.reqs);
        }
        Ok(Proposal {
            reqs,
            payload: Payload::Cond { pulls, ends_round },
        })
    }

    /// Commit a chunk's utilities back to the arms in pull order
    /// (each arm observes the prefix of its slice the budget
    /// allowed), run elimination when the chunk completes a round,
    /// and reconcile any buffered speculation. Pulls whose arm was
    /// eliminated while they waited (the parent speculated past this
    /// block's round boundary) are dropped — an eliminated arm never
    /// observes again, mirroring [`Self::reconcile_spec`].
    fn observe(&mut self, prop: Proposal, ys: &[f64]) {
        let Proposal { reqs, payload } = prop;
        let (pulls, ends_round) = match payload {
            Payload::Cond { pulls, ends_round } => (pulls, ends_round),
            // the zero-active-arm propose hands out an empty proposal
            Payload::Empty => return,
            _ => {
                debug_assert!(false, "proposal/block mismatch");
                return;
            }
        };
        let mut reqs = reqs.into_iter();
        let mut off = 0usize;
        for (ai, len, inner) in pulls {
            let sub_reqs: Vec<(Config, f64)> =
                reqs.by_ref().take(len).collect();
            let lo = off.min(ys.len());
            let hi = (off + len).min(ys.len());
            off += len;
            if !self.arms[ai].active {
                continue;
            }
            self.arms[ai].block.observe(
                Proposal { reqs: sub_reqs, payload: inner },
                &ys[lo..hi]);
        }
        if ends_round {
            self.rounds += 1;
            if self.eliminate {
                self.eliminate_dominated();
            }
            self.reconcile_spec();
            // reconcile the parent-driven schedule too: a parent
            // proposing ahead may already hold a later round's
            // cursor; pulls of freshly eliminated arms that have NOT
            // been proposed yet are dropped from that round's
            // remaining schedule — never proposed, never evaluated,
            // never charged. (Pulls already proposed sit in the
            // parent's buffer out of reach; their observations are
            // dropped by the active check above.)
            if let Some(ext) = self.ext.as_mut() {
                let arms = &self.arms;
                let cursor = ext.cursor.min(ext.sched.len());
                let mut kept = ext.sched[..cursor].to_vec();
                kept.extend(
                    ext.sched[cursor..]
                        .iter()
                        .copied()
                        .filter(|&ai| arms[ai].active));
                ext.sched = kept;
            }
        }
    }

    /// Drop the requests of arms eliminated since this pull was
    /// planned, recursing into the surviving arms (a nested block may
    /// have eliminated *its* arms too). Emptied pulls keep their slot
    /// — round bookkeeping (`ends_round`, the parent's pull count)
    /// must survive revision — but carry zero requests, so the dead
    /// work is never submitted. Mirrors the observation-drop in
    /// [`Self::observe`], one step earlier in the pipeline.
    fn revise(&mut self, prop: Proposal) -> Proposal {
        let Proposal { reqs, payload } = prop;
        let (pulls, ends_round) = match payload {
            Payload::Cond { pulls, ends_round } => (pulls, ends_round),
            other => return Proposal { reqs, payload: other },
        };
        let mut reqs = reqs.into_iter();
        let mut out_reqs: Vec<(Config, f64)> = Vec::new();
        let mut out_pulls: Vec<(usize, usize, Payload)> =
            Vec::with_capacity(pulls.len());
        for (ai, len, inner) in pulls {
            let sub: Vec<(Config, f64)> =
                reqs.by_ref().take(len).collect();
            if !self.arms[ai].active {
                // eliminated while buffered: keep the pull slot,
                // submit nothing for it
                out_pulls.push((ai, 0, Payload::Empty));
                continue;
            }
            let revised = self.arms[ai].block.revise(Proposal {
                reqs: sub,
                payload: inner,
            });
            let Proposal { reqs: sub, payload: inner } = revised;
            out_pulls.push((ai, sub.len(), inner));
            out_reqs.extend(sub);
        }
        Proposal {
            reqs: out_reqs,
            payload: Payload::Cond { pulls: out_pulls, ends_round },
        }
    }

    fn current_best(&self) -> Option<(Config, f64)> {
        self.arms
            .iter()
            .filter_map(|a| a.block.current_best())
            .max_by(|a, b| a.1.partial_cmp(&b.1)
                .unwrap_or(std::cmp::Ordering::Equal))
    }

    fn get_eu(&self, k: f64) -> (f64, f64) {
        let span = |active_only: bool| -> Option<(f64, f64)> {
            let mut any = false;
            let mut lo = f64::NEG_INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for a in self.arms.iter()
                .filter(|a| a.active || !active_only) {
                let (l, u) = a.block.get_eu(k);
                lo = lo.max(l);
                hi = hi.max(u);
                any = true;
            }
            any.then_some((lo, hi))
        };
        // with zero active arms a (-inf, -inf) interval would silently
        // dominate nothing in the rising-bandit comparison: fall back
        // to the inactive arms' evidence, and to the unexplored
        // interval when there are no arms at all
        span(true)
            .or_else(|| span(false))
            .unwrap_or((f64::NEG_INFINITY, f64::INFINITY))
    }

    fn get_eui(&self) -> f64 {
        self.arms
            .iter()
            .filter(|a| a.active)
            .map(|a| a.block.get_eui())
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn set_var(&mut self, fixed: &Config) {
        for a in &mut self.arms {
            a.block.set_var(fixed);
        }
    }

    fn n_evals(&self) -> usize {
        self.arms.iter().map(|a| a.block.n_evals()).sum()
    }

    fn active_children(&self) -> usize {
        self.arms.iter().filter(|a| a.active).count()
    }

    fn observations(&self) -> Vec<(Config, f64)> {
        let mut v = Vec::new();
        for a in &self.arms {
            v.extend(a.block.observations());
        }
        v
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

// ====================================================================
// Alternating block (Algorithms 2 + 3)
// ====================================================================

pub struct AlternatingBlock {
    pub b1: Box<dyn BuildingBlock>,
    pub b2: Box<dyn BuildingBlock>,
    /// The variable names each side owns (for set_var projection).
    vars1: Vec<String>,
    vars2: Vec<String>,
    /// Warmup rounds remaining (Algorithm 2's L round-robin rounds).
    warmup_left: usize,
    /// Split-pull bookkeeping: false = the next proposed warmup half
    /// plays b1, true = b2 (a warmup round is two halves).
    warmup_phase: bool,
    /// EUI-driven arm choice (Algorithm 3); round-robin if false
    /// (ablation of the design choice in §3.3.3).
    pub eui_driven: bool,
    toggle: bool,
}

impl AlternatingBlock {
    pub fn new(b1: Box<dyn BuildingBlock>, vars1: Vec<String>,
               b2: Box<dyn BuildingBlock>, vars2: Vec<String>)
        -> AlternatingBlock {
        AlternatingBlock {
            b1,
            b2,
            vars1,
            vars2,
            warmup_left: 3,
            warmup_phase: false,
            eui_driven: true,
            toggle: false,
        }
    }

    /// Project a full config onto the variables a side owns, to pass
    /// to the other side via set_var.
    fn project(cfg: &Config, vars: &[String]) -> Config {
        let mut out = Config::new();
        for (k, v) in cfg.iter() {
            if vars.iter().any(|p| k == p || k.starts_with(p)) {
                out.set(k, v.clone());
            }
        }
        out
    }

    fn exchange_to_b1(&mut self) {
        if let Some((cfg, _)) = self.b2.current_best() {
            let proj = Self::project(&cfg, &self.vars2);
            self.b1.set_var(&proj);
        }
    }

    fn exchange_to_b2(&mut self) {
        if let Some((cfg, _)) = self.b1.current_best() {
            let proj = Self::project(&cfg, &self.vars1);
            self.b2.set_var(&proj);
        }
    }
}

impl BuildingBlock for AlternatingBlock {
    fn name(&self) -> String {
        format!("alternating[{} | {}]", self.b1.name(), self.b2.name())
    }

    fn do_next(&mut self, env: &mut Env) -> Result<()> {
        // Self-driven iteration stays child.do_next-based: a nested
        // conditioning child (plan AC) then gathers — and pipelines —
        // its own full rounds through the unified scheduler, which a
        // one-chunk-per-pull parent-driven split could not. The
        // propose/observe pair below is the parent-driven path used
        // when *this* block sits under a gathering conditioning block
        // (plan CA, or any nested shape — split pulls are total over
        // the block algebra now).
        if env.obj.exhausted() {
            return Ok(());
        }
        if self.warmup_left > 0 {
            // Algorithm 2: one round-robin pass with set_var exchange
            self.b1.do_next(env)?;
            self.exchange_to_b2();
            self.b2.do_next(env)?;
            self.exchange_to_b1();
            self.warmup_left -= 1;
            return Ok(());
        }
        let play_first = if self.eui_driven {
            self.b1.get_eui() >= self.b2.get_eui()
        } else {
            self.toggle = !self.toggle;
            self.toggle
        };
        if play_first {
            // lines 4-6: fix z̄ to b2's best, then advance b1
            self.exchange_to_b1();
            self.b1.do_next(env)?;
        } else {
            // lines 8-10
            self.exchange_to_b2();
            self.b2.do_next(env)?;
        }
        Ok(())
    }

    fn supports_propose(&self) -> bool {
        self.b1.supports_propose() && self.b2.supports_propose()
    }

    fn pull_granular(&self) -> bool {
        // in warmup a propose covers one half where do_next plays
        // both; past warmup one propose plays exactly the side that
        // do_next would — granular iff the sides themselves are
        self.warmup_left == 0
            && self.b1.pull_granular()
            && self.b2.pull_granular()
    }

    fn propose(&mut self, env: &mut Env) -> Result<Proposal> {
        // Pick the side exactly as the serial iteration would; the
        // results-driven exchanges (`set_var` of the other side's
        // best) happen in `observe`, so under super-batching a side
        // proposes against the best known *at proposal time* — the
        // usual batched-BO staleness, never a torn state.
        let (first, warmup) = if self.warmup_left > 0 {
            let second_half = self.warmup_phase;
            self.warmup_phase = !second_half;
            if second_half {
                self.warmup_left -= 1;
            }
            (!second_half, true)
        } else if self.eui_driven {
            (self.b1.get_eui() >= self.b2.get_eui(), false)
        } else {
            self.toggle = !self.toggle;
            (self.toggle, false)
        };
        // outside warmup the exchange precedes the pull (Algorithm 3
        // lines 4-6 / 8-10); warmup exchanges follow the observations
        if !warmup {
            if first {
                self.exchange_to_b1();
            } else {
                self.exchange_to_b2();
            }
        }
        let inner = if first {
            self.b1.propose(env)?
        } else {
            self.b2.propose(env)?
        };
        let Proposal { reqs, payload } = inner;
        Ok(Proposal {
            reqs,
            payload: Payload::Alt { first, warmup,
                                    inner: Box::new(payload) },
        })
    }

    /// Delegate revision to the side that planned the pull (a nested
    /// conditioning side may have eliminated arms since).
    fn revise(&mut self, prop: Proposal) -> Proposal {
        let Proposal { reqs, payload } = prop;
        match payload {
            Payload::Alt { first, warmup, inner } => {
                let side =
                    if first { &mut self.b1 } else { &mut self.b2 };
                let revised = side.revise(Proposal {
                    reqs,
                    payload: *inner,
                });
                let Proposal { reqs, payload } = revised;
                Proposal {
                    reqs,
                    payload: Payload::Alt {
                        first,
                        warmup,
                        inner: Box::new(payload),
                    },
                }
            }
            other => Proposal { reqs, payload: other },
        }
    }

    fn observe(&mut self, prop: Proposal, ys: &[f64]) {
        let Proposal { reqs, payload } = prop;
        let Payload::Alt { first, warmup, inner } = payload else {
            debug_assert!(false, "proposal/block mismatch");
            return;
        };
        let inner = Proposal { reqs, payload: *inner };
        if first {
            self.b1.observe(inner, ys);
            if warmup {
                // Algorithm 2: push b1's fresh best into b2 before its
                // warmup half
                self.exchange_to_b2();
            }
        } else {
            self.b2.observe(inner, ys);
            if warmup {
                self.exchange_to_b1();
            }
        }
    }

    fn current_best(&self) -> Option<(Config, f64)> {
        [self.b1.current_best(), self.b2.current_best()]
            .into_iter()
            .flatten()
            .max_by(|a, b| a.1.partial_cmp(&b.1)
                .unwrap_or(std::cmp::Ordering::Equal))
    }

    fn get_eu(&self, k: f64) -> (f64, f64) {
        let (l1, u1) = self.b1.get_eu(k);
        let (l2, u2) = self.b2.get_eu(k);
        (l1.max(l2), u1.max(u2))
    }

    fn get_eui(&self) -> f64 {
        self.b1.get_eui().max(self.b2.get_eui())
    }

    fn set_var(&mut self, fixed: &Config) {
        self.b1.set_var(fixed);
        self.b2.set_var(fixed);
    }

    fn n_evals(&self) -> usize {
        self.b1.n_evals() + self.b2.n_evals()
    }

    fn observations(&self) -> Vec<(Config, f64)> {
        let mut v = self.b1.observations();
        v.extend(self.b2.observations());
        v
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::Value;

    /// Synthetic objective over {algorithm in a,b} x (x, y):
    /// algo 'a' peaks at 0.8 (x=0.9, y=0.1), algo 'b' caps at 0.4.
    struct Synth {
        evals: usize,
        max_evals: usize,
    }

    impl Objective for Synth {
        fn evaluate(&mut self, cfg: &Config, _f: f64) -> Result<f64> {
            self.evals += 1;
            let x = cfg.f64_or("x", 0.5);
            let y = cfg.f64_or("y", 0.5);
            Ok(match cfg.str_or("algorithm", "a") {
                "a" => 0.8 - (x - 0.9).powi(2) - (y - 0.1).powi(2),
                _ => 0.4 - 0.5 * (x - 0.5).powi(2),
            })
        }
        fn exhausted(&self) -> bool {
            self.evals >= self.max_evals
        }
    }

    fn xy_space() -> ConfigSpace {
        ConfigSpace::new()
            .float("x", 0.0, 1.0, 0.5)
            .float("y", 0.0, 1.0, 0.5)
    }

    fn joint_for(algo: &str, seed: u64) -> JointBlock {
        JointBlock::bo(
            &format!("hp[{algo}]"),
            xy_space(),
            Config::new().with("algorithm", Value::C(algo.into())),
            seed,
        )
    }

    #[test]
    fn joint_block_improves_and_tracks_best() {
        let mut obj = Synth { evals: 0, max_evals: 60 };
        let mut rng = Rng::new(0);
        let mut block = joint_for("a", 0);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..60 {
                block.do_next(&mut env).unwrap();
            }
        }
        let (cfg, y) = block.current_best().unwrap();
        assert!(y > 0.7, "best={y}");
        assert_eq!(cfg.str_or("algorithm", ""), "a");
        assert_eq!(block.n_evals(), 60);
        // best curve monotone
        let obs = block.observations();
        assert_eq!(obs.len(), 60);
    }

    #[test]
    fn eu_bounds_bracket_the_truth() {
        let mut obj = Synth { evals: 0, max_evals: 30 };
        let mut rng = Rng::new(1);
        let mut block = joint_for("a", 1);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..30 {
                block.do_next(&mut env).unwrap();
            }
        }
        let (l, u) = block.get_eu(10.0);
        let best = block.current_best().unwrap().1;
        assert!((l - best).abs() < 1e-9, "lower bound is current best");
        assert!(u >= l);
    }

    #[test]
    fn conditioning_block_eliminates_weak_arm() {
        let mut obj = Synth { evals: 0, max_evals: 400 };
        let mut rng = Rng::new(2);
        let arms = vec![
            Arm { value: "a".into(), block: Box::new(joint_for("a", 2)),
                  active: true },
            Arm { value: "b".into(), block: Box::new(joint_for("b", 3)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..8 {
                cond.do_next(&mut env).unwrap();
            }
        }
        // arm 'b' caps at 0.4 < arm 'a' best: must be eliminated
        assert_eq!(cond.active_values(), vec!["a".to_string()]);
        let (cfg, y) = cond.current_best().unwrap();
        assert_eq!(cfg.str_or("algorithm", ""), "a");
        assert!(y > 0.7);
    }

    #[test]
    fn conditioning_never_eliminates_all() {
        let mut obj = Synth { evals: 0, max_evals: 300 };
        let mut rng = Rng::new(3);
        let arms = vec![
            Arm { value: "b".into(), block: Box::new(joint_for("b", 4)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        let mut env = Env::new(&mut obj, &mut rng);
        for _ in 0..5 {
            cond.do_next(&mut env).unwrap();
        }
        assert_eq!(cond.active_children(), 1);
    }

    #[test]
    fn continue_tuning_adds_arms_live() {
        let mut obj = Synth { evals: 0, max_evals: 500 };
        let mut rng = Rng::new(4);
        let arms = vec![
            Arm { value: "b".into(), block: Box::new(joint_for("b", 5)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..3 {
                cond.do_next(&mut env).unwrap();
            }
        }
        let before = cond.current_best().unwrap().1;
        assert!(before < 0.5);
        cond.add_arms(vec![Arm {
            value: "a".into(),
            block: Box::new(joint_for("a", 6)),
            active: true,
        }]);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..8 {
                cond.do_next(&mut env).unwrap();
            }
        }
        let after = cond.current_best().unwrap().1;
        assert!(after > 0.7, "continue tuning found the new arm: {after}");
        // and the weak original arm is eventually eliminated
        assert_eq!(cond.active_values(), vec!["a".to_string()]);
    }

    /// Separable objective for the alternating block: f = g(x) + h(y)
    /// where g moves fast and h is nearly flat -> EUI should route
    /// most plays to the x-side.
    struct Separable {
        evals: usize,
        max_evals: usize,
    }

    impl Objective for Separable {
        fn evaluate(&mut self, cfg: &Config, _f: f64) -> Result<f64> {
            self.evals += 1;
            let x = cfg.f64_or("x", 0.0);
            let y = cfg.f64_or("y", 0.0);
            Ok(-(x - 0.7).powi(2) * 4.0 - 0.01 * (y - 0.5).powi(2))
        }
        fn exhausted(&self) -> bool {
            self.evals >= self.max_evals
        }
    }

    #[test]
    fn alternating_block_optimizes_separable_function() {
        let mut obj = Separable { evals: 0, max_evals: 120 };
        let mut rng = Rng::new(5);
        let bx = JointBlock::bo(
            "x-side",
            ConfigSpace::new().float("x", 0.0, 1.0, 0.1),
            Config::new().with("y", Value::F(0.5)),
            7,
        );
        let by = JointBlock::bo(
            "y-side",
            ConfigSpace::new().float("y", 0.0, 1.0, 0.5),
            Config::new().with("x", Value::F(0.1)),
            8,
        );
        let mut alt = AlternatingBlock::new(
            Box::new(bx), vec!["x".into()],
            Box::new(by), vec!["y".into()],
        );
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..60 {
                alt.do_next(&mut env).unwrap();
            }
        }
        let (cfg, y) = alt.current_best().unwrap();
        assert!(y > -0.05, "best={y}");
        assert!((cfg.f64_or("x", 0.0) - 0.7).abs() < 0.2);
        // EUI routing: x side (fast-moving) should get more evals
        assert!(alt.b1.n_evals() + alt.b2.n_evals() <= 120);
    }

    #[test]
    fn alternating_exchanges_best_via_set_var() {
        // b2's best y must appear in b1's evaluated configs
        let mut obj = Separable { evals: 0, max_evals: 60 };
        let mut rng = Rng::new(6);
        let bx = JointBlock::bo(
            "x", ConfigSpace::new().float("x", 0.0, 1.0, 0.1),
            Config::new().with("y", Value::F(0.123456)), 9);
        let by = JointBlock::bo(
            "y", ConfigSpace::new().float("y", 0.0, 1.0, 0.5),
            Config::new().with("x", Value::F(0.1)), 10);
        let mut alt = AlternatingBlock::new(
            Box::new(bx), vec!["x".into()],
            Box::new(by), vec!["y".into()]);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..30 {
                alt.do_next(&mut env).unwrap();
            }
        }
        // after warmup, b1's latest evals should use a y from b2's
        // history, not the stale initial 0.123456
        let obs = alt.b1.observations();
        let last = &obs.last().unwrap().0;
        assert_ne!(last.f64_or("y", -1.0), 0.123456);
    }

    #[test]
    fn unexplored_block_has_infinite_eui() {
        let block = joint_for("a", 11);
        assert!(block.get_eui().is_infinite());
        let (l, u) = block.get_eu(5.0);
        assert!(l.is_infinite() && l < 0.0);
        assert!(u.is_infinite() && u > 0.0);
    }

    #[test]
    fn batched_pull_counts_every_evaluation() {
        let mut obj = Synth { evals: 0, max_evals: 60 };
        let mut rng = Rng::new(12);
        let mut block = joint_for("a", 12);
        {
            let mut env = Env::with_batch(&mut obj, &mut rng, 4);
            for _ in 0..15 {
                block.do_next(&mut env).unwrap();
            }
        }
        assert_eq!(block.n_evals(), 60);
        let (_, y) = block.current_best().unwrap();
        assert!(y > 0.6, "best={y}");
    }

    #[test]
    fn batched_pull_truncates_exactly_at_the_budget() {
        // cap 10 with batch 4: the final batch must be cut to the
        // remaining budget, never overshooting it
        let mut obj = Synth { evals: 0, max_evals: 10 };
        let mut rng = Rng::new(13);
        let mut block = joint_for("a", 13);
        {
            let mut env = Env::with_batch(&mut obj, &mut rng, 4);
            for _ in 0..6 {
                block.do_next(&mut env).unwrap();
            }
        }
        assert_eq!(obj.evals, 10);
        assert_eq!(block.n_evals(), 10);
    }

    #[test]
    fn env_batch_defaults_and_clamps() {
        let mut obj = Synth { evals: 0, max_evals: 1 };
        let mut rng = Rng::new(14);
        assert_eq!(Env::new(&mut obj, &mut rng).batch, 1);
        let mut obj2 = Synth { evals: 0, max_evals: 1 };
        let mut rng2 = Rng::new(15);
        assert_eq!(Env::with_batch(&mut obj2, &mut rng2, 0).batch, 1);
    }

    #[test]
    fn propose_observe_roundtrip_matches_do_next_bitwise() {
        // the split pull is the pull: driving a joint block through
        // propose -> evaluate_batch -> observe by hand must reproduce
        // do_next exactly, for serial and batched pulls
        for batch in [1usize, 4] {
            let mut obj_a = Synth { evals: 0, max_evals: 40 };
            let mut rng_a = Rng::new(31);
            let mut block_a = joint_for("a", 31);
            {
                let mut env = Env::with_batch(&mut obj_a, &mut rng_a,
                                              batch);
                for _ in 0..10 {
                    block_a.do_next(&mut env).unwrap();
                }
            }
            let mut obj_b = Synth { evals: 0, max_evals: 40 };
            let mut rng_b = Rng::new(31);
            let mut block_b = joint_for("a", 31);
            {
                let mut env = Env::with_batch(&mut obj_b, &mut rng_b,
                                              batch);
                for _ in 0..10 {
                    if env.obj.exhausted() {
                        break;
                    }
                    let prop = block_b.propose(&mut env).unwrap();
                    let ys = env.obj.evaluate_batch(&prop.reqs).unwrap();
                    block_b.observe(prop, &ys);
                }
            }
            assert_eq!(block_a.n_evals(), block_b.n_evals(),
                       "batch={batch}");
            let oa = block_a.observations();
            let ob = block_b.observations();
            for ((ca, ya), (cb, yb)) in oa.iter().zip(&ob) {
                assert_eq!(ca, cb, "batch={batch}");
                assert_eq!(ya.to_bits(), yb.to_bits(), "batch={batch}");
            }
        }
    }

    #[test]
    fn eu_interval_is_never_nan() {
        // one observation + zero lookahead used to produce
        // best + inf * 0.0 = NaN
        let mut obj = Synth { evals: 0, max_evals: 1 };
        let mut rng = Rng::new(41);
        let mut block = joint_for("a", 41);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            block.do_next(&mut env).unwrap();
        }
        assert_eq!(block.n_evals(), 1);
        let (l, u) = block.get_eu(0.0);
        assert!(!l.is_nan() && !u.is_nan(), "NaN EU interval");
        assert_eq!(l.to_bits(), u.to_bits(),
                   "zero lookahead pins the interval to the best");
        // positive lookahead with one observation: still unbounded
        let (l1, u1) = block.get_eu(10.0);
        assert!(l1.is_finite());
        assert!(u1.is_infinite() && u1 > 0.0);
    }

    #[test]
    fn conditioning_eu_guards_zero_active_arms() {
        let mut obj = Synth { evals: 0, max_evals: 60 };
        let mut rng = Rng::new(42);
        let arms = vec![
            Arm { value: "a".into(), block: Box::new(joint_for("a", 43)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..3 {
                cond.do_next(&mut env).unwrap();
            }
        }
        // transient zero-active state (e.g. mid-update in a nested
        // plan): the interval must fall back to the arms' evidence
        // instead of the dominated-by-nothing (-inf, -inf)
        cond.arms[0].active = false;
        let (l, u) = cond.get_eu(10.0);
        assert!(!l.is_nan() && !u.is_nan());
        assert!(u > f64::NEG_INFINITY,
                "(-inf, -inf) interval leaked: ({l}, {u})");
        assert!(l.is_finite(), "lower bound should track the best");
        // and with no arms at all: the unexplored interval
        let empty = ConditioningBlock::new("algorithm", Vec::new());
        let (l2, u2) = empty.get_eu(5.0);
        assert!(l2 == f64::NEG_INFINITY && u2 == f64::INFINITY);
    }

    #[test]
    fn batched_conditioning_block_still_eliminates() {
        let mut obj = Synth { evals: 0, max_evals: 400 };
        let mut rng = Rng::new(16);
        let arms = vec![
            Arm { value: "a".into(), block: Box::new(joint_for("a", 17)),
                  active: true },
            Arm { value: "b".into(), block: Box::new(joint_for("b", 18)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        {
            let mut env = Env::with_batch(&mut obj, &mut rng, 3);
            for _ in 0..6 {
                cond.do_next(&mut env).unwrap();
            }
        }
        assert_eq!(cond.active_values(), vec!["a".to_string()]);
        assert!(cond.n_evals() <= 400);
    }
}
