//! VolcanoML building blocks (§3.2–3.3): the joint, conditioning and
//! alternating blocks with the paper's interfaces — `do_next!`,
//! `get_current_best`, `get_eu` (expected-utility interval, used by
//! the rising-bandit elimination), `get_eui` (expected utility
//! improvement, used by the alternating block) and `set_var`.
//!
//! Blocks optimise a black-box [`Objective`] over *subspaces*: each
//! block owns a free subspace plus a `fixed` partial assignment
//! (`f[x̄_g/c̄_g]` in the paper); evaluations always submit the merged
//! full configuration.
//!
//! `do_next!` is a *batched* pull: each leaf proposes `Env::batch`
//! candidates per invocation and submits them through
//! [`Objective::evaluate_batch`], which may evaluate them on a worker
//! pool (see `runtime::executor`). Results come back in proposal
//! order and observations are applied in that order, so the search
//! trajectory depends only on the batch size — never on the worker
//! count. `batch == 1` reproduces the original one-candidate-per-pull
//! Volcano semantics exactly.

use anyhow::Result;

use crate::opt::multifidelity::{HyperbandFamily, MfOptimizer};
use crate::opt::{Evolutionary, Optimizer, RandomSearch, SmacBo};
use crate::space::{Config, ConfigSpace};
use crate::util::rng::Rng;

/// The black-box function f(x; D): evaluate a full configuration at a
/// fidelity, returning a *utility* (higher is better).
pub trait Objective {
    fn evaluate(&mut self, cfg: &Config, fidelity: f64) -> Result<f64>;

    /// Batched pull: evaluate a slice of (config, fidelity) requests
    /// and return utilities for a **prefix** of them, in request
    /// order. The returned vector may be shorter than `reqs` when the
    /// evaluation budget runs out mid-batch — callers must only
    /// observe the returned prefix, which is how batched `do_next`
    /// preserves exact budget accounting.
    ///
    /// The default implementation evaluates sequentially, stopping at
    /// budget exhaustion between requests; parallel objectives (see
    /// `coordinator::evaluator`) fan the batch out across a worker
    /// pool while committing results in request order, so the output
    /// is identical for any worker count.
    fn evaluate_batch(&mut self, reqs: &[(Config, f64)])
        -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(reqs.len());
        for (i, (cfg, fid)) in reqs.iter().enumerate() {
            if i > 0 && self.exhausted() {
                break;
            }
            out.push(self.evaluate(cfg, *fid)?);
        }
        Ok(out)
    }

    /// True when the budget is exhausted; blocks stop issuing work.
    fn exhausted(&self) -> bool;
}

pub struct Env<'a> {
    pub obj: &'a mut dyn Objective,
    pub rng: &'a mut Rng,
    /// Candidates proposed per leaf-block pull (>= 1). With 1 every
    /// leaf `do_next` evaluates exactly one configuration — the
    /// original strictly-serial Volcano semantics.
    pub batch: usize,
}

impl<'a> Env<'a> {
    /// Serial environment (batch of 1).
    pub fn new(obj: &'a mut dyn Objective, rng: &'a mut Rng) -> Env<'a> {
        Env::with_batch(obj, rng, 1)
    }

    pub fn with_batch(obj: &'a mut dyn Objective, rng: &'a mut Rng,
                      batch: usize) -> Env<'a> {
        Env { obj, rng, batch: batch.max(1) }
    }
}

pub trait BuildingBlock {
    fn name(&self) -> String;
    /// One Volcano-style iteration (recursively invokes children).
    fn do_next(&mut self, env: &mut Env) -> Result<()>;
    /// Best (full config, utility) observed in this subtree.
    fn current_best(&self) -> Option<(Config, f64)>;
    /// Expected-utility interval after `k` more iterations
    /// (rising-bandit bounds, see §3.3.2 / [53]).
    fn get_eu(&self, k: f64) -> (f64, f64);
    /// Expected utility improvement (mean of observed improvements,
    /// Levine et al. rotting bandits).
    fn get_eui(&self) -> f64;
    /// Fix variables of the *enclosing* decomposition (paper's
    /// `set_var`): merged into every future evaluation.
    fn set_var(&mut self, fixed: &Config);
    fn n_evals(&self) -> usize;
    /// Number of live arms (1 for non-conditioning blocks) — drives
    /// the Fig 12 active-arm trend.
    fn active_children(&self) -> usize {
        1
    }
    /// All (full config, utility) observations in this subtree, in
    /// evaluation order (feeds the ensemble and meta-corpus).
    fn observations(&self) -> Vec<(Config, f64)>;
    /// Downcasting hook (continue-tuning drivers need the concrete
    /// ConditioningBlock to extend its arms, §3.3.6).
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

// ====================================================================
// Joint block
// ====================================================================

/// Which engine a joint block runs (§3.3.1: vanilla BO by default;
/// VolcanoML+ uses MFES-HB; random is a testing baseline).
pub enum JointEngine {
    Bo(SmacBo),
    Random(RandomSearch),
    /// TPOT-style evolutionary search (genetic pipeline optimizer).
    Evo(Evolutionary),
    Mf(HyperbandFamily),
}

pub struct JointBlock {
    pub label: String,
    space: ConfigSpace,
    fixed: Config,
    engine: JointEngine,
    /// (full config, utility) in evaluation order.
    history: Vec<(Config, f64)>,
    /// best-so-far curve (same length as history).
    best_curve: Vec<f64>,
}

impl JointBlock {
    pub fn bo(label: &str, space: ConfigSpace, fixed: Config, seed: u64)
        -> JointBlock {
        let engine = JointEngine::Bo(SmacBo::new(space.clone(), seed));
        JointBlock::with_engine(label, space, fixed, engine)
    }

    pub fn with_engine(label: &str, space: ConfigSpace, fixed: Config,
                       engine: JointEngine) -> JointBlock {
        JointBlock {
            label: label.to_string(),
            space,
            fixed,
            engine,
            history: Vec::new(),
            best_curve: Vec::new(),
        }
    }

    pub fn space(&self) -> &ConfigSpace {
        &self.space
    }

    fn record(&mut self, full: Config, y: f64) {
        let prev = self.best_curve.last().copied()
            .unwrap_or(f64::NEG_INFINITY);
        self.best_curve.push(prev.max(y));
        self.history.push((full, y));
    }
}

impl BuildingBlock for JointBlock {
    fn name(&self) -> String {
        format!("joint[{}]", self.label)
    }

    fn do_next(&mut self, env: &mut Env) -> Result<()> {
        if env.obj.exhausted() {
            return Ok(());
        }
        let k = env.batch.max(1);
        // (full config, utility, counts toward the best curve);
        // observations are applied in proposal order after the batch
        // returns, so reward updates are independent of how the
        // objective scheduled the evaluations.
        let mut recs: Vec<(Config, f64, bool)> = Vec::with_capacity(k);
        match &mut self.engine {
            JointEngine::Bo(bo) => {
                let subs = bo.suggest_batch(env.rng, k);
                let reqs: Vec<(Config, f64)> = subs
                    .iter()
                    .map(|s| (self.fixed.merged(s), 1.0))
                    .collect();
                let ys = env.obj.evaluate_batch(&reqs)?;
                for ((sub, (full, _)), y) in
                    subs.into_iter().zip(reqs).zip(ys) {
                    bo.observe(sub, y);
                    recs.push((full, y, true));
                }
            }
            JointEngine::Random(rs) => {
                let subs = rs.suggest_batch(env.rng, k);
                let reqs: Vec<(Config, f64)> = subs
                    .iter()
                    .map(|s| (self.fixed.merged(s), 1.0))
                    .collect();
                let ys = env.obj.evaluate_batch(&reqs)?;
                for ((sub, (full, _)), y) in
                    subs.into_iter().zip(reqs).zip(ys) {
                    rs.observe(sub, y);
                    recs.push((full, y, true));
                }
            }
            JointEngine::Evo(ev) => {
                let subs = ev.suggest_batch(env.rng, k);
                let reqs: Vec<(Config, f64)> = subs
                    .iter()
                    .map(|s| (self.fixed.merged(s), 1.0))
                    .collect();
                let ys = env.obj.evaluate_batch(&reqs)?;
                for ((sub, (full, _)), y) in
                    subs.into_iter().zip(reqs).zip(ys) {
                    ev.observe(sub, y);
                    recs.push((full, y, true));
                }
            }
            JointEngine::Mf(mf) => {
                let picks = mf.suggest_batch(env.rng, k);
                let reqs: Vec<(Config, f64)> = picks
                    .iter()
                    .map(|(s, fid)| (self.fixed.merged(s), *fid))
                    .collect();
                let ys = env.obj.evaluate_batch(&reqs)?;
                for (((sub, fid), (full, _)), y) in
                    picks.into_iter().zip(reqs).zip(ys) {
                    mf.observe(sub, fid, y);
                    // only count full-fidelity results toward the best
                    recs.push((full, y, fid >= 1.0));
                }
            }
        }
        for (full, y, counts) in recs {
            if counts {
                self.record(full, y);
            } else {
                let prev = self.best_curve.last().copied()
                    .unwrap_or(f64::NEG_INFINITY);
                self.best_curve.push(prev);
                self.history.push((full, f64::NEG_INFINITY.max(y)));
                // history keeps the low-fidelity value for the record
                // but best_curve ignores it
            }
        }
        Ok(())
    }

    fn current_best(&self) -> Option<(Config, f64)> {
        let (mut best, mut by) = (None, f64::NEG_INFINITY);
        for (i, (cfg, y)) in self.history.iter().enumerate() {
            // skip low-fidelity entries (best_curve didn't move and y
            // below it)
            let curve = self.best_curve[i];
            if *y >= curve - 1e-12 && *y > by {
                by = *y;
                best = Some(cfg.clone());
            }
        }
        best.map(|c| (c, by))
    }

    fn get_eu(&self, k: f64) -> (f64, f64) {
        let n = self.best_curve.len();
        if n == 0 {
            return (f64::NEG_INFINITY, f64::INFINITY);
        }
        let best = self.best_curve[n - 1];
        // rising-bandit extrapolation: recent per-iteration gain rate
        let w = 10.min(n - 1).max(1);
        let gain = if n > 1 {
            ((self.best_curve[n - 1] - self.best_curve[n - 1 - w])
                / w as f64)
                .max(0.0)
        } else {
            f64::INFINITY
        };
        (best, best + gain * k)
    }

    fn get_eui(&self) -> f64 {
        let n = self.best_curve.len();
        if n < 2 {
            return f64::INFINITY; // unexplored blocks are promising
        }
        // mean of observed improvements (rotting-bandit estimate)
        let mut imps = Vec::with_capacity(n - 1);
        for i in 1..n {
            imps.push(self.best_curve[i] - self.best_curve[i - 1]);
        }
        crate::util::stats::mean(&imps)
    }

    fn set_var(&mut self, fixed: &Config) {
        self.fixed = self.fixed.merged(fixed);
    }

    fn n_evals(&self) -> usize {
        self.history.len()
    }

    fn observations(&self) -> Vec<(Config, f64)> {
        self.history.clone()
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

// ====================================================================
// Conditioning block (Algorithm 1 + rising-bandit elimination)
// ====================================================================

pub struct Arm {
    pub value: String,
    pub block: Box<dyn BuildingBlock>,
    pub active: bool,
}

pub struct ConditioningBlock {
    pub var: String,
    pub arms: Vec<Arm>,
    /// Times each arm is played per do_next (paper: L = 5).
    pub plays_per_round: usize,
    /// Lookahead (in iterations) used for the EU interval.
    pub eu_lookahead: f64,
    /// Disable elimination (ablation flag).
    pub eliminate: bool,
    /// Minimum evaluations an arm must receive before it can be
    /// eliminated — guards freshly added (continue-tuning) arms whose
    /// EU interval is still over-pessimistic (§3.3.2 Remark).
    pub elimination_grace: usize,
    rounds: usize,
}

impl ConditioningBlock {
    pub fn new(var: &str, arms: Vec<Arm>) -> ConditioningBlock {
        ConditioningBlock {
            var: var.to_string(),
            arms,
            plays_per_round: 5,
            eu_lookahead: 10.0,
            eliminate: true,
            elimination_grace: 12,
            rounds: 0,
        }
    }

    /// Continue-tuning (§3.3.6): extend the surviving candidate set
    /// with new arms; they join the round-robin immediately.
    pub fn add_arms(&mut self, arms: Vec<Arm>) {
        self.arms.extend(arms);
    }

    pub fn active_values(&self) -> Vec<String> {
        self.arms
            .iter()
            .filter(|a| a.active)
            .map(|a| a.value.clone())
            .collect()
    }
}

impl BuildingBlock for ConditioningBlock {
    fn name(&self) -> String {
        format!("conditioning[{}]", self.var)
    }

    fn do_next(&mut self, env: &mut Env) -> Result<()> {
        self.rounds += 1;
        // lines 2-4: play each active arm L times (round-robin)
        for _ in 0..self.plays_per_round {
            for arm in self.arms.iter_mut().filter(|a| a.active) {
                if env.obj.exhausted() {
                    return Ok(());
                }
                arm.block.do_next(env)?;
            }
        }
        // lines 5-7: eliminate arms dominated under the EU intervals
        if self.eliminate {
            let bounds: Vec<Option<(f64, f64)>> = self
                .arms
                .iter()
                .map(|a| {
                    if a.active {
                        Some(a.block.get_eu(self.eu_lookahead))
                    } else {
                        None
                    }
                })
                .collect();
            let max_lower = bounds
                .iter()
                .flatten()
                .map(|(l, _)| *l)
                .fold(f64::NEG_INFINITY, f64::max);
            let grace = self.elimination_grace;
            for (arm, b) in self.arms.iter_mut().zip(&bounds) {
                if let Some((_, u)) = b {
                    if *u < max_lower && arm.block.n_evals() >= grace {
                        arm.active = false;
                    }
                }
            }
            // never eliminate everything
            if self.arms.iter().all(|a| !a.active) {
                if let Some(best) = self
                    .arms
                    .iter_mut()
                    .max_by(|a, b| {
                        let ya = a.block.current_best()
                            .map(|(_, y)| y).unwrap_or(f64::NEG_INFINITY);
                        let yb = b.block.current_best()
                            .map(|(_, y)| y).unwrap_or(f64::NEG_INFINITY);
                        ya.partial_cmp(&yb)
                            .unwrap_or(std::cmp::Ordering::Equal)
                    })
                {
                    best.active = true;
                }
            }
        }
        Ok(())
    }

    fn current_best(&self) -> Option<(Config, f64)> {
        self.arms
            .iter()
            .filter_map(|a| a.block.current_best())
            .max_by(|a, b| a.1.partial_cmp(&b.1)
                .unwrap_or(std::cmp::Ordering::Equal))
    }

    fn get_eu(&self, k: f64) -> (f64, f64) {
        let mut lo = f64::NEG_INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for a in self.arms.iter().filter(|a| a.active) {
            let (l, u) = a.block.get_eu(k);
            lo = lo.max(l);
            hi = hi.max(u);
        }
        (lo, hi)
    }

    fn get_eui(&self) -> f64 {
        self.arms
            .iter()
            .filter(|a| a.active)
            .map(|a| a.block.get_eui())
            .fold(f64::NEG_INFINITY, f64::max)
    }

    fn set_var(&mut self, fixed: &Config) {
        for a in &mut self.arms {
            a.block.set_var(fixed);
        }
    }

    fn n_evals(&self) -> usize {
        self.arms.iter().map(|a| a.block.n_evals()).sum()
    }

    fn active_children(&self) -> usize {
        self.arms.iter().filter(|a| a.active).count()
    }

    fn observations(&self) -> Vec<(Config, f64)> {
        let mut v = Vec::new();
        for a in &self.arms {
            v.extend(a.block.observations());
        }
        v
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

// ====================================================================
// Alternating block (Algorithms 2 + 3)
// ====================================================================

pub struct AlternatingBlock {
    pub b1: Box<dyn BuildingBlock>,
    pub b2: Box<dyn BuildingBlock>,
    /// The variable names each side owns (for set_var projection).
    vars1: Vec<String>,
    vars2: Vec<String>,
    /// Warmup rounds remaining (Algorithm 2's L round-robin rounds).
    warmup_left: usize,
    /// EUI-driven arm choice (Algorithm 3); round-robin if false
    /// (ablation of the design choice in §3.3.3).
    pub eui_driven: bool,
    toggle: bool,
}

impl AlternatingBlock {
    pub fn new(b1: Box<dyn BuildingBlock>, vars1: Vec<String>,
               b2: Box<dyn BuildingBlock>, vars2: Vec<String>)
        -> AlternatingBlock {
        AlternatingBlock {
            b1,
            b2,
            vars1,
            vars2,
            warmup_left: 3,
            eui_driven: true,
            toggle: false,
        }
    }

    /// Project a full config onto the variables a side owns, to pass
    /// to the other side via set_var.
    fn project(cfg: &Config, vars: &[String]) -> Config {
        let mut out = Config::new();
        for (k, v) in cfg.iter() {
            if vars.iter().any(|p| k == p || k.starts_with(p)) {
                out.set(k, v.clone());
            }
        }
        out
    }

    fn exchange_to_b1(&mut self) {
        if let Some((cfg, _)) = self.b2.current_best() {
            let proj = Self::project(&cfg, &self.vars2);
            self.b1.set_var(&proj);
        }
    }

    fn exchange_to_b2(&mut self) {
        if let Some((cfg, _)) = self.b1.current_best() {
            let proj = Self::project(&cfg, &self.vars1);
            self.b2.set_var(&proj);
        }
    }
}

impl BuildingBlock for AlternatingBlock {
    fn name(&self) -> String {
        format!("alternating[{} | {}]", self.b1.name(), self.b2.name())
    }

    fn do_next(&mut self, env: &mut Env) -> Result<()> {
        if env.obj.exhausted() {
            return Ok(());
        }
        if self.warmup_left > 0 {
            // Algorithm 2: one round-robin pass with set_var exchange
            self.b1.do_next(env)?;
            self.exchange_to_b2();
            self.b2.do_next(env)?;
            self.exchange_to_b1();
            self.warmup_left -= 1;
            return Ok(());
        }
        let play_first = if self.eui_driven {
            self.b1.get_eui() >= self.b2.get_eui()
        } else {
            self.toggle = !self.toggle;
            self.toggle
        };
        if play_first {
            // lines 4-6: fix z̄ to b2's best, then advance b1
            self.exchange_to_b1();
            self.b1.do_next(env)?;
        } else {
            // lines 8-10
            self.exchange_to_b2();
            self.b2.do_next(env)?;
        }
        Ok(())
    }

    fn current_best(&self) -> Option<(Config, f64)> {
        [self.b1.current_best(), self.b2.current_best()]
            .into_iter()
            .flatten()
            .max_by(|a, b| a.1.partial_cmp(&b.1)
                .unwrap_or(std::cmp::Ordering::Equal))
    }

    fn get_eu(&self, k: f64) -> (f64, f64) {
        let (l1, u1) = self.b1.get_eu(k);
        let (l2, u2) = self.b2.get_eu(k);
        (l1.max(l2), u1.max(u2))
    }

    fn get_eui(&self) -> f64 {
        self.b1.get_eui().max(self.b2.get_eui())
    }

    fn set_var(&mut self, fixed: &Config) {
        self.b1.set_var(fixed);
        self.b2.set_var(fixed);
    }

    fn n_evals(&self) -> usize {
        self.b1.n_evals() + self.b2.n_evals()
    }

    fn observations(&self) -> Vec<(Config, f64)> {
        let mut v = self.b1.observations();
        v.extend(self.b2.observations());
        v
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::Value;

    /// Synthetic objective over {algorithm in a,b} x (x, y):
    /// algo 'a' peaks at 0.8 (x=0.9, y=0.1), algo 'b' caps at 0.4.
    struct Synth {
        evals: usize,
        max_evals: usize,
    }

    impl Objective for Synth {
        fn evaluate(&mut self, cfg: &Config, _f: f64) -> Result<f64> {
            self.evals += 1;
            let x = cfg.f64_or("x", 0.5);
            let y = cfg.f64_or("y", 0.5);
            Ok(match cfg.str_or("algorithm", "a") {
                "a" => 0.8 - (x - 0.9).powi(2) - (y - 0.1).powi(2),
                _ => 0.4 - 0.5 * (x - 0.5).powi(2),
            })
        }
        fn exhausted(&self) -> bool {
            self.evals >= self.max_evals
        }
    }

    fn xy_space() -> ConfigSpace {
        ConfigSpace::new()
            .float("x", 0.0, 1.0, 0.5)
            .float("y", 0.0, 1.0, 0.5)
    }

    fn joint_for(algo: &str, seed: u64) -> JointBlock {
        JointBlock::bo(
            &format!("hp[{algo}]"),
            xy_space(),
            Config::new().with("algorithm", Value::C(algo.into())),
            seed,
        )
    }

    #[test]
    fn joint_block_improves_and_tracks_best() {
        let mut obj = Synth { evals: 0, max_evals: 60 };
        let mut rng = Rng::new(0);
        let mut block = joint_for("a", 0);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..60 {
                block.do_next(&mut env).unwrap();
            }
        }
        let (cfg, y) = block.current_best().unwrap();
        assert!(y > 0.7, "best={y}");
        assert_eq!(cfg.str_or("algorithm", ""), "a");
        assert_eq!(block.n_evals(), 60);
        // best curve monotone
        let obs = block.observations();
        assert_eq!(obs.len(), 60);
    }

    #[test]
    fn eu_bounds_bracket_the_truth() {
        let mut obj = Synth { evals: 0, max_evals: 30 };
        let mut rng = Rng::new(1);
        let mut block = joint_for("a", 1);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..30 {
                block.do_next(&mut env).unwrap();
            }
        }
        let (l, u) = block.get_eu(10.0);
        let best = block.current_best().unwrap().1;
        assert!((l - best).abs() < 1e-9, "lower bound is current best");
        assert!(u >= l);
    }

    #[test]
    fn conditioning_block_eliminates_weak_arm() {
        let mut obj = Synth { evals: 0, max_evals: 400 };
        let mut rng = Rng::new(2);
        let arms = vec![
            Arm { value: "a".into(), block: Box::new(joint_for("a", 2)),
                  active: true },
            Arm { value: "b".into(), block: Box::new(joint_for("b", 3)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..8 {
                cond.do_next(&mut env).unwrap();
            }
        }
        // arm 'b' caps at 0.4 < arm 'a' best: must be eliminated
        assert_eq!(cond.active_values(), vec!["a".to_string()]);
        let (cfg, y) = cond.current_best().unwrap();
        assert_eq!(cfg.str_or("algorithm", ""), "a");
        assert!(y > 0.7);
    }

    #[test]
    fn conditioning_never_eliminates_all() {
        let mut obj = Synth { evals: 0, max_evals: 300 };
        let mut rng = Rng::new(3);
        let arms = vec![
            Arm { value: "b".into(), block: Box::new(joint_for("b", 4)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        let mut env = Env::new(&mut obj, &mut rng);
        for _ in 0..5 {
            cond.do_next(&mut env).unwrap();
        }
        assert_eq!(cond.active_children(), 1);
    }

    #[test]
    fn continue_tuning_adds_arms_live() {
        let mut obj = Synth { evals: 0, max_evals: 500 };
        let mut rng = Rng::new(4);
        let arms = vec![
            Arm { value: "b".into(), block: Box::new(joint_for("b", 5)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..3 {
                cond.do_next(&mut env).unwrap();
            }
        }
        let before = cond.current_best().unwrap().1;
        assert!(before < 0.5);
        cond.add_arms(vec![Arm {
            value: "a".into(),
            block: Box::new(joint_for("a", 6)),
            active: true,
        }]);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..8 {
                cond.do_next(&mut env).unwrap();
            }
        }
        let after = cond.current_best().unwrap().1;
        assert!(after > 0.7, "continue tuning found the new arm: {after}");
        // and the weak original arm is eventually eliminated
        assert_eq!(cond.active_values(), vec!["a".to_string()]);
    }

    /// Separable objective for the alternating block: f = g(x) + h(y)
    /// where g moves fast and h is nearly flat -> EUI should route
    /// most plays to the x-side.
    struct Separable {
        evals: usize,
        max_evals: usize,
    }

    impl Objective for Separable {
        fn evaluate(&mut self, cfg: &Config, _f: f64) -> Result<f64> {
            self.evals += 1;
            let x = cfg.f64_or("x", 0.0);
            let y = cfg.f64_or("y", 0.0);
            Ok(-(x - 0.7).powi(2) * 4.0 - 0.01 * (y - 0.5).powi(2))
        }
        fn exhausted(&self) -> bool {
            self.evals >= self.max_evals
        }
    }

    #[test]
    fn alternating_block_optimizes_separable_function() {
        let mut obj = Separable { evals: 0, max_evals: 120 };
        let mut rng = Rng::new(5);
        let bx = JointBlock::bo(
            "x-side",
            ConfigSpace::new().float("x", 0.0, 1.0, 0.1),
            Config::new().with("y", Value::F(0.5)),
            7,
        );
        let by = JointBlock::bo(
            "y-side",
            ConfigSpace::new().float("y", 0.0, 1.0, 0.5),
            Config::new().with("x", Value::F(0.1)),
            8,
        );
        let mut alt = AlternatingBlock::new(
            Box::new(bx), vec!["x".into()],
            Box::new(by), vec!["y".into()],
        );
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..60 {
                alt.do_next(&mut env).unwrap();
            }
        }
        let (cfg, y) = alt.current_best().unwrap();
        assert!(y > -0.05, "best={y}");
        assert!((cfg.f64_or("x", 0.0) - 0.7).abs() < 0.2);
        // EUI routing: x side (fast-moving) should get more evals
        assert!(alt.b1.n_evals() + alt.b2.n_evals() <= 120);
    }

    #[test]
    fn alternating_exchanges_best_via_set_var() {
        // b2's best y must appear in b1's evaluated configs
        let mut obj = Separable { evals: 0, max_evals: 60 };
        let mut rng = Rng::new(6);
        let bx = JointBlock::bo(
            "x", ConfigSpace::new().float("x", 0.0, 1.0, 0.1),
            Config::new().with("y", Value::F(0.123456)), 9);
        let by = JointBlock::bo(
            "y", ConfigSpace::new().float("y", 0.0, 1.0, 0.5),
            Config::new().with("x", Value::F(0.1)), 10);
        let mut alt = AlternatingBlock::new(
            Box::new(bx), vec!["x".into()],
            Box::new(by), vec!["y".into()]);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            for _ in 0..30 {
                alt.do_next(&mut env).unwrap();
            }
        }
        // after warmup, b1's latest evals should use a y from b2's
        // history, not the stale initial 0.123456
        let obs = alt.b1.observations();
        let last = &obs.last().unwrap().0;
        assert_ne!(last.f64_or("y", -1.0), 0.123456);
    }

    #[test]
    fn unexplored_block_has_infinite_eui() {
        let block = joint_for("a", 11);
        assert!(block.get_eui().is_infinite());
        let (l, u) = block.get_eu(5.0);
        assert!(l.is_infinite() && l < 0.0);
        assert!(u.is_infinite() && u > 0.0);
    }

    #[test]
    fn batched_pull_counts_every_evaluation() {
        let mut obj = Synth { evals: 0, max_evals: 60 };
        let mut rng = Rng::new(12);
        let mut block = joint_for("a", 12);
        {
            let mut env = Env::with_batch(&mut obj, &mut rng, 4);
            for _ in 0..15 {
                block.do_next(&mut env).unwrap();
            }
        }
        assert_eq!(block.n_evals(), 60);
        let (_, y) = block.current_best().unwrap();
        assert!(y > 0.6, "best={y}");
    }

    #[test]
    fn batched_pull_truncates_exactly_at_the_budget() {
        // cap 10 with batch 4: the final batch must be cut to the
        // remaining budget, never overshooting it
        let mut obj = Synth { evals: 0, max_evals: 10 };
        let mut rng = Rng::new(13);
        let mut block = joint_for("a", 13);
        {
            let mut env = Env::with_batch(&mut obj, &mut rng, 4);
            for _ in 0..6 {
                block.do_next(&mut env).unwrap();
            }
        }
        assert_eq!(obj.evals, 10);
        assert_eq!(block.n_evals(), 10);
    }

    #[test]
    fn env_batch_defaults_and_clamps() {
        let mut obj = Synth { evals: 0, max_evals: 1 };
        let mut rng = Rng::new(14);
        assert_eq!(Env::new(&mut obj, &mut rng).batch, 1);
        let mut obj2 = Synth { evals: 0, max_evals: 1 };
        let mut rng2 = Rng::new(15);
        assert_eq!(Env::with_batch(&mut obj2, &mut rng2, 0).batch, 1);
    }

    #[test]
    fn batched_conditioning_block_still_eliminates() {
        let mut obj = Synth { evals: 0, max_evals: 400 };
        let mut rng = Rng::new(16);
        let arms = vec![
            Arm { value: "a".into(), block: Box::new(joint_for("a", 17)),
                  active: true },
            Arm { value: "b".into(), block: Box::new(joint_for("b", 18)),
                  active: true },
        ];
        let mut cond = ConditioningBlock::new("algorithm", arms);
        {
            let mut env = Env::with_batch(&mut obj, &mut rng, 3);
            for _ in 0..6 {
                cond.do_next(&mut env).unwrap();
            }
        }
        assert_eq!(cond.active_values(), vec!["a".to_string()]);
        assert!(cond.n_evals() <= 400);
    }
}
