//! Stable content fingerprints for FE artifacts.
//!
//! A [`Fingerprint`] is a 128-bit rolling hash (two independent
//! FNV-1a lanes) over everything an FE stage's output depends on:
//! the evaluator seed, the dataset identity, the fit-row set, and the
//! (stage, operator, operator-config) triples of the stage prefix.
//! Two evaluations fold the same byte stream iff the staged
//! `fe::FePipeline::fit_apply` would produce bit-identical artifacts
//! for them, which is exactly the contract the content-addressed
//! store needs: serving a cached artifact is indistinguishable from
//! recomputing it.
//!
//! Float config values are folded through their IEEE-754 bit pattern
//! (never a decimal rendering): two configs that differ below any
//! print precision must still key different artifacts, or the store
//! would silently change evaluation results.

use crate::space::{Config, Value};

/// 128-bit rolling content hash; `Copy`, cheap to fold, and stable
/// across runs and platforms (no pointer or layout dependence).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Fingerprint {
    hi: u64,
    lo: u64,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;
/// Second-lane offset (FNV offset basis of a different stream): the
/// lanes see the same bytes but from different states, so a collision
/// must defeat both simultaneously.
const LANE2_OFFSET: u64 = 0x6c62272e07bb0142;
/// Per-byte perturbation of the second lane's input.
const LANE2_XOR: u8 = 0xA5;

impl Default for Fingerprint {
    fn default() -> Self {
        Fingerprint::new()
    }
}

impl Fingerprint {
    pub fn new() -> Fingerprint {
        Fingerprint { hi: FNV_OFFSET, lo: LANE2_OFFSET }
    }

    #[inline]
    pub fn push_bytes(mut self, bytes: &[u8]) -> Fingerprint {
        for &b in bytes {
            self.hi = (self.hi ^ b as u64).wrapping_mul(FNV_PRIME);
            self.lo = (self.lo ^ (b ^ LANE2_XOR) as u64)
                .wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Fold a string with a terminator byte, so `("ab", "c")` and
    /// `("a", "bc")` fold differently.
    #[inline]
    pub fn push_str(self, s: &str) -> Fingerprint {
        self.push_bytes(s.as_bytes()).push_bytes(&[0xFE])
    }

    #[inline]
    pub fn push_u64(self, v: u64) -> Fingerprint {
        self.push_bytes(&v.to_le_bytes())
    }

    /// Fold a row-index set (split identity: which rows a stage fits
    /// on is part of the artifact's content address).
    pub fn push_rows(self, rows: &[usize]) -> Fingerprint {
        let mut fp = self.push_u64(rows.len() as u64);
        for &r in rows {
            fp = fp.push_u64(r as u64);
        }
        fp
    }

    /// Fold a column-visibility mask (which columns of the dataset a
    /// stage prefix sees). Columnar datasets can share column chunks
    /// between views, so column identity is part of an artifact's
    /// content address: a 3-of-40-column view must never collide with
    /// the full dataset even when name/n/d match. Bits are packed
    /// little-endian into bytes, length-prefixed (so `[true]` and
    /// `[true, false]` fold differently).
    pub fn push_col_mask(self, mask: &[bool]) -> Fingerprint {
        let mut fp = self.push_u64(mask.len() as u64);
        for chunk in mask.chunks(8) {
            let mut byte = 0u8;
            for (b, &on) in chunk.iter().enumerate() {
                if on {
                    byte |= 1 << b;
                }
            }
            fp = fp.push_bytes(&[byte]);
        }
        fp
    }

    /// Fold one config value *exactly*: floats by bit pattern with a
    /// type tag, so `F(1.0)` and `I(1)` (and any two floats that
    /// would print identically) stay distinct.
    pub fn push_value(self, v: &Value) -> Fingerprint {
        match v {
            Value::F(x) => self.push_bytes(&[b'F']).push_u64(x.to_bits()),
            Value::I(i) => self.push_bytes(&[b'I']).push_u64(*i as u64),
            Value::C(s) => self.push_bytes(&[b'C']).push_str(s),
        }
    }

    /// Fold a whole config in its stable (BTreeMap) key order.
    pub fn push_config(self, cfg: &Config) -> Fingerprint {
        let mut fp = self;
        for (k, v) in cfg.iter() {
            fp = fp.push_str(k).push_value(v);
        }
        fp.push_bytes(&[0xFD])
    }

    /// The 128-bit key used to address the store.
    #[inline]
    pub fn key(&self) -> u128 {
        ((self.hi as u128) << 64) | self.lo as u128
    }

    /// Deterministic 64-bit seed for a stage's private rng stream.
    #[inline]
    pub fn seed64(&self) -> u64 {
        self.hi ^ self.lo.rotate_left(32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_and_boundaries_matter() {
        let a = Fingerprint::new().push_str("ab").push_str("c");
        let b = Fingerprint::new().push_str("a").push_str("bc");
        assert_ne!(a.key(), b.key());
        let c = Fingerprint::new().push_str("c").push_str("ab");
        assert_ne!(a.key(), c.key());
    }

    #[test]
    fn deterministic_across_instances() {
        let mk = || {
            Fingerprint::new()
                .push_str("scaler")
                .push_u64(42)
                .push_rows(&[1, 2, 3])
        };
        assert_eq!(mk().key(), mk().key());
        assert_eq!(mk().seed64(), mk().seed64());
    }

    #[test]
    fn float_values_fold_by_bit_pattern() {
        // two floats that print identically at any fixed precision
        // must still produce distinct fingerprints
        let x = 0.123456789012345_f64;
        let y = f64::from_bits(x.to_bits() + 1);
        let a = Fingerprint::new().push_value(&Value::F(x));
        let b = Fingerprint::new().push_value(&Value::F(y));
        assert_ne!(a.key(), b.key());
        // and F(1.0) vs I(1) are tagged apart
        let f = Fingerprint::new().push_value(&Value::F(1.0));
        let i = Fingerprint::new().push_value(&Value::I(1));
        assert_ne!(f.key(), i.key());
    }

    #[test]
    fn config_folding_uses_stable_order() {
        let a = Config::new()
            .with("b", Value::F(2.0))
            .with("a", Value::F(1.0));
        let b = Config::new()
            .with("a", Value::F(1.0))
            .with("b", Value::F(2.0));
        // BTreeMap iteration order makes insertion order irrelevant
        assert_eq!(Fingerprint::new().push_config(&a).key(),
                   Fingerprint::new().push_config(&b).key());
        // but different assignments differ
        let c = Config::new().with("a", Value::F(1.0));
        assert_ne!(Fingerprint::new().push_config(&a).key(),
                   Fingerprint::new().push_config(&c).key());
    }

    #[test]
    fn col_masks_are_part_of_the_address() {
        let base = Fingerprint::new().push_str("ds");
        // different subsets of the same width differ
        assert_ne!(base.push_col_mask(&[true, false, true]).key(),
                   base.push_col_mask(&[true, true, false]).key());
        // all-true masks of different widths differ (d is folded)
        assert_ne!(base.push_col_mask(&[true; 8]).key(),
                   base.push_col_mask(&[true; 9]).key());
        // deterministic
        assert_eq!(base.push_col_mask(&[false, true]).key(),
                   base.push_col_mask(&[false, true]).key());
    }

    #[test]
    fn row_sets_are_part_of_the_address() {
        let base = Fingerprint::new().push_str("ds");
        assert_ne!(base.push_rows(&[0, 1]).key(),
                   base.push_rows(&[1, 0]).key());
        assert_ne!(base.push_rows(&[0, 1]).key(),
                   base.push_rows(&[0, 1, 2]).key());
    }
}
