//! Shared FE artifact store: a concurrent, content-addressed cache of
//! feature-engineering stage outputs.
//!
//! VolcanoML's decomposition makes whole subtrees of the plan share FE
//! prefixes — a conditioning arm fixes an FE stage while its leaves
//! sweep the rest, and super-batched rounds re-evaluate the same stage
//! config with only algorithm hyper-parameters varying — yet the
//! evaluator used to recompute `fe::fit_apply` from scratch for every
//! fresh evaluation. The store keys `Arc<Dataset>` artifacts by a
//! stable [`Fingerprint`] of (dataset identity, fit rows, FE
//! stage-prefix config): the staged `fit_apply` resolves the longest
//! cached prefix and fits only the suffix.
//!
//! Properties:
//! * **Trajectory-neutral.** An artifact's fingerprint covers every
//!   input of its computation (including the per-stage rng seed, which
//!   is itself derived from the fingerprint), so serving a cached
//!   artifact is bit-identical to recomputing it. Search trajectories
//!   are the same at any byte bound, worker count, or hit pattern —
//!   the store is a pure wall-clock knob.
//! * **Sharded locking.** The map is split into [`SHARDS`] independent
//!   mutexes addressed by fingerprint bits, so concurrent workers
//!   rarely contend.
//! * **Cross-worker dedup.** Two workers fitting the same prefix
//!   concurrently coalesce on one computation: the first inserts a
//!   *pending* entry and computes; the rest block on its condvar and
//!   receive the published artifact ([`FeStoreStats::coalesced`]).
//!   An abandoned computation (the stage turned out to be the
//!   identity, or the fit panicked) wakes the waiters to compute for
//!   themselves, so nobody hangs.
//! * **Byte-bounded LRU.** Entries carry a last-use stamp from a
//!   global clock; publishing past the byte budget evicts the
//!   least-recently-used ready entries until the store fits. Pending
//!   entries are never evicted.
//!
//! Follow-ups recorded in ROADMAP.md: spill-to-disk for artifacts
//! evicted under memory pressure, and cross-run persistence keyed by
//! the same fingerprints.
//!
//! All synchronisation primitives come through [`crate::sync`] (plain
//! `std` normally, loom under `--features loom`), so the
//! pending-entry coalescing and the abandon-on-drop wake-up are
//! model-checked by `rust/tests/loom_models.rs` against this exact
//! code.

// Every pub type here should explain itself in failure output.
#![warn(missing_debug_implementations)]

mod fingerprint;

pub use fingerprint::Fingerprint;

use std::collections::HashMap;

use crate::data::dataset::Dataset;
use crate::sync::{lock, Arc, AtomicU64, AtomicUsize, Condvar, Mutex,
                  MutexGuard, Ordering};

/// Lock-shard count (power of two; addressed by low fingerprint bits).
const SHARDS: usize = 16;

/// One cached FE state: the transformed dataset plus the (possibly
/// balancer-augmented) training index set that goes with it.
///
/// Datasets are columnar with `Arc`-shared columns, so an artifact
/// "stores" only the columns its stage materialised: `novel` marks
/// them, and [`FeArtifact::cost`] charges the byte bound for novel
/// columns alone — a 3-of-40-column stage costs 3 columns, the other
/// 37 stay pointer-shared with (and accounted to) its input.
pub struct FeArtifact {
    pub data: Arc<Dataset>,
    pub train: Arc<Vec<usize>>,
    /// Per-column novelty mask vs the stage input (`true` = this
    /// artifact materialised the column; `false` = pointer-shared).
    novel: Vec<bool>,
    /// Whether `data.y` is a fresh allocation (balancer augmentation)
    /// rather than shared with the stage input.
    novel_y: bool,
}

impl FeArtifact {
    fn vs(data: Arc<Dataset>, train: Arc<Vec<usize>>, base: &Dataset)
        -> FeArtifact {
        let novel = (0..data.d)
            .map(|j| {
                !(0..base.d).any(|b| Arc::ptr_eq(data.col_arc(j),
                                                 base.col_arc(b)))
            })
            .collect();
        let novel_y = !Arc::ptr_eq(&data.y, &base.y);
        FeArtifact { data, train, novel, novel_y }
    }

    /// Which output columns this artifact materialised itself.
    pub fn novel_mask(&self) -> &[bool] {
        &self.novel
    }

    pub fn novel_cols(&self) -> usize {
        self.novel.iter().filter(|&&b| b).count()
    }

    /// Approximate resident bytes, used for the LRU byte bound:
    /// novel columns + (if fresh) labels + the train index set.
    fn cost(&self) -> usize {
        self.novel_cols() * self.data.n * 4
            + if self.novel_y { self.data.y.len() * 4 } else { 0 }
            + self.train.len() * std::mem::size_of::<usize>()
            + 64
    }
}

impl std::fmt::Debug for FeArtifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FeArtifact")
            .field("rows", &self.data.n)
            .field("train", &self.train.len())
            .field("novel_cols", &self.novel_cols())
            .field("cost", &self.cost())
            .finish_non_exhaustive()
    }
}

enum WaitState {
    Pending,
    Ready(Arc<FeArtifact>),
    /// The computing thread gave up (identity stage or unwound):
    /// waiters compute for themselves.
    Abandoned,
}

struct Waiter {
    state: Mutex<WaitState>,
    cv: Condvar,
}

impl Waiter {
    fn new() -> Waiter {
        Waiter { state: Mutex::new(WaitState::Pending),
                 cv: Condvar::new() }
    }

    fn resolve(&self, state: WaitState) {
        *lock(&self.state) = state;
        self.cv.notify_all();
    }
}

enum Entry {
    Ready { art: Arc<FeArtifact>, stamp: u64, cost: usize },
    Pending(Arc<Waiter>),
}

/// Point-in-time counters of the store (see module docs). `hits`
/// count artifacts served from the map, `coalesced` artifacts
/// received by waiting out a concurrent computation, `misses` the
/// computations actually run by callers; the hit rate of interest is
/// `(hits + coalesced) / (hits + coalesced + misses)`.
#[derive(Clone, Copy, Debug, Default)]
pub struct FeStoreStats {
    pub hits: u64,
    pub coalesced: u64,
    pub misses: u64,
    pub published: u64,
    pub evictions: u64,
    pub bytes: usize,
    pub entries: usize,
    pub cap_bytes: usize,
    /// Columns materialised by published artifacts (charged bytes).
    pub novel_cols: u64,
    /// Columns published as pointer-shares of their stage input
    /// (zero-copy; not charged).
    pub shared_cols: u64,
}

impl FeStoreStats {
    pub fn hit_rate(&self) -> f64 {
        let served = self.hits + self.coalesced;
        let total = served + self.misses;
        if total == 0 {
            0.0
        } else {
            served as f64 / total as f64
        }
    }
}

/// Per-tenant slice of the store counters: how one co-tenant search
/// experienced the shared store. `hits + coalesced` of a tenant can
/// exceed its `misses`-driven contributions precisely when co-tenant
/// searches on the same dataset dedup each other's fits — the
/// cross-search sharing the multi-tenant runtime exists for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeTenantStats {
    pub hits: u64,
    pub coalesced: u64,
    pub misses: u64,
}

impl FeTenantStats {
    pub fn served(&self) -> u64 {
        self.hits + self.coalesced
    }

    pub fn total(&self) -> u64 {
        self.hits + self.coalesced + self.misses
    }
}

/// Outcome of [`FeStore::begin`]: either the artifact is already
/// available (cached, or received from a concurrent computation), or
/// the caller owns the computation and must publish through (or drop)
/// the ticket.
pub enum Resolved<'s> {
    Ready(Arc<FeArtifact>),
    Compute(Ticket<'s>),
}

impl std::fmt::Debug for Resolved<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Resolved::Ready(art) => {
                f.debug_tuple("Resolved::Ready").field(art).finish()
            }
            Resolved::Compute(t) => {
                f.debug_tuple("Resolved::Compute").field(t).finish()
            }
        }
    }
}

/// Ownership of one in-flight computation. Publish the artifact with
/// [`Ticket::publish`]; dropping the ticket instead (identity stage,
/// or an unwinding fit) abandons the pending entry and wakes any
/// waiters to compute for themselves — a panicking fit can never
/// strand them.
pub struct Ticket<'s> {
    store: &'s FeStore,
    fp: Fingerprint,
    /// The pending entry registered in the map, if any (a waiter that
    /// was woken by an abandon computes unregistered).
    waiter: Option<Arc<Waiter>>,
}

impl<'s> Ticket<'s> {
    /// Insert the artifact, wake waiters, and enforce the byte bound.
    /// Every column is charged as novel (no stage input to share
    /// with); prefer [`Ticket::publish_vs`] on the pipeline path.
    pub fn publish(mut self, data: Arc<Dataset>, train: Arc<Vec<usize>>)
        -> Arc<FeArtifact> {
        let novel = vec![true; data.d];
        let art = Arc::new(FeArtifact { data, train, novel,
                                        novel_y: true });
        self.store.insert_ready(self.fp, art.clone(),
                                self.waiter.take());
        art
    }

    /// [`Ticket::publish`] with column-level accounting: columns of
    /// `data` that are pointer-shared with any column of `base` (the
    /// stage input) are recorded as non-novel and not charged against
    /// the byte bound — they are already paid for upstream.
    pub fn publish_vs(mut self, data: Arc<Dataset>,
                      train: Arc<Vec<usize>>, base: &Dataset)
        -> Arc<FeArtifact> {
        let art = Arc::new(FeArtifact::vs(data, train, base));
        self.store.insert_ready(self.fp, art.clone(),
                                self.waiter.take());
        art
    }
}

impl std::fmt::Debug for Ticket<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket")
            .field("fp", &self.fp)
            .field("registered", &self.waiter.is_some())
            .finish_non_exhaustive()
    }
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        // not published: clear our pending entry (if it is still
        // ours) and wake waiters to compute for themselves
        if let Some(w) = self.waiter.take() {
            let mut shard = self.store.shard(self.fp);
            if matches!(shard.get(&self.fp.key()),
                        Some(Entry::Pending(p)) if Arc::ptr_eq(p, &w))
            {
                shard.remove(&self.fp.key());
            }
            drop(shard);
            w.resolve(WaitState::Abandoned);
        }
    }
}

/// The concurrent, content-addressed FE artifact store. Shared across
/// evaluator worker threads through an `Arc`; see module docs.
pub struct FeStore {
    shards: Vec<Mutex<HashMap<u128, Entry>>>,
    cap_bytes: usize,
    bytes: AtomicUsize,
    clock: AtomicU64,
    /// Serialises evictions (concurrent publishers past the bound
    /// would otherwise both scan the whole map).
    evict_gate: Mutex<()>,
    hits: AtomicU64,
    coalesced: AtomicU64,
    misses: AtomicU64,
    published: AtomicU64,
    evictions: AtomicU64,
    novel_cols: AtomicU64,
    shared_cols: AtomicU64,
    /// Per-tenant counters (see [`FeTenantStats`]). Keyed by the
    /// executor's tenant id; single-search stores only ever touch
    /// tenant 0. A plain mutex: the map is tiny (one entry per live
    /// search) and bumped once per store operation, which is dwarfed
    /// by the fit either side of it.
    tenants: Mutex<HashMap<u64, FeTenantStats>>,
}

impl std::fmt::Debug for FeStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FeStore")
            .field("stats", &self.stats())
            .finish_non_exhaustive()
    }
}

impl FeStore {
    pub fn new(cap_bytes: usize) -> FeStore {
        FeStore {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new()))
                .collect(),
            cap_bytes,
            bytes: AtomicUsize::new(0),
            clock: AtomicU64::new(0),
            evict_gate: Mutex::new(()),
            hits: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            published: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            novel_cols: AtomicU64::new(0),
            shared_cols: AtomicU64::new(0),
            tenants: Mutex::new(HashMap::new()),
        }
    }

    fn bump_tenant(&self, tenant: u64,
                   f: impl FnOnce(&mut FeTenantStats)) {
        f(lock(&self.tenants).entry(tenant).or_default());
    }

    fn shard(&self, fp: Fingerprint)
        -> MutexGuard<'_, HashMap<u128, Entry>> {
        let idx = (fp.key() as usize) & (SHARDS - 1);
        lock(&self.shards[idx])
    }

    fn tick(&self) -> u64 {
        // SYNC: Relaxed — the LRU clock only needs distinct,
        // monotone stamps (fetch_add is atomic at every ordering);
        // stamps are stored and compared under the shard locks, which
        // provide the ordering that matters.
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Non-blocking probe: a ready artifact or nothing. Used by the
    /// longest-cached-prefix walk; an in-flight (pending) entry reads
    /// as absent here, so the walk falls back to a shorter prefix
    /// instead of blocking (the per-stage [`Self::begin`] still
    /// coalesces with the in-flight fit when the walk reaches it).
    /// Counts a hit only on success — failed probes of a prefix walk
    /// are not misses (the computation miss is counted by `begin`).
    pub fn lookup(&self, fp: Fingerprint) -> Option<Arc<FeArtifact>> {
        self.lookup_as(fp, 0)
    }

    /// [`Self::lookup`] attributed to a tenant (see
    /// [`Self::tenant_stats`]): same semantics, but a successful hit
    /// is also counted on the tenant's slice of the stats.
    pub fn lookup_as(&self, fp: Fingerprint, tenant: u64)
        -> Option<Arc<FeArtifact>> {
        let hit = {
            let mut shard = self.shard(fp);
            match shard.get_mut(&fp.key()) {
                Some(Entry::Ready { art, stamp, .. }) => {
                    *stamp = self.tick();
                    Some(art.clone())
                }
                _ => None,
            }
        };
        if hit.is_some() {
            // SYNC: Relaxed — monotone stats counter, only read back
            // by stats() snapshots; never publishes data
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.bump_tenant(tenant, |t| t.hits += 1);
        }
        hit
    }

    /// Resolve one stage: a ready artifact (hit), the artifact of a
    /// concurrent computation of the same fingerprint (coalesced —
    /// this call blocks until it publishes or abandons), or a
    /// [`Ticket`] making the caller the computing thread (miss).
    pub fn begin(&self, fp: Fingerprint) -> Resolved<'_> {
        self.begin_as(fp, 0)
    }

    /// [`Self::begin`] attributed to a tenant (see
    /// [`Self::tenant_stats`]): same semantics, but the hit /
    /// coalesced / miss outcome is also counted on the tenant's slice
    /// of the stats — this is what lets a co-tenancy test assert that
    /// two searches sharing a dataset split one fit between them.
    pub fn begin_as(&self, fp: Fingerprint, tenant: u64)
        -> Resolved<'_> {
        let waiter = {
            let mut shard = self.shard(fp);
            match shard.get_mut(&fp.key()) {
                Some(Entry::Ready { art, stamp, .. }) => {
                    *stamp = self.tick();
                    // SYNC: Relaxed — monotone stats counter (here
                    // and on every counter bump below): only read by
                    // stats() snapshots, never publishes data
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    self.bump_tenant(tenant, |t| t.hits += 1);
                    crate::obs::event!("fe_store", "hit",
                                       "tenant" => tenant);
                    return Resolved::Ready(art.clone());
                }
                Some(Entry::Pending(w)) => w.clone(),
                None => {
                    let w = Arc::new(Waiter::new());
                    shard.insert(fp.key(), Entry::Pending(w.clone()));
                    // SYNC: Relaxed — monotone stats counter
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    self.bump_tenant(tenant, |t| t.misses += 1);
                    crate::obs::event!("fe_store", "miss",
                                       "tenant" => tenant);
                    return Resolved::Compute(Ticket {
                        store: self,
                        fp,
                        waiter: Some(w),
                    });
                }
            }
        };
        // coalesce: wait out the concurrent computation
        let _span = crate::obs::span!("fe_store", "coalesce",
                                      "tenant" => tenant);
        let mut st = lock(&waiter.state);
        loop {
            match &*st {
                WaitState::Ready(art) => {
                    // SYNC: Relaxed — monotone stats counter
                    self.coalesced.fetch_add(1, Ordering::Relaxed);
                    self.bump_tenant(tenant, |t| t.coalesced += 1);
                    return Resolved::Ready(art.clone());
                }
                WaitState::Abandoned => {
                    // the computing thread gave up (identity stage or
                    // unwound): compute for ourselves, unregistered —
                    // re-registering could livelock against other
                    // woken waiters, and duplicate identical work is
                    // harmless (last publish wins)
                    // SYNC: Relaxed — monotone stats counter
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    self.bump_tenant(tenant, |t| t.misses += 1);
                    return Resolved::Compute(Ticket {
                        store: self,
                        fp,
                        waiter: None,
                    });
                }
                WaitState::Pending => {
                    st = match waiter.cv.wait(st) {
                        Ok(g) => g,
                        Err(p) => p.into_inner(),
                    };
                }
            }
        }
    }

    /// One tenant's slice of the counters: every `lookup_as` /
    /// `begin_as` outcome attributed to `tenant`. Unknown tenants
    /// read as all-zero.
    pub fn tenant_stats(&self, tenant: u64) -> FeTenantStats {
        lock(&self.tenants).get(&tenant).copied().unwrap_or_default()
    }

    /// Insert a ready entry (replacing a pending or stale one), wake
    /// `waiter`, and evict down to the byte bound.
    fn insert_ready(&self, fp: Fingerprint, art: Arc<FeArtifact>,
                    waiter: Option<Arc<Waiter>>) {
        let cost = art.cost();
        {
            let mut shard = self.shard(fp);
            let old = shard.insert(fp.key(), Entry::Ready {
                art: art.clone(),
                stamp: self.tick(),
                cost,
            });
            // SYNC: Relaxed — `bytes` is an advisory occupancy gauge
            // for the eviction trigger, adjusted while the entry's
            // shard lock is held (so it never drifts from the map);
            // eviction decisions tolerate momentary staleness and
            // converge under the evict gate.
            if let Some(Entry::Ready { cost: old_cost, .. }) = old {
                self.bytes.fetch_sub(old_cost, Ordering::Relaxed);
            }
            self.bytes.fetch_add(cost, Ordering::Relaxed);
        }
        // SYNC: Relaxed — monotone stats counters
        self.published.fetch_add(1, Ordering::Relaxed);
        crate::obs::event!("fe_store", "publish", "bytes" => cost);
        let novel = art.novel_cols() as u64;
        self.novel_cols.fetch_add(novel, Ordering::Relaxed);
        self.shared_cols.fetch_add(art.data.d as u64 - novel,
                                   Ordering::Relaxed);
        if let Some(w) = waiter {
            w.resolve(WaitState::Ready(art));
        }
        self.evict_to_cap();
    }

    /// Evict least-recently-used ready entries until the byte bound
    /// holds. Pending entries are never evicted; an entry touched
    /// after the candidate scan is skipped (its stamp moved).
    fn evict_to_cap(&self) {
        // SYNC: Relaxed — advisory occupancy probe (see insert_ready
        // on the `bytes` gauge); a stale read at worst delays or
        // repeats an eviction pass, never corrupts the map
        if self.bytes.load(Ordering::Relaxed) <= self.cap_bytes {
            return;
        }
        let _gate = lock(&self.evict_gate);
        // SYNC: Relaxed — same advisory `bytes` probe as above
        while self.bytes.load(Ordering::Relaxed) > self.cap_bytes {
            // candidate scan: (stamp, key, cost) of every ready entry
            let mut cands: Vec<(u64, usize, u128, usize)> = Vec::new();
            for (si, sh) in self.shards.iter().enumerate() {
                let shard = lock(sh);
                for (key, e) in shard.iter() {
                    if let Entry::Ready { stamp, cost, .. } = e {
                        cands.push((*stamp, si, *key, *cost));
                    }
                }
            }
            cands.sort_unstable_by_key(|c| c.0);
            let mut progressed = false;
            for (stamp, si, key, cost) in cands {
                // SYNC: Relaxed — advisory `bytes` probe (above),
                // gauge adjustment under the shard lock and a
                // monotone stats counter (below)
                if self.bytes.load(Ordering::Relaxed) <= self.cap_bytes
                {
                    break;
                }
                let mut shard = lock(&self.shards[si]);
                let still_lru = matches!(
                    shard.get(&key),
                    Some(Entry::Ready { stamp: s, .. }) if *s == stamp);
                if still_lru {
                    shard.remove(&key);
                    self.bytes.fetch_sub(cost, Ordering::Relaxed);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                    crate::obs::event!("fe_store", "evict",
                                       "bytes" => cost);
                    progressed = true;
                }
            }
            if !progressed {
                // everything left is pending or freshly touched:
                // nothing evictable right now
                break;
            }
        }
    }

    pub fn stats(&self) -> FeStoreStats {
        // SYNC: Relaxed — point-in-time snapshot of monotone
        // counters and the advisory byte gauge; the snapshot is
        // diagnostic, not a synchronisation point
        FeStoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            published: self.published.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            novel_cols: self.novel_cols.load(Ordering::Relaxed),
            shared_cols: self.shared_cols.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            entries: self.shards.iter()
                .map(|s| lock(s).len())
                .sum(),
            cap_bytes: self.cap_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn toy_dataset(rows: usize, tag: f32) -> Arc<Dataset> {
        let mut ds = Dataset::new("toy",
                                  Task::Classification { n_classes: 2 },
                                  4);
        for i in 0..rows {
            ds.push_row(&[tag, i as f32, 0.0, 1.0],
                        (i % 2) as f32);
        }
        Arc::new(ds)
    }

    fn publish(store: &FeStore, fp: Fingerprint, rows: usize)
        -> Arc<FeArtifact> {
        match store.begin(fp) {
            Resolved::Compute(t) => t.publish(
                toy_dataset(rows, 1.0),
                Arc::new((0..rows).collect())),
            Resolved::Ready(a) => a,
        }
    }

    fn fp_of(tag: &str) -> Fingerprint {
        Fingerprint::new().push_str(tag)
    }

    #[test]
    fn miss_then_hit_roundtrip() {
        let store = FeStore::new(1 << 20);
        let fp = fp_of("a");
        assert!(store.lookup(fp).is_none());
        let art = publish(&store, fp, 10);
        assert_eq!(art.data.n, 10);
        let hit = store.lookup(fp).expect("published artifact");
        assert!(Arc::ptr_eq(&hit.data, &art.data));
        let st = store.stats();
        assert_eq!((st.hits, st.misses, st.published), (1, 1, 1));
        assert_eq!(st.entries, 1);
        assert!(st.bytes > 0 && st.bytes <= st.cap_bytes);
    }

    #[test]
    fn abandoned_ticket_clears_its_pending_entry() {
        let store = FeStore::new(1 << 20);
        let fp = fp_of("b");
        match store.begin(fp) {
            Resolved::Compute(t) => drop(t), // identity stage
            Resolved::Ready(_) => panic!("empty store cannot hit"),
        }
        // the pending entry is gone: the next begin computes afresh
        match store.begin(fp) {
            Resolved::Compute(t) => drop(t),
            Resolved::Ready(_) => panic!("abandon must not publish"),
        }
        assert_eq!(store.stats().entries, 0);
    }

    #[test]
    fn eviction_respects_the_byte_bound() {
        // artifacts of ~ (rows * 4 floats * 4 bytes + rows * 8 + 64)
        let one = {
            let probe = FeStore::new(usize::MAX);
            publish(&probe, fp_of("probe"), 50);
            probe.stats().bytes
        };
        let cap = one * 3 + one / 2; // room for three artifacts
        let store = FeStore::new(cap);
        for i in 0..10 {
            publish(&store, fp_of(&format!("k{i}")), 50);
            assert!(store.stats().bytes <= cap,
                    "byte bound violated after insert {i}: {} > {cap}",
                    store.stats().bytes);
        }
        let st = store.stats();
        assert!(st.evictions >= 7, "evictions: {}", st.evictions);
        assert!(st.entries <= 3);
        // the most recently published keys survive, the oldest are
        // gone (LRU order)
        assert!(store.lookup(fp_of("k9")).is_some());
        assert!(store.lookup(fp_of("k0")).is_none());
    }

    #[test]
    fn lru_prefers_recently_used_entries() {
        let one = {
            let probe = FeStore::new(usize::MAX);
            publish(&probe, fp_of("probe"), 50);
            probe.stats().bytes
        };
        let store = FeStore::new(2 * one + one / 2);
        publish(&store, fp_of("old"), 50);
        publish(&store, fp_of("new"), 50);
        // touch "old" so "new" becomes the LRU victim
        assert!(store.lookup(fp_of("old")).is_some());
        publish(&store, fp_of("third"), 50);
        assert!(store.lookup(fp_of("old")).is_some(),
                "recently used entry was evicted");
        assert!(store.lookup(fp_of("new")).is_none(),
                "LRU entry survived past the byte bound");
    }

    #[test]
    fn zero_cap_store_stays_empty_but_correct() {
        let store = FeStore::new(0);
        let art = publish(&store, fp_of("z"), 20);
        assert_eq!(art.data.n, 20, "publish still hands the artifact \
                                    back to the computing thread");
        assert_eq!(store.stats().bytes, 0);
        assert_eq!(store.stats().entries, 0);
        assert!(store.lookup(fp_of("z")).is_none());
    }

    #[test]
    fn concurrent_same_prefix_fits_coalesce_to_one_computation() {
        let store = FeStore::new(1 << 20);
        let fp = fp_of("shared");
        let computed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| match store.begin(fp) {
                    Resolved::Ready(a) => assert_eq!(a.data.n, 33),
                    Resolved::Compute(t) => {
                        computed.fetch_add(1, Ordering::SeqCst);
                        // widen the race window so the other threads
                        // really arrive while we are "fitting"
                        std::thread::sleep(Duration::from_millis(20));
                        t.publish(toy_dataset(33, 2.0),
                                  Arc::new((0..33).collect()));
                    }
                });
            }
        });
        assert_eq!(computed.load(Ordering::SeqCst), 1,
                   "same-prefix fits must coalesce to one computation");
        let st = store.stats();
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits + st.coalesced, 7,
                   "every other thread was served the one artifact");
        assert_eq!(st.published, 1);
    }

    #[test]
    fn tenant_stats_split_the_global_counters() {
        let store = FeStore::new(1 << 20);
        let fp = fp_of("shared-across-tenants");
        // tenant 7 computes; tenant 9 then hits the same fingerprint
        match store.begin_as(fp, 7) {
            Resolved::Compute(t) => {
                t.publish(toy_dataset(12, 1.0),
                          Arc::new((0..12).collect()));
            }
            Resolved::Ready(_) => panic!("empty store cannot hit"),
        }
        match store.begin_as(fp, 9) {
            Resolved::Ready(a) => assert_eq!(a.data.n, 12),
            Resolved::Compute(_) => {
                panic!("tenant 9 must be served tenant 7's fit")
            }
        }
        assert!(store.lookup_as(fp, 9).is_some());
        let t7 = store.tenant_stats(7);
        let t9 = store.tenant_stats(9);
        assert_eq!((t7.hits, t7.coalesced, t7.misses), (0, 0, 1));
        assert_eq!((t9.hits, t9.coalesced, t9.misses), (2, 0, 0));
        assert_eq!(store.tenant_stats(42), FeTenantStats::default(),
                   "unknown tenants read as zero");
        // the global counters are the sum of the tenant slices
        let st = store.stats();
        assert_eq!(st.hits, t7.hits + t9.hits);
        assert_eq!(st.misses, t7.misses + t9.misses);
        assert_eq!(t9.served(), 2);
        assert_eq!(t7.total(), 1);
    }

    #[test]
    fn legacy_untagged_calls_count_as_tenant_zero() {
        let store = FeStore::new(1 << 20);
        let fp = fp_of("untagged");
        publish(&store, fp, 8);
        assert!(store.lookup(fp).is_some());
        let t0 = store.tenant_stats(0);
        assert_eq!((t0.hits, t0.misses), (1, 1));
    }

    #[test]
    fn abandoned_computation_wakes_waiters_to_compute() {
        let store = FeStore::new(1 << 20);
        let fp = fp_of("abandoned");
        let outcomes = Mutex::new(Vec::new());
        let (store, outcomes) = (&store, &outcomes);
        std::thread::scope(|s| {
            for i in 0..4 {
                s.spawn(move || match store.begin(fp) {
                    Resolved::Ready(a) => {
                        lock(&outcomes).push(("ready", a.data.n));
                    }
                    Resolved::Compute(t) => {
                        if i == 0 {
                            std::thread::sleep(
                                Duration::from_millis(20));
                            drop(t); // identity: abandon
                            lock(&outcomes).push(("abandon", 0));
                        } else {
                            t.publish(toy_dataset(5, 3.0),
                                      Arc::new(vec![0]));
                            lock(&outcomes).push(("compute", 5));
                        }
                    }
                });
            }
        });
        // nobody hung, and every thread resolved one way or another
        assert_eq!(lock(&outcomes).len(), 4);
    }
}
