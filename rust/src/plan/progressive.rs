//! Progressive optimization (§4.3): a top-down pass through the plan-5
//! tree — first pick the best algorithm with everything else at
//! defaults, then optimise feature engineering under the chosen
//! algorithm, then its hyper-parameters. High exploration efficiency,
//! but risks committing to the wrong algorithm and yields a
//! low-diversity model pool (Table 11 quantifies both).

use anyhow::Result;

use crate::blocks::{Env, Objective};
use crate::opt::{Optimizer, SmacBo};
use crate::space::{Config, ConfigSpace, Value};

use super::PlanBuilder;

pub struct ProgressiveResult {
    pub best: Option<(Config, f64)>,
    pub chosen_algorithm: Option<String>,
    pub history: Vec<(Config, f64)>,
}

/// Run the progressive strategy. Budget is whatever the objective
/// allows; the FE and HP phases split the remaining evaluations
/// roughly in half.
pub fn run_progressive(builder: &PlanBuilder, env: &mut Env,
                       fe_phase_evals: usize, hp_phase_evals: usize)
    -> Result<ProgressiveResult> {
    let mut history: Vec<(Config, f64)> = Vec::new();
    let mut track = |cfg: Config, y: f64,
                     history: &mut Vec<(Config, f64)>| {
        history.push((cfg, y));
    };

    // ---- phase 1: try each algorithm at defaults -------------------
    // every arm's default config is independent, so they fan out
    // across the worker pool in `Env::batch`-sized chunks (a batch of
    // 1 reproduces the original per-algorithm serial loop, including
    // its between-algorithm budget checks)
    let fe_default = builder.fe_space().default_config();
    let mut best_algo: Option<(String, f64)> = None;
    let algos = builder.algo_values();
    let mut idx = 0;
    while idx < algos.len() && !env.obj.exhausted() {
        let k = env.batch.max(1).min(algos.len() - idx);
        let chunk = &algos[idx..idx + k];
        let reqs: Vec<(Config, f64)> = chunk
            .iter()
            .map(|algo| {
                let hp_default = builder.hp_space(algo).default_config();
                let cfg = Config::new()
                    .with("algorithm", Value::C(algo.clone()))
                    .merged(&hp_default)
                    .merged(&fe_default);
                (cfg, 1.0)
            })
            .collect();
        let ys = env.obj.evaluate_batch(&reqs)?;
        let n = ys.len();
        for ((algo, (cfg, _)), y) in chunk.iter().zip(reqs).zip(ys) {
            track(cfg, y, &mut history);
            if best_algo.as_ref().map(|(_, b)| y > *b).unwrap_or(true) {
                best_algo = Some((algo.clone(), y));
            }
        }
        if n < k {
            break; // budget exhausted mid-chunk
        }
        idx += k;
    }
    let Some((algo, _)) = best_algo.clone() else {
        return Ok(ProgressiveResult {
            best: None,
            chosen_algorithm: None,
            history,
        });
    };

    // ---- phase 2: optimise FE with the algorithm fixed -------------
    let fixed_algo = Config::new()
        .with("algorithm", Value::C(algo.clone()))
        .merged(&builder.hp_space(&algo).default_config());
    let mut best_fe = fe_default.clone();
    {
        let mut bo = SmacBo::new(builder.fe_space(), builder.seed ^ 0xFE);
        run_bo_phase(&mut bo, &fixed_algo, fe_phase_evals, env,
                     &mut history)?;
        if let Some((cfg, _)) = bo.best() {
            best_fe = cfg.clone();
        }
    }

    // ---- phase 3: optimise HPs with algorithm + FE fixed ------------
    let hp_space: ConfigSpace = builder.hp_space(&algo);
    if !hp_space.is_empty() {
        let fixed = Config::new()
            .with("algorithm", Value::C(algo.clone()))
            .merged(&best_fe);
        let mut bo = SmacBo::new(hp_space, builder.seed ^ 0x4B);
        run_bo_phase(&mut bo, &fixed, hp_phase_evals, env,
                     &mut history)?;
    }

    let best = history
        .iter()
        .cloned()
        .max_by(|a, b| a.1.partial_cmp(&b.1)
            .unwrap_or(std::cmp::Ordering::Equal));
    Ok(ProgressiveResult { best, chosen_algorithm: Some(algo), history })
}

/// One batched BO phase of the progressive strategy: propose
/// `Env::batch`-sized chunks (clamped to the phase budget) until the
/// phase or the global objective budget is exhausted. With a batch of
/// 1 this reproduces the original one-suggestion-per-step loop.
fn run_bo_phase(bo: &mut SmacBo, fixed: &Config, phase_evals: usize,
                env: &mut Env, history: &mut Vec<(Config, f64)>)
    -> Result<()> {
    let mut done = 0;
    while done < phase_evals && !env.obj.exhausted() {
        let k = env.batch.max(1).min(phase_evals - done);
        let subs = bo.suggest_batch(env.rng, k);
        let reqs: Vec<(Config, f64)> = subs
            .iter()
            .map(|s| (fixed.merged(s), 1.0))
            .collect();
        let ys = env.obj.evaluate_batch(&reqs)?;
        let n = ys.len();
        for ((sub, (full, _)), y) in
            subs.into_iter().zip(reqs).zip(ys) {
            bo.observe(sub, y);
            history.push((full, y));
        }
        if n == 0 {
            break; // budget exhausted mid-batch
        }
        done += n;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::EngineKind;
    use crate::util::rng::Rng;

    struct Synth {
        evals: usize,
        cap: usize,
    }
    impl Objective for Synth {
        fn evaluate(&mut self, cfg: &Config, _f: f64) -> Result<f64> {
            self.evals += 1;
            let d = cfg.f64_or("alg.tree:depth", 0.5);
            let frac = cfg.f64_or("fe:frac", 0.5);
            Ok(match cfg.str_or("algorithm", "tree") {
                "tree" => 0.6 - (d - 0.9).powi(2) - (frac - 0.2).powi(2),
                _ => 0.2,
            })
        }
        fn exhausted(&self) -> bool {
            self.evals >= self.cap
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::new()
            .cat("algorithm", &["tree", "linear"], "linear")
            .float("alg.tree:depth", 0.0, 1.0, 0.5)
            .when("algorithm", &["tree"])
            .float("fe:frac", 0.0, 1.0, 0.5)
    }

    #[test]
    fn progressive_picks_algo_then_improves() {
        let sp = space();
        let builder = PlanBuilder::new(&sp, EngineKind::Bo, 7);
        let mut obj = Synth { evals: 0, cap: 120 };
        let mut rng = Rng::new(7);
        let mut env = Env::new(&mut obj, &mut rng);
        let res = run_progressive(&builder, &mut env, 40, 40).unwrap();
        assert_eq!(res.chosen_algorithm.as_deref(), Some("tree"));
        let (cfg, y) = res.best.unwrap();
        assert!(y > 0.45, "best={y}");
        assert_eq!(cfg.str_or("algorithm", ""), "tree");
        // phase-1 history contains both default-algo probes
        assert!(res.history.len() >= 2);
    }

    #[test]
    fn progressive_respects_budget() {
        let sp = space();
        let builder = PlanBuilder::new(&sp, EngineKind::Bo, 8);
        let mut obj = Synth { evals: 0, cap: 10 };
        let mut rng = Rng::new(8);
        let mut env = Env::new(&mut obj, &mut rng);
        let res = run_progressive(&builder, &mut env, 40, 40).unwrap();
        assert!(res.history.len() <= 10);
    }
}
