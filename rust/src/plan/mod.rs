//! VolcanoML execution plans (§4): trees of building blocks over the
//! joint AutoML space, executed Volcano-style (`do_next!` propagating
//! root -> leaf).
//!
//! Space conventions (built by `coordinator::joint_space`):
//! * `algorithm` — categorical over the arm names;
//! * `alg.<name>:<hp>` — per-algorithm hyper-parameters, conditional
//!   on `algorithm == name`;
//! * `fe:<stage>` / `fe:<stage>.<op>:<hp>` — FE pipeline parameters.
//!
//! The five coarse-grained plans of §4.2 / Fig 6 are implemented:
//! J, C, A, AC and CA (the paper's default, Fig 4), plus the
//! progressive top-down strategy of §4.3.

pub mod progressive;

use anyhow::Result;

use crate::blocks::{
    AlternatingBlock, Arm, BuildingBlock, ConditioningBlock, Env,
    JointBlock, JointEngine,
};
use crate::opt::multifidelity::HyperbandFamily;
use crate::opt::{Evolutionary, RandomSearch, SmacBo};
use crate::space::{Config, ConfigSpace, Domain, Value};
use crate::surrogate::Surrogate;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanKind {
    /// Plan 1 — single joint block over the entire space.
    J,
    /// Plan 2 — conditioning on algorithm, joint subspaces.
    C,
    /// Plan 3 — alternating FE <-> CASH.
    A,
    /// Plan 4 — alternating FE <-> (conditioning on algorithm).
    AC,
    /// Plan 5 — conditioning on algorithm, then alternating FE <-> HP
    /// (the VolcanoML default).
    CA,
    /// Nested decomposition — conditioning on algorithm, then
    /// conditioning on the first categorical FE stage, joint leaves
    /// over the remaining FE + HP subspace. Not one of the paper's
    /// five coarse plans; it exercises the recursive propose/observe
    /// contract (blocks compose arbitrarily, §3.2), so the unified
    /// scheduler's cross-level batching is visible on a plan whose
    /// elimination runs at *two* depths.
    CC,
}

impl PlanKind {
    pub fn parse(s: &str) -> Option<PlanKind> {
        Some(match s.to_ascii_uppercase().as_str() {
            "J" | "PLAN1" | "1" => PlanKind::J,
            "C" | "PLAN2" | "2" => PlanKind::C,
            "A" | "PLAN3" | "3" => PlanKind::A,
            "AC" | "PLAN4" | "4" => PlanKind::AC,
            "CA" | "PLAN5" | "5" => PlanKind::CA,
            "CC" | "PLAN6" | "6" => PlanKind::CC,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlanKind::J => "J",
            PlanKind::C => "C",
            PlanKind::A => "A",
            PlanKind::AC => "AC",
            PlanKind::CA => "CA",
            PlanKind::CC => "CC",
        }
    }

    /// The paper's five coarse-grained plans (§4.2 / Fig 6).
    pub fn all() -> [PlanKind; 5] {
        [PlanKind::J, PlanKind::C, PlanKind::A, PlanKind::AC,
         PlanKind::CA]
    }

    /// The five coarse plans plus the nested-decomposition variant
    /// ([`PlanKind::CC`]) exercised by the unified-scheduler tests
    /// and benches.
    pub fn with_nested() -> [PlanKind; 6] {
        [PlanKind::J, PlanKind::C, PlanKind::A, PlanKind::AC,
         PlanKind::CA, PlanKind::CC]
    }
}

/// Engine used by every leaf joint block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Bo,
    Random,
    /// TPOT-style evolutionary engine.
    Evolutionary,
    Hyperband,
    Bohb,
    MfesHb,
    SuccessiveHalving,
}

/// Builds plan trees over a joint space. Meta-learning hooks:
/// `arm_filter` restricts conditioning arms (RankNet pruning, §5.1);
/// `surrogate_factory` injects per-leaf surrogates (RGPE, §5.2).
pub struct PlanBuilder<'a> {
    pub space: &'a ConfigSpace,
    pub engine: EngineKind,
    pub seed: u64,
    pub arm_filter: Option<Vec<String>>,
    #[allow(clippy::type_complexity)]
    pub surrogate_factory:
        Option<&'a dyn Fn(&str, &ConfigSpace) -> Option<Box<dyn Surrogate>>>,
}

impl<'a> PlanBuilder<'a> {
    pub fn new(space: &'a ConfigSpace, engine: EngineKind, seed: u64)
        -> PlanBuilder<'a> {
        PlanBuilder {
            space,
            engine,
            seed,
            arm_filter: None,
            surrogate_factory: None,
        }
    }

    /// Algorithm values (optionally pruned by the meta-learned filter).
    pub fn algo_values(&self) -> Vec<String> {
        let all = match self.space.param("algorithm").map(|p| &p.domain) {
            Some(Domain::Cat(vals)) => vals.clone(),
            _ => Vec::new(),
        };
        match &self.arm_filter {
            Some(keep) => all
                .into_iter()
                .filter(|a| keep.contains(a))
                .collect(),
            None => all,
        }
    }

    pub fn fe_space(&self) -> ConfigSpace {
        self.space.subspace_prefixed("fe:")
    }

    pub fn hp_space(&self, algo: &str) -> ConfigSpace {
        self.space.subspace_prefixed(&format!("alg.{algo}:"))
    }

    /// CASH space: algorithm selection + all conditional HPs.
    pub fn cash_space(&self) -> ConfigSpace {
        let names: Vec<&str> = self
            .space
            .params
            .iter()
            .filter(|p| p.name == "algorithm"
                || p.name.starts_with("alg."))
            .map(|p| p.name.as_str())
            .collect();
        let mut sub = self.space.subspace(&names);
        if let Some(filter) = &self.arm_filter {
            for p in &mut sub.params {
                if p.name == "algorithm" {
                    if let Domain::Cat(vals) = &mut p.domain {
                        vals.retain(|v| filter.contains(v));
                        if let Value::C(d) = &p.default {
                            if !vals.contains(d) && !vals.is_empty() {
                                p.default = Value::C(vals[0].clone());
                            }
                        }
                    }
                }
            }
        }
        sub
    }

    fn leaf(&self, label: &str, sub: ConfigSpace, fixed: Config,
            salt: u64) -> JointBlock {
        let seed = self.seed ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
        let engine = match self.engine {
            EngineKind::Bo => {
                let bo = match &self.surrogate_factory {
                    Some(f) => match f(label, &sub) {
                        Some(s) => SmacBo::with_surrogate(sub.clone(), s),
                        None => SmacBo::new(sub.clone(), seed),
                    },
                    None => SmacBo::new(sub.clone(), seed),
                };
                JointEngine::Bo(bo)
            }
            EngineKind::Random => {
                JointEngine::Random(RandomSearch::new(sub.clone()))
            }
            EngineKind::Evolutionary => {
                JointEngine::Evo(Evolutionary::new(sub.clone()))
            }
            EngineKind::Hyperband => JointEngine::Mf(
                HyperbandFamily::hyperband(sub.clone(), seed)),
            EngineKind::Bohb => JointEngine::Mf(
                HyperbandFamily::bohb(sub.clone(), seed)),
            EngineKind::MfesHb => JointEngine::Mf(
                HyperbandFamily::mfes_hb(sub.clone(), seed)),
            EngineKind::SuccessiveHalving => JointEngine::Mf(
                HyperbandFamily::successive_halving(sub.clone(), seed)),
        };
        JointBlock::with_engine(label, sub, fixed, engine)
    }

    /// Per-algorithm alternating block: FE <-> HP (Fig 4 subtree).
    fn alt_fe_hp(&self, algo: &str, salt: u64) -> Box<dyn BuildingBlock> {
        let fe = self.fe_space();
        let hp = self.hp_space(algo);
        let algo_fix = Config::new()
            .with("algorithm", Value::C(algo.to_string()));
        let fe_fixed = algo_fix.merged(&hp.default_config());
        let hp_fixed = algo_fix.merged(&fe.default_config());
        if hp.is_empty() {
            return Box::new(self.leaf(
                &format!("fe|{algo}"), fe, fe_fixed, salt));
        }
        let b_fe = self.leaf(&format!("fe|{algo}"), fe.clone(), fe_fixed,
                             salt * 2 + 1);
        let b_hp = self.leaf(&format!("hp|{algo}"), hp.clone(), hp_fixed,
                             salt * 2 + 2);
        let fe_vars: Vec<String> =
            fe.params.iter().map(|p| p.name.clone()).collect();
        let hp_vars: Vec<String> =
            hp.params.iter().map(|p| p.name.clone()).collect();
        Box::new(AlternatingBlock::new(
            Box::new(b_fe), fe_vars, Box::new(b_hp), hp_vars))
    }

    pub fn build(&self, kind: PlanKind) -> Box<dyn BuildingBlock> {
        match kind {
            PlanKind::J => {
                let mut sub = self.space.clone();
                if self.arm_filter.is_some() {
                    // prune algorithm domain in place
                    sub = self.prune_space(sub);
                }
                Box::new(self.leaf("full", sub, Config::new(), 1))
            }
            PlanKind::C => {
                let arms = self
                    .algo_values()
                    .iter()
                    .enumerate()
                    .map(|(i, a)| {
                        let mut sub = self.fe_space();
                        sub = merge_spaces(sub, self.hp_space(a));
                        let fixed = Config::new().with(
                            "algorithm", Value::C(a.clone()));
                        Arm {
                            value: a.clone(),
                            block: Box::new(self.leaf(
                                &format!("fe+hp|{a}"), sub, fixed,
                                100 + i as u64)),
                            active: true,
                        }
                    })
                    .collect();
                Box::new(ConditioningBlock::new("algorithm", arms))
            }
            PlanKind::A => {
                let fe = self.fe_space();
                let cash = self.cash_space();
                let fe_fixed = cash.default_config();
                let cash_fixed = fe.default_config();
                let b_fe = self.leaf("fe", fe.clone(), fe_fixed, 11);
                let b_cash =
                    self.leaf("cash", cash.clone(), cash_fixed, 12);
                let fe_vars: Vec<String> =
                    fe.params.iter().map(|p| p.name.clone()).collect();
                let cash_vars: Vec<String> =
                    cash.params.iter().map(|p| p.name.clone()).collect();
                Box::new(AlternatingBlock::new(
                    Box::new(b_fe), fe_vars,
                    Box::new(b_cash), cash_vars))
            }
            PlanKind::AC => {
                let fe = self.fe_space();
                let fe_fixed = self.cash_space().default_config();
                let b_fe = self.leaf("fe", fe.clone(), fe_fixed, 21);
                let arms = self
                    .algo_values()
                    .iter()
                    .enumerate()
                    .map(|(i, a)| {
                        let hp = self.hp_space(a);
                        let fixed = Config::new()
                            .with("algorithm", Value::C(a.clone()))
                            .merged(&fe.default_config());
                        Arm {
                            value: a.clone(),
                            block: Box::new(self.leaf(
                                &format!("hp|{a}"), hp, fixed,
                                200 + i as u64)),
                            active: true,
                        }
                    })
                    .collect();
                let mut cond = ConditioningBlock::new("algorithm", arms);
                // inner conditioning plays fewer rounds per pull so the
                // alternation stays responsive
                cond.plays_per_round = 1;
                let fe_vars: Vec<String> =
                    fe.params.iter().map(|p| p.name.clone()).collect();
                let cash_vars: Vec<String> = self
                    .cash_space()
                    .params
                    .iter()
                    .map(|p| p.name.clone())
                    .collect();
                Box::new(AlternatingBlock::new(
                    Box::new(b_fe), fe_vars,
                    Box::new(cond), cash_vars))
            }
            PlanKind::CA => {
                Box::new(ConditioningBlock::new("algorithm",
                                                self.ca_arms()))
            }
            PlanKind::CC => {
                let arms = self
                    .algo_values()
                    .iter()
                    .enumerate()
                    .map(|(i, a)| Arm {
                        value: a.clone(),
                        block: self.cc_inner(a, 400 + i as u64),
                        active: true,
                    })
                    .collect();
                Box::new(ConditioningBlock::new("algorithm", arms))
            }
        }
    }

    /// The CA plan's conditioning arms (public so continue-tuning
    /// drivers can extend a live block with new algorithms, §3.3.6).
    pub fn ca_arms(&self) -> Vec<Arm> {
        self.algo_values()
            .iter()
            .enumerate()
            .map(|(i, a)| Arm {
                value: a.clone(),
                block: self.alt_fe_hp(a, 300 + i as u64),
                active: true,
            })
            .collect()
    }

    /// The FE subspace with categorical stage `var` fixed to `val`:
    /// the stage parameter itself is dropped (it rides in the arm's
    /// `fixed` config) and the per-op parameters of the *other* ops
    /// of that stage — inactive under `val` — are dropped with it.
    fn cc_leaf_space(&self, var: &str, val: &str) -> ConfigSpace {
        let fe = self.fe_space();
        let mut out = ConfigSpace::new();
        for p in &fe.params {
            if p.name == var {
                continue;
            }
            let mut q = p.clone();
            if let Some(c) = &q.condition {
                if c.parent == var {
                    if !c.values.iter().any(|v| v == val) {
                        continue;
                    }
                    q.condition = None;
                }
            }
            out.params.push(q);
        }
        out
    }

    /// Inner conditioning block of the nested CC plan: conditions on
    /// the first multi-valued categorical FE stage under a fixed
    /// algorithm, with joint leaves over the remaining FE + HP
    /// subspace. Falls back to plan C's joint leaf when the FE space
    /// offers no categorical stage to nest on.
    fn cc_inner(&self, algo: &str, salt: u64)
        -> Box<dyn BuildingBlock> {
        let fe = self.fe_space();
        let nested = fe.params.iter().find(|p| {
            p.condition.is_none()
                && matches!(&p.domain, Domain::Cat(vals)
                            if vals.len() >= 2)
        });
        let Some(nested) = nested else {
            let sub = merge_spaces(self.fe_space(),
                                   self.hp_space(algo));
            let fixed = Config::new()
                .with("algorithm", Value::C(algo.to_string()));
            return Box::new(self.leaf(&format!("fe+hp|{algo}"), sub,
                                      fixed, salt));
        };
        let var = nested.name.clone();
        let vals = match &nested.domain {
            Domain::Cat(vals) => vals.clone(),
            _ => unreachable!("matched Cat above"),
        };
        let arms = vals
            .iter()
            .enumerate()
            .map(|(j, v)| {
                let sub = merge_spaces(self.cc_leaf_space(&var, v),
                                       self.hp_space(algo));
                let fixed = Config::new()
                    .with("algorithm", Value::C(algo.to_string()))
                    .with(&var, Value::C(v.clone()));
                Arm {
                    value: v.clone(),
                    block: Box::new(self.leaf(
                        &format!("{var}={v}|{algo}"), sub, fixed,
                        salt * 37 + j as u64)),
                    active: true,
                }
            })
            .collect();
        let mut inner = ConditioningBlock::new(&var, arms);
        // short inner rounds keep the outer elimination responsive
        // (same choice as the AC plan's nested conditioning)
        inner.plays_per_round = 1;
        Box::new(inner)
    }

    fn prune_space(&self, mut space: ConfigSpace) -> ConfigSpace {
        if let Some(filter) = &self.arm_filter {
            for p in &mut space.params {
                if p.name == "algorithm" {
                    if let Domain::Cat(vals) = &mut p.domain {
                        vals.retain(|v| filter.contains(v));
                        if let Value::C(d) = &p.default {
                            if !vals.contains(d) && !vals.is_empty() {
                                p.default = Value::C(vals[0].clone());
                            }
                        }
                    }
                }
            }
        }
        space
    }
}

fn merge_spaces(mut a: ConfigSpace, b: ConfigSpace) -> ConfigSpace {
    a.params.extend(b.params);
    a
}

/// Top-level executor: repeatedly invokes the root's `do_next!` until
/// the objective's budget is exhausted.
pub struct ExecutionPlan {
    pub root: Box<dyn BuildingBlock>,
    pub iterations: usize,
}

impl ExecutionPlan {
    pub fn new(root: Box<dyn BuildingBlock>) -> ExecutionPlan {
        ExecutionPlan { root, iterations: 0 }
    }

    pub fn run(&mut self, env: &mut Env) -> Result<()> {
        while !env.obj.exhausted() {
            self.root.do_next(env)?;
            self.iterations += 1;
        }
        Ok(())
    }

    pub fn best(&self) -> Option<(Config, f64)> {
        self.root.current_best()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::Objective;

    /// Joint space shaped like the AutoML convention.
    fn automl_like_space() -> ConfigSpace {
        ConfigSpace::new()
            .cat("algorithm", &["tree", "linear"], "tree")
            .float("alg.tree:depth", 0.0, 1.0, 0.5)
            .when("algorithm", &["tree"])
            .float("alg.linear:reg", 0.0, 1.0, 0.5)
            .when("algorithm", &["linear"])
            .cat("fe:scaler", &["none", "standard"], "none")
            .float("fe:frac", 0.0, 1.0, 0.5)
    }

    struct Synth {
        evals: usize,
        cap: usize,
    }

    impl Objective for Synth {
        fn evaluate(&mut self, cfg: &Config, _f: f64)
            -> Result<f64> {
            self.evals += 1;
            let fe_bonus = if cfg.str_or("fe:scaler", "none")
                == "standard" { 0.2 } else { 0.0 };
            let frac = cfg.f64_or("fe:frac", 0.5);
            Ok(match cfg.str_or("algorithm", "tree") {
                "tree" => {
                    let d = cfg.f64_or("alg.tree:depth", 0.5);
                    0.5 + fe_bonus - (d - 0.8).powi(2)
                        - 0.1 * (frac - 0.3).powi(2)
                }
                _ => {
                    let r = cfg.f64_or("alg.linear:reg", 0.5);
                    0.3 + fe_bonus - (r - 0.5).powi(2)
                }
            })
        }
        fn exhausted(&self) -> bool {
            self.evals >= self.cap
        }
    }

    #[test]
    fn plan_kind_parsing() {
        assert_eq!(PlanKind::parse("ca"), Some(PlanKind::CA));
        assert_eq!(PlanKind::parse("Plan1"), Some(PlanKind::J));
        assert_eq!(PlanKind::parse("cc"), Some(PlanKind::CC));
        assert_eq!(PlanKind::parse("Plan6"), Some(PlanKind::CC));
        assert_eq!(PlanKind::parse("xx"), None);
        // the paper's five coarse plans, plus the nested variant
        assert_eq!(PlanKind::all().len(), 5);
        assert_eq!(PlanKind::with_nested().len(), 6);
        assert!(!PlanKind::all().contains(&PlanKind::CC));
    }

    #[test]
    fn subspace_helpers_split_by_prefix() {
        let space = automl_like_space();
        let b = PlanBuilder::new(&space, EngineKind::Bo, 0);
        assert_eq!(b.fe_space().len(), 2);
        assert_eq!(b.hp_space("tree").len(), 1);
        assert_eq!(b.cash_space().len(), 3);
        assert_eq!(b.algo_values(), vec!["tree", "linear"]);
    }

    #[test]
    fn all_plans_find_the_good_region() {
        let space = automl_like_space();
        for kind in PlanKind::with_nested() {
            let mut obj = Synth { evals: 0, cap: 220 };
            let mut rng = crate::util::rng::Rng::new(kind as u64);
            let builder = PlanBuilder::new(&space, EngineKind::Bo,
                                           42 + kind as u64);
            let mut plan = ExecutionPlan::new(builder.build(kind));
            {
                let mut env = Env::new(&mut obj, &mut rng);
                plan.run(&mut env).unwrap();
            }
            let (cfg, y) = plan.best()
                .unwrap_or_else(|| panic!("{}: no best", kind.name()));
            // optimum is algorithm=tree, scaler=standard, depth~0.8
            // with utility ~0.7
            assert!(y > 0.55, "{}: best={y}", kind.name());
            assert_eq!(cfg.str_or("algorithm", ""), "tree",
                       "{}", kind.name());
        }
    }

    #[test]
    fn ca_plan_structure_matches_fig4() {
        let space = automl_like_space();
        let builder = PlanBuilder::new(&space, EngineKind::Bo, 1);
        let root = builder.build(PlanKind::CA);
        assert!(root.name().starts_with("conditioning"));
        assert_eq!(root.active_children(), 2);
    }

    #[test]
    fn cc_plan_nests_conditioning_inside_conditioning() {
        let space = automl_like_space();
        let builder = PlanBuilder::new(&space, EngineKind::Bo, 1);
        let mut root = builder.build(PlanKind::CC);
        assert!(root.name().starts_with("conditioning[algorithm]"));
        assert_eq!(root.active_children(), 2);
        let cond = root
            .as_any_mut()
            .downcast_mut::<ConditioningBlock>()
            .expect("CC root is a conditioning block");
        for arm in &mut cond.arms {
            // each algorithm arm conditions on fe:scaler (the first
            // categorical FE stage of the test space)
            assert!(arm.block.name()
                        .starts_with("conditioning[fe:scaler]"),
                    "{}", arm.block.name());
            assert_eq!(arm.block.active_children(), 2);
            // the whole tree can split pulls: a gathering parent may
            // batch across both decomposition levels
            assert!(arm.block.supports_propose());
        }
    }

    #[test]
    fn arm_filter_prunes_conditioning_arms() {
        let space = automl_like_space();
        let mut builder = PlanBuilder::new(&space, EngineKind::Bo, 2);
        builder.arm_filter = Some(vec!["linear".to_string()]);
        let root = builder.build(PlanKind::CA);
        assert_eq!(root.active_children(), 1);
        // and plan J's algorithm domain is pruned too
        let j = builder.build(PlanKind::J);
        let mut obj = Synth { evals: 0, cap: 30 };
        let mut rng = crate::util::rng::Rng::new(3);
        let mut plan = ExecutionPlan::new(j);
        {
            let mut env = Env::new(&mut obj, &mut rng);
            plan.run(&mut env).unwrap();
        }
        let (cfg, _) = plan.best().unwrap();
        assert_eq!(cfg.str_or("algorithm", ""), "linear");
    }

    #[test]
    fn mf_engines_build_and_run() {
        let space = automl_like_space();
        for engine in [EngineKind::Hyperband, EngineKind::MfesHb,
                       EngineKind::Bohb, EngineKind::SuccessiveHalving,
                       EngineKind::Random] {
            let builder = PlanBuilder::new(&space, engine, 4);
            let mut plan = ExecutionPlan::new(builder.build(PlanKind::J));
            let mut obj = Synth { evals: 0, cap: 80 };
            let mut rng = crate::util::rng::Rng::new(5);
            {
                let mut env = Env::new(&mut obj, &mut rng);
                plan.run(&mut env).unwrap();
            }
            assert!(plan.best().is_some(), "{engine:?}");
        }
    }
}
