//! Minimal CLI argument parser (no `clap` offline).
//!
//! Grammar: `volcanoml <subcommand> [--key value | --flag] [positional]`.
//! Typed getters with defaults; unknown-flag detection so typos fail
//! loudly instead of silently using defaults.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    consumed: std::cell::RefCell<BTreeSet<String>>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue { key: String, val: String, why: String },
    Unknown(Vec<String>),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => {
                write!(f, "missing value for option --{k}")
            }
            CliError::BadValue { key, val, why } => {
                write!(f, "invalid value for --{key}: {val:?} ({why})")
            }
            CliError::Unknown(keys) => {
                write!(f, "unknown options: {keys:?} (see --help)")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw args (not including argv[0]). Options may appear
    /// before or after positionals. `--key=value` and `--key value`
    /// both work; a `--key` followed by another `--...` or end-of-args
    /// is a boolean flag.
    pub fn parse(raw: &[String]) -> Result<Args, CliError> {
        let mut a = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else {
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            a.opts.insert(body.to_string(), v.clone());
                        }
                        _ => {
                            a.flags.insert(body.to_string());
                        }
                    }
                }
            } else if a.subcommand.is_none() && a.positional.is_empty() {
                a.subcommand = Some(tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args, CliError> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().insert(key.to_string());
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains(key)
            || self.opts.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize)
        -> Result<usize, CliError> {
        self.typed_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        self.typed_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        self.typed_or(key, default)
    }

    fn typed_or<T: std::str::FromStr>(&self, key: &str, default: T)
        -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| CliError::BadValue {
                key: key.to_string(),
                val: v.clone(),
                why: e.to_string(),
            }),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        self.mark(key);
        match self.opts.get(key) {
            Some(v) if !v.is_empty() => {
                v.split(',').map(|s| s.trim().to_string()).collect()
            }
            _ => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Call after all getters: errors on any option/flag that was never
    /// consumed (catches typos like `--buget`).
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(*k) && *k != "help")
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(unknown))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["run", "--dataset", "quake", "--budget", "60",
                        "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.str_or("dataset", "x"), "quake");
        assert_eq!(a.usize_or("budget", 0).unwrap(), 60);
        assert!(a.flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_form_and_lists() {
        let a = parse(&["bench", "--systems=volcano,ausk, tpot"]);
        assert_eq!(a.list_or("systems", &[]),
                   vec!["volcano", "ausk", "tpot"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["run"]);
        assert_eq!(a.f64_or("frac", 0.8).unwrap(), 0.8);
        assert!(!a.flag("meta"));
    }

    #[test]
    fn bad_numeric_value_errors() {
        let a = parse(&["run", "--budget", "soon"]);
        assert!(a.usize_or("budget", 1).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse(&["run", "--buget", "10"]);
        let _ = a.str_or("dataset", "d");
        assert!(matches!(a.finish(), Err(CliError::Unknown(v)) if v == ["buget"]));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["run", "--meta"]);
        assert!(a.flag("meta"));
    }

    #[test]
    fn serve_subcommand_options_parse() {
        let a = parse(&["serve", "--workers", "8", "--fe-cache-mb",
                        "128", "--max-active", "3", "--pending-cap",
                        "5"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("workers", 4).unwrap(), 8);
        assert_eq!(a.usize_or("fe-cache-mb", 256).unwrap(), 128);
        assert_eq!(a.usize_or("max-active", 4).unwrap(), 3);
        assert_eq!(a.usize_or("pending-cap", 16).unwrap(), 5);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn serve_job_spec_line_round_trips_through_json() {
        // the serve wire format: one JSON job spec per stdin line;
        // parse -> serialise -> parse must be the identity
        use crate::service::JobSpec;
        use crate::util::json::Json;
        let line = r#"{"name": "t", "dataset": "quake", "weight": 2,
                       "plan": "CC", "scale": "small",
                       "metric": "accuracy", "evals": 12,
                       "eval_batch": 3, "super_batch": 0,
                       "pipeline_depth": 2, "seed": 7,
                       "ensemble": true}"#;
        let spec = JobSpec::from_json(&Json::parse(line).unwrap())
            .unwrap();
        assert_eq!(spec.weight, 2);
        assert_eq!(spec.max_evals, 12);
        assert_eq!(spec.pipeline_depth, 2);
        let round = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, round);
        // and the serialised form itself is stable
        assert_eq!(spec.to_json().to_string(),
                   round.to_json().to_string());
    }
}
