//! Core dataset types: a dense row-major feature matrix with labels,
//! train/valid/test splits, and the prediction container shared by all
//! algorithms (native and PJRT-backed).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// `n_classes` live classes, labels are 0..n_classes.
    Classification { n_classes: usize },
    Regression,
}

impl Task {
    pub fn is_classification(&self) -> bool {
        matches!(self, Task::Classification { .. })
    }
    pub fn n_classes(&self) -> usize {
        match self {
            Task::Classification { n_classes } => *n_classes,
            Task::Regression => 0,
        }
    }
}

/// Dense dataset; `x` is row-major `n * d`, labels are class indices
/// (as f32) for classification or target values for regression.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    pub n: usize,
    pub d: usize,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn new(name: &str, task: Task, d: usize) -> Dataset {
        Dataset { name: name.to_string(), task, n: 0, d, x: Vec::new(),
                  y: Vec::new() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    pub fn push_row(&mut self, row: &[f32], y: f32) {
        assert_eq!(row.len(), self.d, "row width mismatch");
        self.x.extend_from_slice(row);
        self.y.push(y);
        self.n += 1;
    }

    pub fn label(&self, i: usize) -> usize {
        debug_assert!(self.task.is_classification());
        self.y[i] as usize
    }

    /// Rows selected by index (allows repetition — used by balancers
    /// and bootstrap sampling).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut out = Dataset::new(&self.name, self.task, self.d);
        out.x.reserve(idx.len() * self.d);
        out.y.reserve(idx.len());
        for &i in idx {
            out.x.extend_from_slice(self.row(i));
            out.y.push(self.y[i]);
        }
        out.n = idx.len();
        out
    }

    /// Class frequency histogram (classification only).
    pub fn class_counts(&self) -> Vec<usize> {
        let k = self.task.n_classes();
        let mut counts = vec![0usize; k];
        for &y in &self.y {
            let c = y as usize;
            if c < k {
                counts[c] += 1;
            }
        }
        counts
    }

    /// Column mean/std over given rows (used by meta-features & FE).
    pub fn col_stats(&self, rows: &[usize]) -> (Vec<f64>, Vec<f64>) {
        let mut mean = vec![0.0f64; self.d];
        let mut var = vec![0.0f64; self.d];
        let n = rows.len().max(1) as f64;
        for &i in rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                mean[j] += v as f64;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        for &i in rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                let dlt = v as f64 - mean[j];
                var[j] += dlt * dlt;
            }
        }
        let std: Vec<f64> = var.iter().map(|v| (v / n).sqrt()).collect();
        (mean, std)
    }
}

/// Index-based split. `train` is what pipelines fit on, `valid` drives
/// the search utility, `test` is only touched for final reporting.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<usize>,
    pub valid: Vec<usize>,
    pub test: Vec<usize>,
}

impl Split {
    /// The paper's protocol: 4/5 for search (of which an inner
    /// validation fifth drives utility), 1/5 held-out test.
    pub fn standard(n: usize, rng: &mut Rng) -> Split {
        let mut perm = rng.permutation(n);
        let n_test = n / 5;
        let test = perm.split_off(n - n_test);
        let n_valid = perm.len() / 5;
        let valid = perm.split_off(perm.len() - n_valid);
        Split { train: perm, valid, test }
    }

    /// Stratified variant keeping class proportions in every part
    /// (classification); falls back to `standard` for regression.
    pub fn stratified(ds: &Dataset, rng: &mut Rng) -> Split {
        if !ds.task.is_classification() {
            return Split::standard(ds.n, rng);
        }
        let k = ds.task.n_classes();
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); k];
        for i in 0..ds.n {
            by_class[ds.label(i).min(k - 1)].push(i);
        }
        let (mut train, mut valid, mut test) =
            (Vec::new(), Vec::new(), Vec::new());
        for mut members in by_class {
            rng.shuffle(&mut members);
            let n_test = members.len() / 5;
            let t = members.split_off(members.len() - n_test);
            let n_valid = members.len() / 5;
            let v = members.split_off(members.len() - n_valid);
            test.extend(t);
            valid.extend(v);
            train.extend(members);
        }
        rng.shuffle(&mut train);
        rng.shuffle(&mut valid);
        rng.shuffle(&mut test);
        Split { train, valid, test }
    }

    /// k-fold split of the *search* portion (train+valid), used by
    /// cross-validation utilities.
    pub fn kfold(n: usize, k: usize, rng: &mut Rng) -> Vec<(Vec<usize>, Vec<usize>)> {
        let perm = rng.permutation(n);
        let mut folds = Vec::with_capacity(k);
        for f in 0..k {
            let lo = n * f / k;
            let hi = n * (f + 1) / k;
            let valid: Vec<usize> = perm[lo..hi].to_vec();
            let train: Vec<usize> =
                perm[..lo].iter().chain(&perm[hi..]).copied().collect();
            folds.push((train, valid));
        }
        folds
    }
}

/// Model outputs: class scores (n x n_classes, higher = more likely)
/// or regression values.
#[derive(Clone, Debug)]
pub enum Predictions {
    ClassScores { n_classes: usize, scores: Vec<f32> },
    Values(Vec<f32>),
}

impl Predictions {
    pub fn n(&self) -> usize {
        match self {
            Predictions::ClassScores { n_classes, scores } => {
                scores.len() / n_classes.max(&1)
            }
            Predictions::Values(v) => v.len(),
        }
    }

    pub fn score_row(&self, i: usize) -> &[f32] {
        match self {
            Predictions::ClassScores { n_classes, scores } => {
                &scores[i * n_classes..(i + 1) * n_classes]
            }
            Predictions::Values(_) => panic!("not class scores"),
        }
    }

    pub fn argmax_labels(&self) -> Vec<usize> {
        match self {
            Predictions::ClassScores { n_classes, scores } => {
                let c = *n_classes;
                (0..scores.len() / c)
                    .map(|i| {
                        let row = &scores[i * c..(i + 1) * c];
                        row.iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1)
                                .unwrap_or(std::cmp::Ordering::Equal))
                            .map(|(j, _)| j)
                            .unwrap_or(0)
                    })
                    .collect()
            }
            Predictions::Values(_) => panic!("not class scores"),
        }
    }

    pub fn values(&self) -> &[f32] {
        match self {
            Predictions::Values(v) => v,
            _ => panic!("not regression values"),
        }
    }

    /// Elementwise weighted sum of predictions (ensembling substrate).
    pub fn weighted_sum(preds: &[(&Predictions, f64)]) -> Predictions {
        assert!(!preds.is_empty());
        match preds[0].0 {
            Predictions::ClassScores { n_classes, scores } => {
                let mut acc = vec![0.0f32; scores.len()];
                for (p, w) in preds {
                    match p {
                        Predictions::ClassScores { scores: s, .. } => {
                            for (a, &v) in acc.iter_mut().zip(s.iter()) {
                                *a += (*w as f32) * v;
                            }
                        }
                        _ => panic!("mixed prediction kinds"),
                    }
                }
                Predictions::ClassScores { n_classes: *n_classes,
                                           scores: acc }
            }
            Predictions::Values(v0) => {
                let mut acc = vec![0.0f32; v0.len()];
                for (p, w) in preds {
                    for (a, &v) in acc.iter_mut().zip(p.values().iter()) {
                        *a += (*w as f32) * v;
                    }
                }
                Predictions::Values(acc)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, k: usize) -> Dataset {
        let mut d = Dataset::new("toy", Task::Classification { n_classes: k }, 2);
        for i in 0..n {
            d.push_row(&[i as f32, (i * 2) as f32], (i % k) as f32);
        }
        d
    }

    #[test]
    fn rows_and_subsets() {
        let d = toy(10, 2);
        assert_eq!(d.row(3), &[3.0, 6.0]);
        let s = d.subset(&[1, 1, 4]);
        assert_eq!(s.n, 3);
        assert_eq!(s.row(0), s.row(1));
        assert_eq!(s.y[2], 0.0);
    }

    #[test]
    fn standard_split_partitions() {
        let mut rng = Rng::new(0);
        let s = Split::standard(100, &mut rng);
        assert_eq!(s.test.len(), 20);
        assert_eq!(s.valid.len(), 16);
        assert_eq!(s.train.len(), 64);
        let mut all: Vec<usize> = s.train.iter()
            .chain(&s.valid).chain(&s.test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn stratified_split_keeps_proportions() {
        let mut d = Dataset::new("im", Task::Classification { n_classes: 2 }, 1);
        for i in 0..200 {
            d.push_row(&[i as f32], if i < 180 { 0.0 } else { 1.0 });
        }
        let mut rng = Rng::new(1);
        let s = Split::stratified(&d, &mut rng);
        let minority_in_test =
            s.test.iter().filter(|&&i| d.y[i] == 1.0).count();
        assert_eq!(minority_in_test, 4); // 20 minority / 5
    }

    #[test]
    fn kfold_covers_everything_once() {
        let mut rng = Rng::new(2);
        let folds = Split::kfold(53, 5, &mut rng);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 53];
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 53);
            for &i in va {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn argmax_labels_picks_max() {
        let p = Predictions::ClassScores {
            n_classes: 3,
            scores: vec![0.1, 0.7, 0.2, 0.5, 0.2, 0.3],
        };
        assert_eq!(p.argmax_labels(), vec![1, 0]);
    }

    #[test]
    fn weighted_sum_blends() {
        let a = Predictions::Values(vec![1.0, 2.0]);
        let b = Predictions::Values(vec![3.0, 4.0]);
        let m = Predictions::weighted_sum(&[(&a, 0.5), (&b, 0.5)]);
        assert_eq!(m.values(), &[2.0, 3.0]);
    }

    #[test]
    fn class_counts_histogram() {
        let d = toy(10, 3);
        assert_eq!(d.class_counts(), vec![4, 3, 3]);
    }
}
