//! Core dataset types: a columnar feature store with `Arc`-shared
//! column chunks and labels, view-based train/valid/test splits, and
//! the prediction container shared by all algorithms (native and
//! PJRT-backed).
//!
//! # Columnar zero-copy substrate
//!
//! `Dataset` holds one `Arc<Vec<f32>>` per column plus an `Arc`-shared
//! label vector, so "sharing a column" costs a refcount bump: an FE
//! stage that touches 3 of 40 columns republishes 3 fresh columns and
//! pointer-shares the other 37 with its input (the `cache::FeStore`
//! charges only the novel ones). Splits and fidelity subsampling are
//! [`RowView`]s — index ranges over one shared permutation — instead
//! of materialised index copies.
//!
//! Determinism contract: columnar storage changes *where* values live,
//! never the values or the order any consumer combines them in, so
//! trajectories stay bit-identical to the row-major layout at every
//! worker count, chunking, and cache bound.

use std::sync::Arc;

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// `n_classes` live classes, labels are 0..n_classes.
    Classification { n_classes: usize },
    Regression,
}

impl Task {
    pub fn is_classification(&self) -> bool {
        matches!(self, Task::Classification { .. })
    }
    pub fn n_classes(&self) -> usize {
        match self {
            Task::Classification { n_classes } => *n_classes,
            Task::Regression => 0,
        }
    }
}

/// Columnar dataset; `d` feature columns of length `n` behind `Arc`
/// (clone = refcount), labels are class indices (as f32) for
/// classification or target values for regression.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    pub n: usize,
    pub d: usize,
    cols: Vec<Arc<Vec<f32>>>,
    pub y: Arc<Vec<f32>>,
}

impl Dataset {
    pub fn new(name: &str, task: Task, d: usize) -> Dataset {
        Dataset {
            name: name.to_string(),
            task,
            n: 0,
            d,
            cols: (0..d).map(|_| Arc::new(Vec::new())).collect(),
            y: Arc::new(Vec::new()),
        }
    }

    /// Assemble from pre-built columns (the FE apply path): columns
    /// may be shared with another dataset — that is the point.
    pub fn from_columns(name: &str, task: Task,
                        cols: Vec<Arc<Vec<f32>>>, y: Arc<Vec<f32>>)
        -> Dataset {
        let n = y.len();
        for (j, c) in cols.iter().enumerate() {
            assert_eq!(c.len(), n, "column {j} length != n rows");
        }
        Dataset { name: name.to_string(), task, n, d: cols.len(),
                  cols, y }
    }

    /// One feature column as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.cols[j]
    }

    /// The `Arc` behind column `j` (zero-copy sharing / pointer
    /// identity checks).
    #[inline]
    pub fn col_arc(&self, j: usize) -> &Arc<Vec<f32>> {
        &self.cols[j]
    }

    /// Single cell access.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.cols[j][i]
    }

    /// Gather row `i` into `buf` (cleared first). Reuse `buf` across
    /// calls in hot loops.
    #[inline]
    pub fn gather_row(&self, i: usize, buf: &mut Vec<f32>) {
        buf.clear();
        buf.extend(self.cols.iter().map(|c| c[i]));
    }

    /// Row `i` as a fresh vector (cold paths / tests; hot loops should
    /// reuse a buffer via [`Dataset::gather_row`]).
    pub fn row_vec(&self, i: usize) -> Vec<f32> {
        self.cols.iter().map(|c| c[i]).collect()
    }

    /// Row-major export (`n * d`), for consumers that need contiguous
    /// rows (PJRT tensor upload, binning). Blocked: each source
    /// column is streamed once per row block instead of strided once
    /// per row (`util::kernels::gather_all_rowmajor` — pure data
    /// movement, bit-exact).
    pub fn to_row_major(&self) -> Vec<f32> {
        let cols: Vec<&[f32]> =
            self.cols.iter().map(|c| c.as_slice()).collect();
        let mut x = Vec::new();
        crate::util::kernels::gather_all_rowmajor(&cols, self.n,
                                                  &mut x);
        x
    }

    /// Gather an arbitrary row subset into a row-major buffer
    /// (`out[r * d + j] = col j at rows[r]`), blocked the same way as
    /// [`Dataset::to_row_major`]. The bulk counterpart of calling
    /// [`Dataset::gather_row`] per index (tree/GBM training views,
    /// batched predict).
    pub fn gather_rows_rowmajor(&self, rows: &[usize],
                                out: &mut Vec<f32>) {
        let cols: Vec<&[f32]> =
            self.cols.iter().map(|c| c.as_slice()).collect();
        crate::util::kernels::gather_rowmajor(&cols, rows, out);
    }

    pub fn push_row(&mut self, row: &[f32], y: f32) {
        assert_eq!(row.len(), self.d, "row width mismatch");
        for (c, &v) in self.cols.iter_mut().zip(row) {
            Arc::make_mut(c).push(v);
        }
        Arc::make_mut(&mut self.y).push(y);
        self.n += 1;
    }

    /// Bulk row append (balancer augmentation): `x` is row-major
    /// `y.len() * d`. Each column is copied-on-write once, not per
    /// appended row.
    pub fn append_rows(&mut self, x: &[f32], y: &[f32]) {
        assert_eq!(x.len(), y.len() * self.d, "row-major shape mismatch");
        for (j, c) in self.cols.iter_mut().enumerate() {
            let c = Arc::make_mut(c);
            c.reserve(y.len());
            c.extend(x.iter().skip(j).step_by(self.d.max(1)));
        }
        Arc::make_mut(&mut self.y).extend_from_slice(y);
        self.n += y.len();
    }

    pub fn label(&self, i: usize) -> usize {
        debug_assert!(self.task.is_classification());
        self.y[i] as usize
    }

    /// Rows selected by index (allows repetition — used by balancers
    /// and bootstrap sampling). Materialises fresh columns.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let cols = self
            .cols
            .iter()
            .map(|c| Arc::new(idx.iter().map(|&i| c[i]).collect()))
            .collect();
        let y = Arc::new(idx.iter().map(|&i| self.y[i]).collect());
        Dataset::from_columns(&self.name, self.task, cols, y)
    }

    /// Class frequency histogram (classification only). Counts every
    /// label exhaustively: out-of-range labels are a caller bug
    /// (`debug_assert`ed) and saturate into the top class in release
    /// rather than silently vanishing from the histogram.
    pub fn class_counts(&self) -> Vec<usize> {
        let k = self.task.n_classes();
        let mut counts = vec![0usize; k];
        if k == 0 {
            return counts;
        }
        for &y in self.y.iter() {
            let c = y as usize;
            debug_assert!(c < k, "label {c} out of range for {k} classes");
            counts[c.min(k - 1)] += 1;
        }
        counts
    }

    /// Column mean/std over given rows (used by meta-features & FE).
    /// Per-column accumulation order equals the historical row-major
    /// loop's (row order within each column), so results are
    /// bit-identical to the seed layout.
    pub fn col_stats(&self, rows: &[usize]) -> (Vec<f64>, Vec<f64>) {
        let n = rows.len().max(1) as f64;
        let mut mean = vec![0.0f64; self.d];
        let mut std = vec![0.0f64; self.d];
        for (j, c) in self.cols.iter().enumerate() {
            let mut m = 0.0f64;
            for &i in rows {
                m += c[i] as f64;
            }
            m /= n;
            let mut v = 0.0f64;
            for &i in rows {
                let dlt = c[i] as f64 - m;
                v += dlt * dlt;
            }
            mean[j] = m;
            std[j] = (v / n).sqrt();
        }
        (mean, std)
    }
}

/// A set of row indices as a view into a shared permutation: cloning
/// is a refcount bump + two offsets, never an index copy. Derefs to
/// `&[usize]`, so any `&[usize]` consumer takes a `RowView` as-is.
#[derive(Clone, Debug)]
pub struct RowView {
    perm: Arc<Vec<usize>>,
    lo: usize,
    hi: usize,
}

impl RowView {
    /// View owning its own (whole) index vector.
    pub fn from_vec(v: Vec<usize>) -> RowView {
        let hi = v.len();
        RowView { perm: Arc::new(v), lo: 0, hi }
    }

    /// Range view over a shared permutation.
    pub fn slice_of(perm: &Arc<Vec<usize>>, lo: usize, hi: usize)
        -> RowView {
        assert!(lo <= hi && hi <= perm.len(), "view range out of bounds");
        RowView { perm: Arc::clone(perm), lo, hi }
    }

    pub fn to_vec(&self) -> Vec<usize> {
        self[..].to_vec()
    }

    /// The shared permutation this view ranges over (pointer-identity
    /// probes in tests).
    pub fn perm_arc(&self) -> &Arc<Vec<usize>> {
        &self.perm
    }
}

impl std::ops::Deref for RowView {
    type Target = [usize];
    #[inline]
    fn deref(&self) -> &[usize] {
        &self.perm[self.lo..self.hi]
    }
}

/// Index-based split. `train` is what pipelines fit on, `valid` drives
/// the search utility, `test` is only touched for final reporting.
/// All three parts are views over ONE shared permutation laid out
/// `[train | valid | test]` — constructing or cloning a `Split` never
/// copies indices.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: RowView,
    pub valid: RowView,
    pub test: RowView,
}

impl Split {
    /// Build from materialised parts (test helpers, external callers):
    /// concatenates into the canonical shared permutation.
    pub fn from_parts(train: Vec<usize>, valid: Vec<usize>,
                      test: Vec<usize>) -> Split {
        let (b1, b2) = (train.len(), train.len() + valid.len());
        let mut perm = train;
        perm.extend_from_slice(&valid);
        perm.extend_from_slice(&test);
        let b3 = perm.len();
        let perm = Arc::new(perm);
        Split {
            train: RowView::slice_of(&perm, 0, b1),
            valid: RowView::slice_of(&perm, b1, b2),
            test: RowView::slice_of(&perm, b2, b3),
        }
    }

    /// The paper's protocol: 4/5 for search (of which an inner
    /// validation fifth drives utility), 1/5 held-out test.
    pub fn standard(n: usize, rng: &mut Rng) -> Split {
        // rng.permutation already yields [train | valid | test] in the
        // historical order: the old code split the tail off twice.
        let perm = Arc::new(rng.permutation(n));
        let n_test = n / 5;
        let b2 = n - n_test;
        let n_valid = b2 / 5;
        let b1 = b2 - n_valid;
        Split {
            train: RowView::slice_of(&perm, 0, b1),
            valid: RowView::slice_of(&perm, b1, b2),
            test: RowView::slice_of(&perm, b2, n),
        }
    }

    /// Stratified variant keeping class proportions in every part
    /// (classification); falls back to `standard` for regression and
    /// for degenerate `n_classes == 0` tasks (which previously
    /// underflowed `k - 1`).
    pub fn stratified(ds: &Dataset, rng: &mut Rng) -> Split {
        let k = ds.task.n_classes();
        if !ds.task.is_classification() || k == 0 {
            return Split::standard(ds.n, rng);
        }
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); k];
        for i in 0..ds.n {
            let c = ds.label(i);
            debug_assert!(c < k, "label {c} out of range for {k} classes");
            by_class[c.min(k - 1)].push(i);
        }
        let (mut train, mut valid, mut test) =
            (Vec::new(), Vec::new(), Vec::new());
        for mut members in by_class {
            rng.shuffle(&mut members);
            let n_test = members.len() / 5;
            let t = members.split_off(members.len() - n_test);
            let n_valid = members.len() / 5;
            let v = members.split_off(members.len() - n_valid);
            test.extend(t);
            valid.extend(v);
            train.extend(members);
        }
        rng.shuffle(&mut train);
        rng.shuffle(&mut valid);
        rng.shuffle(&mut test);
        Split::from_parts(train, valid, test)
    }

    /// k-fold split of the *search* portion (train+valid), used by
    /// cross-validation utilities. `k` is clamped to `1..=n` (a `0`
    /// request previously divided by zero; `k > n` produced empty
    /// folds). Each fold's train/valid are views over one shared
    /// `[train | valid]` permutation.
    pub fn kfold(n: usize, k: usize, rng: &mut Rng)
        -> Vec<(RowView, RowView)> {
        let k = k.clamp(1, n.max(1));
        let perm = rng.permutation(n);
        let mut folds = Vec::with_capacity(k);
        for f in 0..k {
            let lo = n * f / k;
            let hi = n * (f + 1) / k;
            // fold layout: [train (complement, in order) | valid]
            let mut fold: Vec<usize> = Vec::with_capacity(n);
            fold.extend_from_slice(&perm[..lo]);
            fold.extend_from_slice(&perm[hi..]);
            fold.extend_from_slice(&perm[lo..hi]);
            let fold = Arc::new(fold);
            let b = n - (hi - lo);
            folds.push((RowView::slice_of(&fold, 0, b),
                        RowView::slice_of(&fold, b, n)));
        }
        folds
    }
}

/// Model outputs: class scores (n x n_classes, higher = more likely)
/// or regression values.
#[derive(Clone, Debug)]
pub enum Predictions {
    ClassScores { n_classes: usize, scores: Vec<f32> },
    Values(Vec<f32>),
}

impl Predictions {
    pub fn n(&self) -> usize {
        match self {
            Predictions::ClassScores { n_classes, scores } => {
                scores.len() / n_classes.max(&1)
            }
            Predictions::Values(v) => v.len(),
        }
    }

    pub fn score_row(&self, i: usize) -> &[f32] {
        match self {
            Predictions::ClassScores { n_classes, scores } => {
                &scores[i * n_classes..(i + 1) * n_classes]
            }
            Predictions::Values(_) => panic!("not class scores"),
        }
    }

    pub fn argmax_labels(&self) -> Vec<usize> {
        match self {
            Predictions::ClassScores { n_classes, scores } => {
                let c = *n_classes;
                (0..scores.len() / c)
                    .map(|i| {
                        let row = &scores[i * c..(i + 1) * c];
                        row.iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1)
                                .unwrap_or(std::cmp::Ordering::Equal))
                            .map(|(j, _)| j)
                            .unwrap_or(0)
                    })
                    .collect()
            }
            Predictions::Values(_) => panic!("not class scores"),
        }
    }

    pub fn values(&self) -> &[f32] {
        match self {
            Predictions::Values(v) => v,
            _ => panic!("not regression values"),
        }
    }

    /// Elementwise weighted sum of predictions (ensembling substrate).
    /// Panics unless every member has the same kind AND shape — a
    /// short member used to silently truncate the blend.
    pub fn weighted_sum(preds: &[(&Predictions, f64)]) -> Predictions {
        assert!(!preds.is_empty());
        match preds[0].0 {
            Predictions::ClassScores { n_classes, scores } => {
                let mut acc = vec![0.0f32; scores.len()];
                for (p, w) in preds {
                    match p {
                        Predictions::ClassScores { n_classes: k2,
                                                   scores: s } => {
                            assert_eq!(*k2, *n_classes,
                                       "mismatched n_classes in blend");
                            assert_eq!(s.len(), acc.len(),
                                       "mismatched prediction lengths");
                            for (a, &v) in acc.iter_mut().zip(s.iter()) {
                                *a += (*w as f32) * v;
                            }
                        }
                        _ => panic!("mixed prediction kinds"),
                    }
                }
                Predictions::ClassScores { n_classes: *n_classes,
                                           scores: acc }
            }
            Predictions::Values(v0) => {
                let mut acc = vec![0.0f32; v0.len()];
                for (p, w) in preds {
                    let vals = p.values();
                    assert_eq!(vals.len(), acc.len(),
                               "mismatched prediction lengths");
                    for (a, &v) in acc.iter_mut().zip(vals.iter()) {
                        *a += (*w as f32) * v;
                    }
                }
                Predictions::Values(acc)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, k: usize) -> Dataset {
        let mut d = Dataset::new("toy", Task::Classification { n_classes: k }, 2);
        for i in 0..n {
            d.push_row(&[i as f32, (i * 2) as f32], (i % k) as f32);
        }
        d
    }

    #[test]
    fn rows_and_subsets() {
        let d = toy(10, 2);
        assert_eq!(d.row_vec(3), &[3.0, 6.0]);
        assert_eq!(d.at(3, 1), 6.0);
        let s = d.subset(&[1, 1, 4]);
        assert_eq!(s.n, 3);
        assert_eq!(s.row_vec(0), s.row_vec(1));
        assert_eq!(s.y[2], 0.0);
    }

    #[test]
    fn columns_are_shared_by_refcount() {
        let d = toy(10, 2);
        let d2 = d.clone();
        for j in 0..d.d {
            assert!(Arc::ptr_eq(d.col_arc(j), d2.col_arc(j)));
        }
        assert!(Arc::ptr_eq(&d.y, &d2.y));
        // from_columns with one replaced column shares the other
        let fresh = Arc::new(vec![9.0f32; d.n]);
        let ds3 = Dataset::from_columns(
            "mix", d.task,
            vec![Arc::clone(d.col_arc(0)), fresh.clone()],
            Arc::clone(&d.y));
        assert!(Arc::ptr_eq(ds3.col_arc(0), d.col_arc(0)));
        assert!(Arc::ptr_eq(ds3.col_arc(1), &fresh));
    }

    #[test]
    fn push_row_after_share_leaves_the_shared_copy_alone() {
        let mut d = toy(4, 2);
        let shared = d.clone();
        d.push_row(&[100.0, 200.0], 1.0);
        assert_eq!(d.n, 5);
        assert_eq!(shared.n, 4);
        assert_eq!(shared.col(0).len(), 4);
        assert_eq!(d.at(4, 1), 200.0);
    }

    #[test]
    fn gather_and_row_major_round_trip() {
        let d = toy(5, 2);
        let x = d.to_row_major();
        assert_eq!(x.len(), 10);
        let mut buf = Vec::new();
        for i in 0..d.n {
            d.gather_row(i, &mut buf);
            assert_eq!(&x[i * d.d..(i + 1) * d.d], &buf[..]);
        }
    }

    #[test]
    fn standard_split_partitions() {
        let mut rng = Rng::new(0);
        let s = Split::standard(100, &mut rng);
        assert_eq!(s.test.len(), 20);
        assert_eq!(s.valid.len(), 16);
        assert_eq!(s.train.len(), 64);
        let mut all: Vec<usize> = s.train.iter()
            .chain(s.valid.iter()).chain(s.test.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
        // one shared permutation behind all three parts
        assert!(Arc::ptr_eq(s.train.perm_arc(), s.valid.perm_arc()));
        assert!(Arc::ptr_eq(s.train.perm_arc(), s.test.perm_arc()));
    }

    #[test]
    fn stratified_split_keeps_proportions() {
        let mut d = Dataset::new("im", Task::Classification { n_classes: 2 }, 1);
        for i in 0..200 {
            d.push_row(&[i as f32], if i < 180 { 0.0 } else { 1.0 });
        }
        let mut rng = Rng::new(1);
        let s = Split::stratified(&d, &mut rng);
        let minority_in_test =
            s.test.iter().filter(|&&i| d.y[i] == 1.0).count();
        assert_eq!(minority_in_test, 4); // 20 minority / 5
    }

    #[test]
    fn stratified_with_zero_classes_falls_back_to_standard() {
        // previously underflowed `k - 1`
        let mut d = Dataset::new("z", Task::Classification { n_classes: 0 }, 1);
        for i in 0..50 {
            d.push_row(&[i as f32], 0.0);
        }
        let s = Split::stratified(&d, &mut Rng::new(3));
        assert_eq!(s.train.len() + s.valid.len() + s.test.len(), 50);
    }

    #[test]
    fn kfold_covers_everything_once() {
        let mut rng = Rng::new(2);
        let folds = Split::kfold(53, 5, &mut rng);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 53];
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 53);
            for &i in va.iter() {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn kfold_clamps_degenerate_k() {
        // k == 0 previously divided by zero; k > n made empty folds
        let folds = Split::kfold(10, 0, &mut Rng::new(4));
        assert_eq!(folds.len(), 1);
        assert_eq!(folds[0].1.len(), 10);
        let folds = Split::kfold(3, 10, &mut Rng::new(5));
        assert_eq!(folds.len(), 3);
        assert!(folds.iter().all(|(_, va)| !va.is_empty()));
    }

    #[test]
    fn argmax_labels_picks_max() {
        let p = Predictions::ClassScores {
            n_classes: 3,
            scores: vec![0.1, 0.7, 0.2, 0.5, 0.2, 0.3],
        };
        assert_eq!(p.argmax_labels(), vec![1, 0]);
    }

    #[test]
    fn weighted_sum_blends() {
        let a = Predictions::Values(vec![1.0, 2.0]);
        let b = Predictions::Values(vec![3.0, 4.0]);
        let m = Predictions::weighted_sum(&[(&a, 0.5), (&b, 0.5)]);
        assert_eq!(m.values(), &[2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "mismatched prediction lengths")]
    fn weighted_sum_rejects_short_members() {
        let a = Predictions::Values(vec![1.0, 2.0]);
        let b = Predictions::Values(vec![3.0]);
        let _ = Predictions::weighted_sum(&[(&a, 0.5), (&b, 0.5)]);
    }

    #[test]
    #[should_panic(expected = "mismatched n_classes")]
    fn weighted_sum_rejects_mismatched_classes() {
        let a = Predictions::ClassScores { n_classes: 2,
                                           scores: vec![0.1; 4] };
        let b = Predictions::ClassScores { n_classes: 4,
                                           scores: vec![0.1; 4] };
        let _ = Predictions::weighted_sum(&[(&a, 0.5), (&b, 0.5)]);
    }

    #[test]
    fn class_counts_histogram() {
        let d = toy(10, 3);
        assert_eq!(d.class_counts(), vec![4, 3, 3]);
    }

    #[test]
    fn row_view_derefs_as_slice() {
        let v = RowView::from_vec(vec![5, 6, 7]);
        let s: &[usize] = &v;
        assert_eq!(s, &[5, 6, 7]);
        assert_eq!(v.to_vec(), vec![5, 6, 7]);
        fn takes_slice(r: &[usize]) -> usize { r.len() }
        assert_eq!(takes_slice(&v), 3);
    }
}
