//! Dataset substrate: core types, metrics, synthetic generators and
//! the named registry standing in for the paper's OpenML/Kaggle
//! corpora (see DESIGN.md "Substitutions").

pub mod dataset;
pub mod metrics;
pub mod registry;
pub mod synthetic;

pub use dataset::{Dataset, Predictions, Split, Task};
pub use metrics::Metric;
pub use synthetic::{generate, GenKind, Profile};
