//! Synthetic dataset generators.
//!
//! Substitution for the paper's OpenML/Kaggle corpora (see DESIGN.md):
//! a heterogeneous family of generators chosen so that *different
//! algorithm arms win on different datasets* — the property that drives
//! the conditioning block's bandit behaviour — and so that feature
//! engineering genuinely matters on some tasks (unscaled features,
//! redundant columns, sparse signals, texture-like signals).

use super::dataset::{Dataset, Task};
use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum GenKind {
    /// Gaussian class clusters; `sep` is the centre spread (linear /
    /// LDA-friendly at high sep).
    Blobs { sep: f64 },
    /// Checkerboard labels over the first two dims (tree/MLP-friendly).
    Checker { cells: usize },
    /// Concentric annuli (KNN/MLP-friendly, defeats linear models).
    Rings,
    /// Sparse linear logits in high-ish dim (l1/linear-friendly).
    SparseLinearCls { informative: usize },
    /// 1-D sinusoidal "texture" signals whose class is the dominant
    /// frequency with random phase — raw pixels defeat pixel-wise
    /// splits; frequency-band embeddings (fe::embedding) crack it.
    Texture,
    /// Friedman #1 regression benchmark (GBM/RF-friendly).
    Friedman1,
    /// Plain linear regression with noise (ridge-friendly).
    LinearReg { informative: usize },
    /// Sum of axis-aligned step functions (tree-friendly regression).
    PiecewiseReg { steps: usize },
    /// Smooth nonlinear surface of sin/product terms (MLP/KNN-friendly).
    NonlinearReg,
    /// NonlinearReg surface thresholded into a binary label (the
    /// classification analogue of kin8nm/puma8NH-style tasks).
    /// `imbalance` is ignored (labels are derived, not sampled).
    NonlinearCls,
    /// PiecewiseReg surface thresholded into a binary label.
    PiecewiseCls { steps: usize },
}

#[derive(Clone, Debug)]
pub struct Profile {
    pub name: String,
    pub task: Task,
    pub gen: GenKind,
    pub n: usize,
    pub d: usize,
    /// Label-flip fraction (classification) or relative y-noise (reg).
    pub noise: f64,
    /// Largest:smallest class prior ratio (>= 1.0).
    pub imbalance: f64,
    /// Number of redundant columns (linear combos of informative ones)
    /// appended within `d`.
    pub redundant: usize,
    /// Per-feature random scale/offset (exercises scalers).
    pub wild_scales: bool,
    pub seed: u64,
}

impl Profile {
    pub fn n_classes(&self) -> usize {
        self.task.n_classes()
    }
}

/// Class priors with geometric imbalance ratio.
fn class_priors(k: usize, imbalance: f64) -> Vec<f64> {
    if k == 0 {
        return Vec::new();
    }
    let r = imbalance.max(1.0).powf(1.0 / (k.max(2) - 1) as f64);
    let mut w: Vec<f64> = (0..k).map(|c| r.powi(c as i32)).collect();
    w.reverse(); // class 0 = majority
    let s: f64 = w.iter().sum();
    w.into_iter().map(|x| x / s).collect()
}

pub fn generate(p: &Profile) -> Dataset {
    let mut rng = Rng::new(p.seed ^ 0xDA7A);
    let mut ds = Dataset::new(&p.name, p.task, p.d);
    let k = p.n_classes();
    let priors = class_priors(k, p.imbalance);

    // informative dimensionality (rest = redundant + pure noise)
    let d_inf = match &p.gen {
        GenKind::Checker { .. } | GenKind::Rings => 2,
        GenKind::SparseLinearCls { informative } => *informative,
        GenKind::LinearReg { informative } => *informative,
        GenKind::Friedman1 => 5,
        GenKind::NonlinearCls => 3,
        GenKind::Texture => p.d,
        _ => (p.d / 2).clamp(2, 8),
    }
    .min(p.d);

    // fixed per-dataset structures
    let centers: Vec<Vec<f64>> = (0..k.max(1))
        .map(|_| (0..d_inf).map(|_| rng.normal()).collect())
        .collect();
    let sparse_w: Vec<Vec<f64>> = (0..k.max(1))
        .map(|_| (0..d_inf).map(|_| rng.normal()).collect())
        .collect();
    let lin_w: Vec<f64> = (0..d_inf).map(|_| rng.normal_ms(0.0, 2.0)).collect();
    let step_thresh: Vec<(usize, f64, f64)> = (0..8)
        .map(|_| (rng.below(d_inf.max(1)), rng.uniform(-1.0, 1.0),
                  rng.normal_ms(0.0, 2.0)))
        .collect();
    let redundant_mix: Vec<(usize, usize, f64, f64)> = (0..p.redundant)
        .map(|_| (rng.below(d_inf.max(1)), rng.below(d_inf.max(1)),
                  rng.normal(), rng.normal()))
        .collect();
    let texture_freqs: Vec<f64> = (0..k.max(1))
        .map(|c| 3.0 + 1.5 * c as f64 + rng.uniform(0.0, 0.3))
        .collect();
    // per-feature affine warp (exercises scalers)
    let warps: Vec<(f64, f64)> = (0..p.d)
        .map(|_| {
            if p.wild_scales {
                (rng.log_uniform(0.01, 100.0), rng.normal_ms(0.0, 10.0))
            } else {
                (1.0, 0.0)
            }
        })
        .collect();

    for _ in 0..p.n {
        let mut x = vec![0.0f32; p.d];
        let mut inf = vec![0.0f64; d_inf];
        let y: f64;
        match &p.gen {
            GenKind::Blobs { sep } => {
                let c = rng.weighted(&priors);
                for j in 0..d_inf {
                    inf[j] = centers[c][j] * sep + rng.normal();
                }
                y = c as f64;
            }
            GenKind::Checker { cells } => {
                let c = *cells as f64;
                for j in 0..d_inf {
                    inf[j] = rng.uniform(-2.0, 2.0);
                }
                let cx = ((inf[0] + 2.0) / 4.0 * c).floor() as i64;
                let cy = ((inf[1] + 2.0) / 4.0 * c).floor() as i64;
                let cls = (cx + cy).rem_euclid(k.max(2) as i64) as usize;
                y = cls.min(k - 1) as f64;
            }
            GenKind::Rings => {
                let c = rng.weighted(&priors);
                let radius = 1.0 + 1.5 * c as f64 + rng.normal_ms(0.0, 0.2);
                let theta = rng.uniform(0.0, std::f64::consts::TAU);
                inf[0] = radius * theta.cos();
                inf[1] = radius * theta.sin();
                y = c as f64;
            }
            GenKind::SparseLinearCls { .. } => {
                for j in 0..d_inf {
                    inf[j] = rng.normal();
                }
                let mut best = (f64::NEG_INFINITY, 0usize);
                for (c, w) in sparse_w.iter().enumerate().take(k) {
                    let mut logit = priors[c].ln();
                    for j in 0..d_inf {
                        logit += w[j] * inf[j];
                    }
                    if logit > best.0 {
                        best = (logit, c);
                    }
                }
                y = best.1 as f64;
            }
            GenKind::Texture => {
                let c = rng.weighted(&priors);
                let phase = rng.uniform(0.0, std::f64::consts::TAU);
                let f = texture_freqs[c];
                for j in 0..d_inf {
                    let t = j as f64 / d_inf as f64;
                    // heavy per-pixel noise: band energies average it
                    // out, pixel-level models drown in it
                    inf[j] = (std::f64::consts::TAU * f * t + phase).sin()
                        + rng.normal_ms(0.0, 1.2);
                }
                y = c as f64;
            }
            GenKind::Friedman1 => {
                for j in 0..d_inf {
                    inf[j] = rng.f64();
                }
                // indices clamp so low-dim profiles degrade gracefully
                let ix = |i: usize| inf[i.min(d_inf - 1)];
                y = 10.0 * (std::f64::consts::PI * ix(0) * ix(1)).sin()
                    + 20.0 * (ix(2) - 0.5).powi(2)
                    + 10.0 * ix(3)
                    + 5.0 * ix(4);
            }
            GenKind::LinearReg { .. } => {
                for j in 0..d_inf {
                    inf[j] = rng.normal();
                }
                y = crate::util::linalg::dot(&inf, &lin_w);
            }
            GenKind::PiecewiseReg { steps } => {
                for j in 0..d_inf {
                    inf[j] = rng.uniform(-2.0, 2.0);
                }
                let mut acc = 0.0;
                for (j, t, h) in step_thresh.iter().take(*steps) {
                    if inf[*j] > *t {
                        acc += h;
                    }
                }
                y = acc;
            }
            GenKind::NonlinearReg => {
                for j in 0..d_inf {
                    inf[j] = rng.normal();
                }
                y = (3.0 * inf[0]).sin() * inf[1.min(d_inf - 1)]
                    + inf[(2).min(d_inf - 1)].powi(2)
                    - inf[0] * 0.5;
            }
            GenKind::NonlinearCls => {
                for j in 0..d_inf {
                    inf[j] = rng.normal();
                }
                let s = (3.0 * inf[0]).sin() * inf[1.min(d_inf - 1)]
                    + inf[(2).min(d_inf - 1)].powi(2)
                    - inf[0] * 0.5;
                // ~median of the surface under standard normals
                y = if s > 0.85 { 1.0 } else { 0.0 };
            }
            GenKind::PiecewiseCls { steps } => {
                for j in 0..d_inf {
                    inf[j] = rng.uniform(-2.0, 2.0);
                }
                let mut acc = 0.0;
                for (j, t, h) in step_thresh.iter().take(*steps) {
                    if inf[*j] > *t {
                        acc += h;
                    }
                }
                y = if acc > 0.0 { 1.0 } else { 0.0 };
            }
        }

        // assemble feature row: informative | redundant | noise
        for j in 0..d_inf {
            x[j] = inf[j] as f32;
        }
        for (r, (a, b, wa, wb)) in redundant_mix.iter().enumerate() {
            let idx = d_inf + r;
            if idx >= p.d {
                break;
            }
            x[idx] = (wa * inf[*a] + wb * inf[*b]
                + rng.normal_ms(0.0, 0.05)) as f32;
        }
        for j in (d_inf + p.redundant.min(p.d - d_inf))..p.d {
            x[j] = rng.normal() as f32;
        }
        // affine warp per feature
        for (j, v) in x.iter_mut().enumerate() {
            *v = (*v as f64 * warps[j].0 + warps[j].1) as f32;
        }

        // label / target noise
        let y_final = if p.task.is_classification() {
            if rng.bool(p.noise) {
                rng.below(k) as f64
            } else {
                y
            }
        } else {
            y + rng.normal_ms(0.0, p.noise.max(1e-9))
        };
        ds.push_row(&x, y_final as f32);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(name: &str, gen: GenKind, task: Task) -> Profile {
        Profile {
            name: name.into(),
            task,
            gen,
            n: 400,
            d: 10,
            noise: 0.0,
            imbalance: 1.0,
            redundant: 2,
            wild_scales: false,
            seed: 7,
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let p = base("a", GenKind::Blobs { sep: 2.0 },
                     Task::Classification { n_classes: 3 });
        let d1 = generate(&p);
        let d2 = generate(&p);
        assert_eq!(d1.to_row_major(), d2.to_row_major());
        assert_eq!(d1.y, d2.y);
        let mut p2 = p.clone();
        p2.seed = 8;
        assert_ne!(generate(&p2).to_row_major(), d1.to_row_major());
    }

    #[test]
    fn shapes_and_label_range() {
        for (gen, task) in [
            (GenKind::Blobs { sep: 2.0 }, Task::Classification { n_classes: 4 }),
            (GenKind::Checker { cells: 4 }, Task::Classification { n_classes: 2 }),
            (GenKind::Rings, Task::Classification { n_classes: 3 }),
            (GenKind::SparseLinearCls { informative: 5 },
             Task::Classification { n_classes: 2 }),
            (GenKind::Texture, Task::Classification { n_classes: 2 }),
            (GenKind::Friedman1, Task::Regression),
            (GenKind::LinearReg { informative: 4 }, Task::Regression),
            (GenKind::PiecewiseReg { steps: 5 }, Task::Regression),
            (GenKind::NonlinearReg, Task::Regression),
        ] {
            let p = base("t", gen, task);
            let ds = generate(&p);
            assert_eq!(ds.n, 400);
            assert_eq!(ds.d, 10);
            assert!((0..ds.d).all(|j| ds.col(j).len() == 400));
            if task.is_classification() {
                let k = task.n_classes();
                assert!(ds.y.iter().all(|&y| (y as usize) < k));
                // every class appears
                assert!(ds.class_counts().iter().all(|&c| c > 0),
                        "{:?}", ds.class_counts());
            } else {
                assert!(ds.y.iter().any(|&y| y != ds.y[0]));
            }
        }
    }

    #[test]
    fn imbalance_skews_priors() {
        let mut p = base("im", GenKind::Blobs { sep: 2.0 },
                         Task::Classification { n_classes: 2 });
        p.imbalance = 9.0;
        p.n = 2000;
        let ds = generate(&p);
        let counts = ds.class_counts();
        let ratio = counts[0] as f64 / counts[1].max(1) as f64;
        assert!(ratio > 5.0, "ratio={ratio}");
    }

    #[test]
    fn label_noise_flips_labels() {
        let mut p = base("n", GenKind::Blobs { sep: 6.0 },
                         Task::Classification { n_classes: 2 });
        p.n = 2000;
        let clean = generate(&p);
        p.noise = 0.3;
        let noisy = generate(&p);
        let diff = clean.y.iter().zip(&noisy.y)
            .filter(|(a, b)| a != b).count();
        assert!(diff > 100, "diff={diff}");
    }

    #[test]
    fn wild_scales_change_feature_magnitudes() {
        let mut p = base("w", GenKind::Blobs { sep: 2.0 },
                         Task::Classification { n_classes: 2 });
        p.wild_scales = true;
        let ds = generate(&p);
        let rows: Vec<usize> = (0..ds.n).collect();
        let (_, std) = ds.col_stats(&rows);
        let max = std.iter().cloned().fold(0.0, f64::max);
        let min = std.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min.max(1e-12) > 10.0, "scales too uniform");
    }

    #[test]
    fn rings_are_not_linearly_separable_but_radial() {
        let p = base("r", GenKind::Rings,
                     Task::Classification { n_classes: 2 });
        let ds = generate(&p);
        // radius separates classes almost perfectly
        let mut correct = 0;
        for i in 0..ds.n {
            let r = (ds.at(i, 0).powi(2) + ds.at(i, 1).powi(2)).sqrt();
            let pred = if r < 1.75 { 0 } else { 1 };
            if pred == ds.label(i) {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.n as f64 > 0.9);
    }
}
