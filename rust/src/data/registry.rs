//! Named dataset registry reproducing the paper's experimental corpora
//! as synthetic profiles (substitution documented in DESIGN.md):
//!
//! * 30 medium OpenML classification datasets (Fig 7, Tables 1/4-6)
//! * 10 large classification datasets (Fig 8, Table 10)
//! * 20 OpenML regression datasets (Fig 7, Tables 1/4-6)
//! * 6 Kaggle competition datasets (Fig 9, Table 3)
//! * the imbalanced five of Table 2, pc4 (Figs 12/13), fri_c1 (Fig 14),
//!   and the image-like dogs-vs-cats analogue (§6.3).
//!
//! Profiles are chosen so the *shape* of the paper's findings can
//! reproduce: heterogeneous generator kinds (different winners),
//! realistic size ladders (scaled down for a single core) and the same
//! names the paper's tables reference.

use super::dataset::Task;
use super::synthetic::{GenKind, Profile};

fn name_seed(name: &str) -> u64 {
    // FNV-1a for stable per-name seeds
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn cls(name: &str, gen: GenKind, n: usize, d: usize, k: usize,
       noise: f64, imbalance: f64) -> Profile {
    Profile {
        name: name.to_string(),
        task: Task::Classification { n_classes: k },
        gen,
        n,
        d,
        noise,
        imbalance,
        redundant: d / 4,
        wild_scales: name_seed(name) % 3 == 0,
        seed: name_seed(name),
    }
}

fn reg(name: &str, gen: GenKind, n: usize, d: usize, noise: f64)
    -> Profile {
    Profile {
        name: name.to_string(),
        task: Task::Regression,
        gen,
        n,
        d,
        noise,
        imbalance: 1.0,
        redundant: d / 4,
        wild_scales: name_seed(name) % 3 == 0,
        seed: name_seed(name),
    }
}

/// The paper's 30 medium classification datasets.
pub fn medium_classification() -> Vec<Profile> {
    use GenKind::*;
    vec![
        cls("kc1", SparseLinearCls { informative: 6 }, 1200, 21, 2, 0.15, 5.0),
        cls("quake", Blobs { sep: 0.8 }, 1100, 3, 2, 0.25, 1.2),
        cls("segment", Blobs { sep: 2.5 }, 1200, 19, 7, 0.02, 1.0),
        cls("ozone-level-8hr", SparseLinearCls { informative: 8 }, 1300, 32, 2, 0.08, 15.0),
        cls("space_ga_cls", NonlinearCls, 1500, 6, 2, 0.10, 1.0),
        cls("sick", SparseLinearCls { informative: 5 }, 1900, 28, 2, 0.03, 15.0),
        cls("pollen", Blobs { sep: 0.15 }, 1900, 5, 2, 0.40, 1.0),
        cls("analcatdata_supreme", Checker { cells: 3 }, 1000, 7, 2, 0.05, 1.3),
        cls("abalone", Rings, 2000, 8, 3, 0.20, 1.8),
        cls("spambase", SparseLinearCls { informative: 12 }, 2300, 32, 2, 0.06, 1.5),
        cls("waveform(2)", Blobs { sep: 1.2 }, 2500, 21, 3, 0.12, 1.0),
        cls("phoneme", Rings, 2700, 5, 2, 0.10, 2.4),
        cls("page-blocks(2)", Blobs { sep: 2.0 }, 2700, 10, 2, 0.04, 9.0),
        cls("optdigits", Blobs { sep: 1.8 }, 2800, 32, 8, 0.03, 1.0),
        cls("satimage", Blobs { sep: 1.5 }, 3200, 32, 6, 0.06, 2.4),
        cls("wind_cls", NonlinearCls, 3300, 14, 2, 0.12, 1.0),
        cls("delta_ailerons", Checker { cells: 2 }, 3500, 5, 2, 0.08, 1.3),
        cls("puma8NH", NonlinearCls, 4000, 8, 2, 0.15, 1.0),
        cls("kin8nm", NonlinearCls, 4000, 8, 2, 0.10, 1.0),
        cls("puma32H", SparseLinearCls { informative: 4 }, 4000, 32, 2, 0.12, 1.0),
        cls("cpu_act", PiecewiseCls { steps: 5 }, 4000, 21, 2, 0.06, 1.4),
        cls("bank32nh", SparseLinearCls { informative: 9 }, 4000, 32, 2, 0.18, 1.3),
        cls("mc1", SparseLinearCls { informative: 7 }, 4000, 32, 2, 0.04, 30.0),
        cls("delta_elevators", Checker { cells: 2 }, 4000, 6, 2, 0.10, 1.2),
        cls("jm1", SparseLinearCls { informative: 8 }, 4000, 21, 2, 0.22, 4.0),
        cls("pendigits", Blobs { sep: 2.2 }, 4000, 16, 8, 0.02, 1.0),
        cls("mammography", Blobs { sep: 1.6 }, 4000, 6, 2, 0.05, 42.0),
        cls("ailerons", SparseLinearCls { informative: 10 }, 4000, 32, 2, 0.08, 1.2),
        cls("eeg", Rings, 4000, 14, 2, 0.12, 1.1),
        cls("pc4", Checker { cells: 3 }, 1450, 32, 2, 0.08, 7.0),
    ]
}

/// The paper's 10 large classification datasets (sizes scaled down
/// ~10x; ratios kept).
pub fn large_classification() -> Vec<Profile> {
    use GenKind::*;
    vec![
        cls("mnist_784", Blobs { sep: 1.9 }, 8000, 32, 8, 0.02, 1.0),
        cls("letter(2)", Blobs { sep: 2.4 }, 6000, 16, 2, 0.01, 1.1),
        cls("kropt", Checker { cells: 4 }, 6000, 6, 8, 0.05, 2.5),
        cls("mv", PiecewiseCls { steps: 5 }, 8000, 10, 2, 0.01, 1.2),
        cls("a9a", SparseLinearCls { informative: 14 }, 8000, 32, 2, 0.10, 3.2),
        cls("covertype", Checker { cells: 5 }, 10000, 12, 7, 0.08, 8.0),
        cls("2dplanes", PiecewiseCls { steps: 5 }, 8000, 10, 2, 0.06, 1.0),
        cls("higgs", NonlinearCls, 10000, 28, 2, 0.22, 1.1),
        cls("electricity", Checker { cells: 3 }, 9000, 8, 2, 0.07, 1.4),
        cls("fried_cls", NonlinearCls, 8000, 10, 2, 0.05, 1.0),
    ]
}

/// The paper's 20 regression datasets.
pub fn regression() -> Vec<Profile> {
    use GenKind::*;
    vec![
        reg("stock", LinearReg { informative: 6 }, 950, 9, 0.3),
        reg("socmob", PiecewiseReg { steps: 4 }, 1150, 5, 0.4),
        reg("Moneyball", LinearReg { informative: 8 }, 1230, 14, 0.5),
        reg("insurance", PiecewiseReg { steps: 5 }, 1300, 7, 0.6),
        reg("weather_izmir", LinearReg { informative: 5 }, 1460, 9, 0.3),
        reg("us_crime", LinearReg { informative: 12 }, 1990, 32, 0.6),
        reg("debutanizer", NonlinearReg, 2390, 7, 0.3),
        reg("space_ga", NonlinearReg, 3100, 6, 0.25),
        reg("pollen_reg", LinearReg { informative: 4 }, 3840, 5, 1.2),
        reg("wind", LinearReg { informative: 10 }, 6570, 14, 0.8),
        reg("bank8FM", NonlinearReg, 4500, 8, 0.15),
        reg("bank32nh", LinearReg { informative: 9 }, 4500, 32, 1.0),
        reg("kin8nm", NonlinearReg, 4500, 8, 0.2),
        reg("puma8NH", NonlinearReg, 4500, 8, 1.0),
        reg("cpu_act", PiecewiseReg { steps: 7 }, 4500, 21, 0.4),
        reg("puma32H", NonlinearReg, 4500, 32, 0.3),
        reg("cpu_small", PiecewiseReg { steps: 6 }, 4500, 12, 0.4),
        reg("visualizing_soil", Friedman1, 4700, 4, 0.5),
        reg("sulfur", NonlinearReg, 5000, 6, 0.2),
        reg("rainfall_bangladesh", Friedman1, 4600, 10, 1.5),
    ]
}

/// The six Kaggle competition tasks of Table 3 / Fig 9 (binary
/// classification; samples scaled down, feature counts capped at 32).
pub fn kaggle() -> Vec<Profile> {
    use GenKind::*;
    vec![
        cls("influencers", SparseLinearCls { informative: 10 }, 1700, 22, 2, 0.12, 1.3),
        cls("west-nile-virus", Blobs { sep: 1.1 }, 2600, 11, 2, 0.08, 18.0),
        cls("employee-access", Checker { cells: 4 }, 3300, 9, 2, 0.05, 16.0),
        cls("santander", SparseLinearCls { informative: 9 }, 4000, 32, 2, 0.10, 24.0),
        cls("redhat-business", Checker { cells: 3 }, 8000, 12, 2, 0.06, 1.6),
        cls("flavors-of-physics", NonlinearCls, 3800, 32, 2, 0.15, 1.4),
    ]
}

/// Table 2's five imbalanced datasets (smote enrichment experiment).
pub fn imbalanced() -> Vec<Profile> {
    use GenKind::*;
    vec![
        cls("sick", SparseLinearCls { informative: 5 }, 1900, 28, 2, 0.03, 15.0),
        cls("pc2", Blobs { sep: 1.4 }, 1500, 32, 2, 0.04, 45.0),
        cls("abalone", Rings, 2000, 8, 3, 0.20, 1.8),
        cls("page-blocks(2)", Blobs { sep: 2.0 }, 2700, 10, 2, 0.04, 9.0),
        cls("hypothyroid(2)", SparseLinearCls { informative: 6 }, 1900, 27, 2, 0.01, 20.0),
    ]
}

/// fri_c1 for the Fig 14 FE x HPO grid.
pub fn fri_c1() -> Profile {
    cls("fri_c1", GenKind::NonlinearCls, 1000, 10, 2, 0.05, 1.0)
}

/// Image-like dogs-vs-cats analogue for the embedding-selection
/// experiment (§6.3): 1-D textures, raw "pixels" defeat tabular models.
pub fn dogs_vs_cats() -> Profile {
    let mut p = cls("dogs-vs-cats", GenKind::Texture, 1500, 32, 2, 0.02, 1.0);
    p.redundant = 0;
    p.wild_scales = false;
    p
}

pub fn by_name(name: &str) -> Option<Profile> {
    all_profiles().into_iter().find(|p| p.name == name)
}

pub fn all_profiles() -> Vec<Profile> {
    let mut v = medium_classification();
    v.extend(large_classification());
    v.extend(regression().into_iter().map(|mut p| {
        // disambiguate names shared across CLS/REG corpora
        if by_name_in(&medium_classification(), &p.name)
            || by_name_in(&large_classification(), &p.name) {
            p.name = format!("{}_reg", p.name);
        }
        p
    }));
    v.extend(kaggle());
    v.push(cls("pc2", GenKind::Blobs { sep: 1.4 }, 1500, 32, 2, 0.04, 45.0));
    v.push(cls("hypothyroid(2)",
               GenKind::SparseLinearCls { informative: 6 }, 1900, 27, 2,
               0.01, 20.0));
    v.push(fri_c1());
    v.push(dogs_vs_cats());
    // dedupe by name (keep first)
    let mut seen = std::collections::HashSet::new();
    v.retain(|p| seen.insert(p.name.clone()));
    v
}

fn by_name_in(list: &[Profile], name: &str) -> bool {
    list.iter().any(|p| p.name == name)
}

/// Meta-training corpus: extra synthetic tasks (never in the eval
/// sets) standing in for the paper's 90 CLS + 50 REG meta datasets.
pub fn meta_corpus(n_cls: usize, n_reg: usize) -> Vec<Profile> {
    use GenKind::*;
    let mut v = Vec::new();
    for i in 0..n_cls {
        let gens = [
            Blobs { sep: 0.5 + 0.25 * (i % 9) as f64 },
            Checker { cells: 2 + i % 4 },
            Rings,
            SparseLinearCls { informative: 3 + i % 10 },
            NonlinearCls,
            PiecewiseCls { steps: 5 },
        ];
        let gen = gens[i % gens.len()].clone();
        v.push(cls(&format!("meta_cls_{i}"), gen,
                   700 + 113 * (i % 12), 4 + (i * 3) % 29,
                   2 + i % 5, 0.02 * (i % 10) as f64,
                   1.0 + (i % 7) as f64 * 2.0));
    }
    for i in 0..n_reg {
        let gens = [
            Friedman1,
            LinearReg { informative: 3 + i % 8 },
            PiecewiseReg { steps: 3 + i % 6 },
            NonlinearReg,
        ];
        let gen = gens[i % gens.len()].clone();
        v.push(reg(&format!("meta_reg_{i}"), gen,
                   700 + 97 * (i % 12), 4 + (i * 3) % 29,
                   0.1 + 0.15 * (i % 8) as f64));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::generate;

    #[test]
    fn corpus_sizes_match_paper() {
        assert_eq!(medium_classification().len(), 30);
        assert_eq!(large_classification().len(), 10);
        assert_eq!(regression().len(), 20);
        assert_eq!(kaggle().len(), 6);
        assert_eq!(imbalanced().len(), 5);
    }

    #[test]
    fn all_profiles_have_unique_names() {
        let all = all_profiles();
        let names: std::collections::HashSet<_> =
            all.iter().map(|p| p.name.clone()).collect();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn by_name_finds_key_datasets() {
        for name in ["quake", "pc4", "fri_c1", "dogs-vs-cats", "higgs",
                     "space_ga", "pc2", "santander"] {
            assert!(by_name(name).is_some(), "{name} missing");
        }
        assert!(by_name("not-a-dataset").is_none());
    }

    #[test]
    fn every_profile_generates() {
        for p in all_profiles() {
            let mut small = p.clone();
            small.n = 60; // keep the test fast
            let ds = generate(&small);
            assert_eq!(ds.n, 60, "{}", p.name);
            assert_eq!(ds.d, p.d, "{}", p.name);
            if p.task.is_classification() {
                assert!(ds.y.iter().all(|&y| (y as usize) < p.n_classes()),
                        "{}", p.name);
            }
        }
    }

    #[test]
    fn meta_corpus_disjoint_from_eval_sets() {
        let eval: std::collections::HashSet<_> =
            all_profiles().iter().map(|p| p.name.clone()).collect();
        for p in meta_corpus(20, 10) {
            assert!(!eval.contains(&p.name));
        }
    }

    #[test]
    fn imbalanced_profiles_are_imbalanced() {
        for p in imbalanced() {
            if p.name != "abalone" {
                assert!(p.imbalance >= 9.0, "{}", p.name);
            }
        }
    }
}
