//! Utility metrics from the paper's protocol: balanced accuracy
//! (classification headline), accuracy, macro-F1, AUC (binary), MSE
//! (regression headline), MAE, R². All metrics are reported so that
//! *higher is better* via `Metric::utility` (errors are negated), which
//! is what the building blocks maximise.

use super::dataset::Predictions;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    BalancedAccuracy,
    Accuracy,
    F1Macro,
    Auc,
    Mse,
    Mae,
    R2,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::BalancedAccuracy => "balanced_accuracy",
            Metric::Accuracy => "accuracy",
            Metric::F1Macro => "f1_macro",
            Metric::Auc => "auc",
            Metric::Mse => "mse",
            Metric::Mae => "mae",
            Metric::R2 => "r2",
        }
    }

    pub fn parse(s: &str) -> Option<Metric> {
        Some(match s {
            "balanced_accuracy" | "bal_acc" => Metric::BalancedAccuracy,
            "accuracy" | "acc" => Metric::Accuracy,
            "f1" | "f1_macro" => Metric::F1Macro,
            "auc" => Metric::Auc,
            "mse" => Metric::Mse,
            "mae" => Metric::Mae,
            "r2" => Metric::R2,
            _ => return None,
        })
    }

    pub fn is_classification(&self) -> bool {
        matches!(self, Metric::BalancedAccuracy | Metric::Accuracy
                 | Metric::F1Macro | Metric::Auc)
    }

    /// Raw metric value (its natural orientation).
    pub fn compute(&self, y_true: &[f32], preds: &Predictions) -> f64 {
        match self {
            Metric::BalancedAccuracy => {
                balanced_accuracy(y_true, &preds.argmax_labels())
            }
            Metric::Accuracy => accuracy(y_true, &preds.argmax_labels()),
            Metric::F1Macro => f1_macro(y_true, &preds.argmax_labels()),
            Metric::Auc => auc_binary(y_true, preds),
            Metric::Mse => mse(y_true, preds.values()),
            Metric::Mae => mae(y_true, preds.values()),
            Metric::R2 => r2(y_true, preds.values()),
        }
    }

    /// Higher-is-better utility (errors negated). This is the objective
    /// the VolcanoML blocks maximise.
    pub fn utility(&self, y_true: &[f32], preds: &Predictions) -> f64 {
        let v = self.compute(y_true, preds);
        match self {
            Metric::Mse | Metric::Mae => -v,
            _ => v,
        }
    }
}

pub fn accuracy(y_true: &[f32], y_pred: &[usize]) -> f64 {
    if y_true.is_empty() {
        return 0.0;
    }
    let hits = y_true
        .iter()
        .zip(y_pred)
        .filter(|(t, p)| **t as usize == **p)
        .count();
    hits as f64 / y_true.len() as f64
}

/// Mean of per-class recall — the paper's classification metric.
pub fn balanced_accuracy(y_true: &[f32], y_pred: &[usize]) -> f64 {
    let k = y_true
        .iter()
        .map(|&t| t as usize)
        .max()
        .map(|m| m + 1)
        .unwrap_or(0);
    if k == 0 {
        return 0.0;
    }
    let mut correct = vec![0usize; k];
    let mut total = vec![0usize; k];
    for (&t, &p) in y_true.iter().zip(y_pred) {
        let t = t as usize;
        total[t] += 1;
        if t == p {
            correct[t] += 1;
        }
    }
    let mut acc = 0.0;
    let mut live = 0;
    for c in 0..k {
        if total[c] > 0 {
            acc += correct[c] as f64 / total[c] as f64;
            live += 1;
        }
    }
    if live == 0 { 0.0 } else { acc / live as f64 }
}

pub fn f1_macro(y_true: &[f32], y_pred: &[usize]) -> f64 {
    let k = y_true
        .iter()
        .map(|&t| t as usize)
        .chain(y_pred.iter().copied())
        .max()
        .map(|m| m + 1)
        .unwrap_or(0);
    if k == 0 {
        return 0.0;
    }
    let (mut tp, mut fp, mut fntv) = (vec![0f64; k], vec![0f64; k], vec![0f64; k]);
    for (&t, &p) in y_true.iter().zip(y_pred) {
        let t = t as usize;
        if t == p {
            tp[t] += 1.0;
        } else {
            fp[p] += 1.0;
            fntv[t] += 1.0;
        }
    }
    let mut f1 = 0.0;
    let mut live = 0;
    for c in 0..k {
        let denom = 2.0 * tp[c] + fp[c] + fntv[c];
        if denom > 0.0 {
            f1 += 2.0 * tp[c] / denom;
            live += 1;
        }
    }
    if live == 0 { 0.0 } else { f1 / live as f64 }
}

/// Binary ROC-AUC from class-1 scores (rank statistic with tie
/// correction). Multi-class inputs fall back to accuracy.
pub fn auc_binary(y_true: &[f32], preds: &Predictions) -> f64 {
    match preds {
        Predictions::ClassScores { n_classes, scores } if *n_classes == 2 => {
            let n = y_true.len();
            let s: Vec<f64> = (0..n).map(|i| scores[i * 2 + 1] as f64).collect();
            let order = crate::util::stats::argsort(&s);
            // average ranks with ties
            let sorted: Vec<f64> = order.iter().map(|&i| s[i]).collect();
            let mut rank = vec![0.0; n];
            let mut i = 0;
            while i < n {
                let mut j = i;
                while j + 1 < n && sorted[j + 1] == sorted[i] {
                    j += 1;
                }
                let avg = (i + j + 2) as f64 / 2.0;
                for k in i..=j {
                    rank[order[k]] = avg;
                }
                i = j + 1;
            }
            let n_pos = y_true.iter().filter(|&&t| t == 1.0).count() as f64;
            let n_neg = n as f64 - n_pos;
            if n_pos == 0.0 || n_neg == 0.0 {
                return 0.5;
            }
            let rank_sum: f64 = y_true
                .iter()
                .zip(&rank)
                .filter(|(t, _)| **t == 1.0)
                .map(|(_, r)| *r)
                .sum();
            (rank_sum - n_pos * (n_pos + 1.0) / 2.0) / (n_pos * n_neg)
        }
        _ => accuracy(y_true, &preds.argmax_labels()),
    }
}

pub fn mse(y_true: &[f32], y_pred: &[f32]) -> f64 {
    if y_true.is_empty() {
        return 0.0;
    }
    y_true
        .iter()
        .zip(y_pred)
        .map(|(t, p)| ((t - p) as f64).powi(2))
        .sum::<f64>()
        / y_true.len() as f64
}

pub fn mae(y_true: &[f32], y_pred: &[f32]) -> f64 {
    if y_true.is_empty() {
        return 0.0;
    }
    y_true
        .iter()
        .zip(y_pred)
        .map(|(t, p)| ((t - p) as f64).abs())
        .sum::<f64>()
        / y_true.len() as f64
}

pub fn r2(y_true: &[f32], y_pred: &[f32]) -> f64 {
    let n = y_true.len();
    if n == 0 {
        return 0.0;
    }
    let mean: f64 = y_true.iter().map(|&t| t as f64).sum::<f64>() / n as f64;
    let ss_tot: f64 = y_true
        .iter()
        .map(|&t| (t as f64 - mean).powi(2))
        .sum();
    let ss_res: f64 = y_true
        .iter()
        .zip(y_pred)
        .map(|(t, p)| ((t - p) as f64).powi(2))
        .sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// The paper's Fig 7 relative-MSE improvement:
/// Δ(m1, m2) = (s(m2) - s(m1)) / max(s(m1), s(m2)).
pub fn relative_mse_improvement(mse_ours: f64, mse_theirs: f64) -> f64 {
    let denom = mse_ours.max(mse_theirs);
    if denom <= 0.0 {
        0.0
    } else {
        (mse_theirs - mse_ours) / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_accuracy_weights_classes_equally() {
        // 90 of class 0 all right, 10 of class 1 all wrong:
        // accuracy 0.9 but balanced accuracy 0.5
        let mut yt = vec![0.0f32; 90];
        yt.extend(vec![1.0f32; 10]);
        let yp = vec![0usize; 100];
        assert!((accuracy(&yt, &yp) - 0.9).abs() < 1e-12);
        assert!((balanced_accuracy(&yt, &yp) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_macro_perfect_is_one() {
        let yt = [0.0f32, 1.0, 2.0, 1.0];
        let yp = [0usize, 1, 2, 1];
        assert!((f1_macro(&yt, &yp) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auc_ranks_separable_scores() {
        let yt = [0.0f32, 0.0, 1.0, 1.0];
        let preds = Predictions::ClassScores {
            n_classes: 2,
            scores: vec![0.9, 0.1, 0.8, 0.2, 0.3, 0.7, 0.4, 0.6],
        };
        assert!((auc_binary(&yt, &preds) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn auc_handles_ties_as_half() {
        let yt = [0.0f32, 1.0];
        let preds = Predictions::ClassScores {
            n_classes: 2,
            scores: vec![0.5, 0.5, 0.5, 0.5],
        };
        assert!((auc_binary(&yt, &preds) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn regression_metrics() {
        let yt = [1.0f32, 2.0, 3.0];
        let yp = [1.0f32, 2.0, 4.0];
        assert!((mse(&yt, &yp) - 1.0 / 3.0).abs() < 1e-6);
        assert!((mae(&yt, &yp) - 1.0 / 3.0).abs() < 1e-6);
        assert!(r2(&yt, &yp) > 0.0 && r2(&yt, &yt) == 1.0);
    }

    #[test]
    fn utility_negates_errors() {
        let yt = [1.0f32, 2.0];
        let p = Predictions::Values(vec![0.0, 0.0]);
        assert!(Metric::Mse.utility(&yt, &p) < 0.0);
        assert_eq!(Metric::Mse.utility(&yt, &p), -Metric::Mse.compute(&yt, &p));
    }

    #[test]
    fn relative_improvement_matches_paper_formula() {
        assert!((relative_mse_improvement(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert!((relative_mse_improvement(2.0, 1.0) + 0.5).abs() < 1e-12);
    }

    #[test]
    fn metric_parse_roundtrip() {
        for m in [Metric::BalancedAccuracy, Metric::Accuracy, Metric::F1Macro,
                  Metric::Auc, Metric::Mse, Metric::Mae, Metric::R2] {
            assert_eq!(Metric::parse(m.name()), Some(m));
        }
        assert_eq!(Metric::parse("nope"), None);
    }
}
