//! Multi-tenant search service: one process-wide worker pool and one
//! content-addressed FE artifact store serving N concurrent AutoML
//! searches.
//!
//! The paper frames the executor as a database-style runtime; this
//! module makes the database move of *sharing* it. Each submitted job
//! registers a weighted tenant on the shared [`WorkerPool`] (stride
//! scheduling drains tenant queues proportionally to their weights),
//! runs its search through [`VolcanoML::with_shared`], and streams
//! incumbent improvements back over a channel. Admission control
//! bounds the blast radius: at most `max_active` searches run at
//! once, at most `pending_cap` queue behind them, and anything beyond
//! that is refused outright ([`AdmitError::Saturated`]) instead of
//! accepted and silently starved.
//!
//! ## The co-tenancy determinism contract
//!
//! A search's trajectory is a function of its own configuration and
//! seed — never of its co-tenants. Three properties compose to give
//! this:
//! 1. every per-search side effect commits serially in request order
//!    on the search's own thread (the evaluator's plan/execute/commit
//!    split), so pool scheduling order is invisible;
//! 2. FE artifacts are content-addressed by everything their
//!    computation depends on (dataset identity, search seed, fit
//!    rows, stage-prefix config), so a co-tenant publishing an
//!    artifact first changes *when* it is computed, never *what*;
//! 3. per-search budgets and deadlines are enforced inside the
//!    search's own evaluator — a tenant dying mid-batch retires its
//!    queue entries and frees the pool for everyone else.
//!
//! Consequently `tests/multi_tenant.rs` can assert bit-identical
//! incumbent trajectories solo vs. under 7 co-tenants. The one knob
//! that *does* shape trajectories is batch sizing: when a job leaves
//! `eval_batch == 0` it follows the pool's thread count, exactly as a
//! private pool of the same size would. Pin `eval_batch` to compare
//! runs across differently sized pools.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex};
use std::sync::Arc;
use std::thread;

use anyhow::Result;

use crate::cache::{FeStore, FeTenantStats};
use crate::coordinator::automl::{RunOutcome, SharedRuntime,
                                 VolcanoConfig, VolcanoML};
use crate::coordinator::evaluator::IncumbentEvent;
use crate::coordinator::SpaceScale;
use crate::data::dataset::Dataset;
use crate::data::metrics::Metric;
use crate::data::registry;
use crate::data::synthetic::generate;
use crate::ensemble::EnsembleMethod;
use crate::plan::PlanKind;
use crate::runtime::executor::{Executor, TenantId, WorkerPool,
                               MAX_TENANT_WEIGHT};
use crate::util::json::Json;
use crate::util::lock;

/// Sizing of the shared runtime: pool threads, FE store byte budget,
/// and the admission-control bounds.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Threads in the shared worker pool.
    pub workers: usize,
    /// Shared FE artifact store byte budget in megabytes (0 = off).
    pub fe_cache_mb: usize,
    /// Searches running concurrently; further admissions queue.
    pub max_active: usize,
    /// Bounded pending queue; admissions beyond it are refused.
    pub pending_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            fe_cache_mb: 256,
            max_active: 4,
            pending_cap: 16,
        }
    }
}

/// One search job: which dataset, how urgent (fair-share weight), and
/// the search knobs. Parsed from / serialised to the `serve`
/// subcommand's JSON-lines wire format by [`JobSpec::from_json`] /
/// [`JobSpec::to_json`].
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Client-chosen label echoed back in every event.
    pub name: String,
    /// Registry dataset name (see `volcanoml datasets`).
    pub dataset: String,
    /// Fair-share weight of this search's pool tenant (clamped into
    /// `1..=MAX_TENANT_WEIGHT` at parse time): a weight-2 tenant
    /// drains its queue twice as fast as a weight-1 co-tenant under
    /// saturation. Never affects the trajectory.
    pub weight: u32,
    pub plan: PlanKind,
    pub scale: SpaceScale,
    /// None = pick by task (balanced accuracy / MSE) once the
    /// dataset is resolved.
    pub metric: Option<Metric>,
    pub max_evals: usize,
    pub budget_secs: f64,
    /// 0 follows the shared pool's thread count (see module docs).
    pub eval_batch: usize,
    pub super_batch: usize,
    pub pipeline_depth: usize,
    pub seed: u64,
    /// Greedy-selection ensembling on top of the search.
    pub ensemble: bool,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            name: String::new(),
            dataset: String::new(),
            weight: 1,
            plan: PlanKind::CA,
            scale: SpaceScale::Medium,
            metric: None,
            max_evals: 60,
            budget_secs: f64::INFINITY,
            eval_batch: 0,
            super_batch: 1,
            pipeline_depth: 1,
            seed: 42,
            ensemble: false,
        }
    }
}

impl JobSpec {
    /// Parse a job spec from one JSON-lines request object. `name`
    /// and `dataset` are required; everything else falls back to
    /// [`JobSpec::default`]. Unknown enum values are hard errors —
    /// a typo'd plan must not silently search a different space.
    pub fn from_json(v: &Json) -> Result<JobSpec> {
        let d = JobSpec::default();
        let req_str = |key: &str| -> Result<String> {
            v.get(key)
                .and_then(|x| x.as_str())
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!(
                    "job spec: missing required string field {key:?}"))
        };
        let parse_enum = |key: &str| -> Result<Option<String>> {
            match v.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(Json::Str(s)) => Ok(Some(s.clone())),
                Some(other) => anyhow::bail!(
                    "job spec: {key} must be a string, got {other:?}"),
            }
        };
        let plan = match parse_enum("plan")? {
            Some(s) => PlanKind::parse(&s).ok_or_else(
                || anyhow::anyhow!("job spec: unknown plan {s:?}"))?,
            None => d.plan,
        };
        let scale = match parse_enum("scale")? {
            Some(s) => SpaceScale::parse(&s).ok_or_else(
                || anyhow::anyhow!("job spec: unknown scale {s:?}"))?,
            None => d.scale,
        };
        let metric = match parse_enum("metric")? {
            Some(s) => Some(Metric::parse(&s).ok_or_else(
                || anyhow::anyhow!("job spec: unknown metric {s:?}"))?),
            None => None,
        };
        let num = |key: &str, default: f64| -> f64 {
            v.get(key).and_then(|x| x.as_f64()).unwrap_or(default)
        };
        Ok(JobSpec {
            name: req_str("name")?,
            dataset: req_str("dataset")?,
            // clamp both ends: a zero/negative weight would never be
            // scheduled, and an overlarge one would zero the stride
            // and starve every co-tenant
            weight: (num("weight", f64::from(d.weight)) as u32)
                .clamp(1, MAX_TENANT_WEIGHT),
            plan,
            scale,
            metric,
            max_evals: num("evals", d.max_evals as f64) as usize,
            budget_secs: num("budget_secs", d.budget_secs),
            eval_batch: num("eval_batch", d.eval_batch as f64) as usize,
            super_batch: num("super_batch", d.super_batch as f64)
                as usize,
            pipeline_depth: num("pipeline_depth",
                                d.pipeline_depth as f64)
                as usize,
            seed: num("seed", d.seed as f64) as u64,
            ensemble: v.get("ensemble").and_then(|x| x.as_bool())
                .unwrap_or(d.ensemble),
        })
    }

    /// Serialise back to the wire format. `from_json(to_json(s))`
    /// round-trips exactly (infinite budgets are omitted — JSON has
    /// no `inf` — and fall back to the infinite default on parse).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("weight", Json::Num(f64::from(self.weight))),
            ("plan", Json::Str(self.plan.name().to_string())),
            ("scale", Json::Str(self.scale.name().to_string())),
            ("evals", Json::Num(self.max_evals as f64)),
            ("eval_batch", Json::Num(self.eval_batch as f64)),
            ("super_batch", Json::Num(self.super_batch as f64)),
            ("pipeline_depth",
             Json::Num(self.pipeline_depth as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("ensemble", Json::Bool(self.ensemble)),
        ];
        if let Some(m) = self.metric {
            pairs.push(("metric", Json::Str(m.name().to_string())));
        }
        if self.budget_secs.is_finite() {
            pairs.push(("budget_secs", Json::Num(self.budget_secs)));
        }
        Json::obj(pairs)
    }

    /// Lower to a search configuration for a resolved dataset.
    pub fn to_config(&self, ds: &Dataset) -> VolcanoConfig {
        VolcanoConfig {
            plan: self.plan,
            scale: self.scale,
            metric: self.metric.unwrap_or(
                if ds.task.is_classification() {
                    Metric::BalancedAccuracy
                } else {
                    Metric::Mse
                }),
            max_evals: self.max_evals,
            budget_secs: self.budget_secs,
            ensemble: if self.ensemble {
                EnsembleMethod::Selection
            } else {
                EnsembleMethod::None
            },
            eval_batch: self.eval_batch,
            super_batch: self.super_batch,
            pipeline_depth: self.pipeline_depth.max(1),
            seed: self.seed,
            ..Default::default()
        }
    }
}

/// Events streamed to a job's [`JobHandle`], in commit order.
#[derive(Debug)]
pub enum JobEvent {
    /// The search's incumbent improved.
    Incumbent {
        job: u64,
        n_evals: usize,
        utility: f64,
        elapsed_secs: f64,
        config_key: String,
    },
    /// The search finished; terminal.
    Done { job: u64, outcome: Box<RunOutcome> },
    /// The search failed (bad dataset, panic, ...); terminal.
    Failed { job: u64, error: String },
}

/// Client half of a submitted job: receives its event stream.
pub struct JobHandle {
    pub id: u64,
    pub name: String,
    rx: Receiver<JobEvent>,
}

impl JobHandle {
    /// Next event, blocking; `None` once the stream is exhausted
    /// (after a terminal [`JobEvent::Done`] / [`JobEvent::Failed`]).
    pub fn next_event(&self) -> Option<JobEvent> {
        self.rx.recv().ok()
    }

    /// Drain the stream to completion, returning the outcome (and
    /// discarding incumbent events — use [`Self::next_event`] to
    /// observe those).
    pub fn wait(self) -> Result<Box<RunOutcome>> {
        loop {
            match self.rx.recv() {
                Ok(JobEvent::Done { outcome, .. }) => {
                    return Ok(outcome);
                }
                Ok(JobEvent::Failed { error, .. }) => {
                    anyhow::bail!("job {}: {error}", self.name);
                }
                Ok(JobEvent::Incumbent { .. }) => continue,
                Err(_) => anyhow::bail!(
                    "job {}: worker vanished without a terminal \
                     event", self.name),
            }
        }
    }
}

/// Why an admission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Active and pending slots are all taken; resubmit later.
    Saturated { active: usize, pending: usize },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
        -> std::fmt::Result {
        match self {
            AdmitError::Saturated { active, pending } => write!(
                f,
                "service saturated: {active} active searches and \
                 {pending} pending (resubmit later)"),
        }
    }
}

impl std::error::Error for AdmitError {}

struct PendingJob {
    id: u64,
    spec: JobSpec,
    /// Pre-resolved dataset (tests / embedders); None resolves
    /// `spec.dataset` from the registry when the job starts.
    ds: Option<Dataset>,
    tx: Sender<JobEvent>,
}

struct SvcState {
    active: usize,
    pending: VecDeque<PendingJob>,
    next_id: u64,
}

struct SvcInner {
    pool: Arc<WorkerPool>,
    fe_store: Option<Arc<FeStore>>,
    max_active: usize,
    pending_cap: usize,
    state: Mutex<SvcState>,
    idle_cv: Condvar,
}

/// The process-wide multi-tenant search runtime (see module docs).
pub struct SearchService {
    inner: Arc<SvcInner>,
}

impl SearchService {
    pub fn new(cfg: ServiceConfig) -> SearchService {
        let fe_store = if cfg.fe_cache_mb == 0 {
            None
        } else {
            Some(Arc::new(FeStore::new(
                cfg.fe_cache_mb.saturating_mul(1024 * 1024))))
        };
        SearchService {
            inner: Arc::new(SvcInner {
                pool: Arc::new(WorkerPool::new(cfg.workers.max(1))),
                fe_store,
                max_active: cfg.max_active.max(1),
                pending_cap: cfg.pending_cap,
                state: Mutex::new(SvcState {
                    active: 0,
                    pending: VecDeque::new(),
                    next_id: 1,
                }),
                idle_cv: Condvar::new(),
            }),
        }
    }

    /// The shared worker pool (e.g. to size client-side batching).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.inner.pool
    }

    /// The shared FE store, when one is attached.
    pub fn fe_store(&self) -> Option<&Arc<FeStore>> {
        self.inner.fe_store.as_ref()
    }

    /// Per-tenant slice of the shared FE store's counters (all zero
    /// when no store is attached or the tenant never ran).
    pub fn tenant_fe_stats(&self, tenant: TenantId) -> FeTenantStats {
        self.inner
            .fe_store
            .as_ref()
            .map(|s| s.tenant_stats(tenant))
            .unwrap_or_default()
    }

    /// (active, pending) job counts right now.
    pub fn load(&self) -> (usize, usize) {
        let st = lock(&self.inner.state);
        (st.active, st.pending.len())
    }

    /// Submit a job whose dataset is resolved from the registry by
    /// name when it starts. Refused with [`AdmitError::Saturated`]
    /// when both the active slots and the pending queue are full.
    pub fn submit(&self, spec: JobSpec)
        -> Result<JobHandle, AdmitError> {
        self.admit(spec, None)
    }

    /// Submit a job on an explicitly provided dataset (bypasses the
    /// registry — the spec's `dataset` field is advisory).
    pub fn submit_on(&self, spec: JobSpec, ds: Dataset)
        -> Result<JobHandle, AdmitError> {
        self.admit(spec, Some(ds))
    }

    fn admit(&self, spec: JobSpec, ds: Option<Dataset>)
        -> Result<JobHandle, AdmitError> {
        let (tx, rx) = channel();
        let name = spec.name.clone();
        let mut st = lock(&self.inner.state);
        let id = st.next_id;
        st.next_id += 1;
        if st.active < self.inner.max_active {
            st.active += 1;
            drop(st);
            let inner = self.inner.clone();
            let job = PendingJob { id, spec, ds, tx };
            thread::spawn(move || worker_loop(&inner, job));
        } else if st.pending.len() < self.inner.pending_cap {
            st.pending.push_back(PendingJob { id, spec, ds, tx });
        } else {
            return Err(AdmitError::Saturated {
                active: st.active,
                pending: st.pending.len(),
            });
        }
        Ok(JobHandle { id, name, rx })
    }

    /// Block until no job is active or pending (the `serve` loop's
    /// clean-shutdown barrier).
    pub fn wait_idle(&self) {
        let mut st = lock(&self.inner.state);
        while st.active > 0 || !st.pending.is_empty() {
            st = self
                .inner
                .idle_cv
                .wait(st)
                .unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// Run one job, then keep draining the pending queue from this same
/// thread until it is empty (the active count is held, not re-taken,
/// so `max_active` bounds *threads*, not submissions).
fn worker_loop(inner: &Arc<SvcInner>, first: PendingJob) {
    let mut job = first;
    loop {
        run_job(inner, job);
        let mut st = lock(&inner.state);
        match st.pending.pop_front() {
            Some(next) => {
                drop(st);
                job = next;
            }
            None => {
                st.active -= 1;
                if st.active == 0 {
                    inner.idle_cv.notify_all();
                }
                return;
            }
        }
    }
}

fn run_job(inner: &Arc<SvcInner>, job: PendingJob) {
    let PendingJob { id, spec, ds, tx } = job;
    let ds = match ds {
        Some(ds) => ds,
        None => match registry::by_name(&spec.dataset) {
            Some(profile) => generate(&profile),
            None => {
                let _ = tx.send(JobEvent::Failed {
                    job: id,
                    error: format!("unknown dataset {:?} (see \
                                    `volcanoml datasets`)",
                                   spec.dataset),
                });
                return;
            }
        },
    };
    let cfg = spec.to_config(&ds);
    // one fair-share tenant per job; its queue drains at
    // weight-proportional speed and dies with the job
    let executor = Executor::shared(&inner.pool, spec.weight.max(1));
    let tenant = executor.tenant();
    let _span = crate::obs::span!("service", "job",
                                  "job" => id, "tenant" => tenant);
    let sink_tx = Mutex::new(tx.clone());
    let system = VolcanoML::new(cfg)
        .with_shared(SharedRuntime {
            executor: Some(executor),
            fe_store: inner.fe_store.clone(),
        })
        .with_incumbent_sink(Arc::new(move |e: &IncumbentEvent| {
            let _ = lock(&sink_tx).send(JobEvent::Incumbent {
                job: id,
                n_evals: e.n_evals,
                utility: e.utility,
                elapsed_secs: e.elapsed_secs,
                config_key: e.config.key(),
            });
        }));
    // a panicking search must not take the service thread (or its
    // co-tenants) down with it: surface it as a Failed event
    let result =
        catch_unwind(AssertUnwindSafe(|| system.run(&ds, None)));
    match result {
        Ok(Ok(outcome)) => {
            let _ = tx.send(JobEvent::Done {
                job: id,
                outcome: Box::new(outcome),
            });
        }
        Ok(Err(e)) => {
            let _ = tx.send(JobEvent::Failed {
                job: id,
                error: format!("{e:#}"),
            });
        }
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "search panicked".to_string());
            let _ = tx.send(JobEvent::Failed {
                job: id,
                error: format!("panic: {msg}"),
            });
        }
    }
    // the search joined every batch before returning, so the tenant's
    // queue is empty and removal succeeds; a leaked tenant would only
    // cost a HashMap entry, so a refusal is not fatal
    let _ = inner.pool.remove_tenant(tenant);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::data::synthetic::{GenKind, Profile};

    fn tiny_ds(seed: u64) -> Dataset {
        generate(&Profile {
            name: format!("svc-{seed}"),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Blobs { sep: 1.8 },
            n: 200,
            d: 5,
            noise: 0.04,
            imbalance: 1.0,
            redundant: 1,
            wild_scales: false,
            seed,
        })
    }

    fn quick_spec(name: &str, seed: u64) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            dataset: "synthetic".to_string(),
            max_evals: 10,
            eval_batch: 2,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn spec_json_round_trips_exactly() {
        let spec = JobSpec {
            name: "t1".into(),
            dataset: "quake".into(),
            weight: 3,
            plan: PlanKind::CC,
            scale: SpaceScale::Large,
            metric: Some(Metric::F1Macro),
            max_evals: 80,
            budget_secs: 12.5,
            eval_batch: 4,
            super_batch: 0,
            pipeline_depth: 2,
            seed: 99,
            ensemble: true,
        };
        let round = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, round);
        // infinite budget is omitted on the wire and restored by the
        // default on parse
        let inf = JobSpec { budget_secs: f64::INFINITY, ..spec };
        assert!(inf.to_json().get("budget_secs").is_none());
        let back = JobSpec::from_json(&inf.to_json()).unwrap();
        assert_eq!(inf, back);
    }

    #[test]
    fn spec_parse_rejects_bad_input() {
        let missing = Json::parse(r#"{"dataset": "quake"}"#).unwrap();
        assert!(JobSpec::from_json(&missing).is_err(), "no name");
        let bad_plan = Json::parse(
            r#"{"name": "x", "dataset": "quake", "plan": "XX"}"#)
            .unwrap();
        assert!(JobSpec::from_json(&bad_plan).is_err());
        let bad_metric = Json::parse(
            r#"{"name": "x", "dataset": "quake", "metric": "vibes"}"#)
            .unwrap();
        assert!(JobSpec::from_json(&bad_metric).is_err());
    }

    #[test]
    fn spec_parse_clamps_weight_to_schedulable_range() {
        // an oversized wire weight would zero the scheduler stride
        // and starve co-tenants; the parser clamps both ends
        let big = Json::parse(
            r#"{"name": "j", "dataset": "quake", "weight": 2000000}"#)
            .unwrap();
        assert_eq!(JobSpec::from_json(&big).unwrap().weight,
                   MAX_TENANT_WEIGHT);
        let zero = Json::parse(
            r#"{"name": "j", "dataset": "quake", "weight": 0}"#)
            .unwrap();
        assert_eq!(JobSpec::from_json(&zero).unwrap().weight, 1);
    }

    #[test]
    fn minimal_spec_uses_defaults() {
        let v = Json::parse(r#"{"name": "j", "dataset": "quake"}"#)
            .unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        let d = JobSpec::default();
        assert_eq!(spec.weight, d.weight);
        assert_eq!(spec.plan, d.plan);
        assert_eq!(spec.metric, None);
        assert_eq!(spec.max_evals, d.max_evals);
        assert!(spec.budget_secs.is_infinite());
    }

    #[test]
    fn service_runs_jobs_and_streams_incumbents() {
        let svc = SearchService::new(ServiceConfig {
            workers: 2,
            fe_cache_mb: 16,
            max_active: 2,
            pending_cap: 4,
        });
        let h1 = svc.submit_on(quick_spec("a", 1), tiny_ds(1))
            .unwrap();
        let h2 = svc.submit_on(quick_spec("b", 2), tiny_ds(2))
            .unwrap();
        assert_ne!(h1.id, h2.id);
        // both streams end in Done, with at least one incumbent each
        let mut seen = 0usize;
        while let Some(ev) = h1.next_event() {
            match ev {
                JobEvent::Incumbent { job, .. } => {
                    assert_eq!(job, h1.id);
                    seen += 1;
                }
                JobEvent::Done { job, outcome } => {
                    assert_eq!(job, h1.id);
                    assert!(outcome.n_evals <= 10);
                    assert_eq!(outcome.valid_curve.len(), seen,
                               "stream mirrors the curve");
                }
                JobEvent::Failed { error, .. } => {
                    panic!("job a failed: {error}");
                }
            }
        }
        assert!(seen >= 1, "no incumbent events");
        let out2 = h2.wait().unwrap();
        assert!(out2.best_config.is_some());
        svc.wait_idle();
        assert_eq!(svc.load(), (0, 0));
    }

    #[test]
    fn unknown_dataset_fails_cleanly() {
        let svc = SearchService::new(ServiceConfig {
            workers: 1,
            fe_cache_mb: 0,
            max_active: 1,
            pending_cap: 0,
        });
        let h = svc
            .submit(JobSpec {
                name: "ghost".into(),
                dataset: "no-such-dataset".into(),
                ..Default::default()
            })
            .unwrap();
        match h.wait() {
            Err(e) => assert!(
                format!("{e:#}").contains("no-such-dataset"),
                "{e:#}"),
            Ok(_) => panic!("expected failure"),
        }
        svc.wait_idle();
    }

    #[test]
    fn admission_control_queues_then_refuses() {
        // one active slot, one pending slot: the third concurrent
        // submission must be refused, and after the backlog drains a
        // resubmission is accepted
        let svc = SearchService::new(ServiceConfig {
            workers: 1,
            fe_cache_mb: 0,
            max_active: 1,
            pending_cap: 1,
        });
        // a search of this size runs for far longer than the
        // microseconds the two follow-up submissions take
        let slow = JobSpec {
            max_evals: 60,
            ..quick_spec("slow", 3)
        };
        let h1 = svc.submit_on(slow, tiny_ds(3)).unwrap();
        let h2 = svc.submit_on(quick_spec("q", 4), tiny_ds(4))
            .unwrap();
        let refused = svc.submit_on(quick_spec("r", 5), tiny_ds(5));
        match refused {
            Err(AdmitError::Saturated { active, pending }) => {
                assert_eq!(active, 1);
                assert_eq!(pending, 1);
            }
            Ok(_) => panic!("third job must be refused"),
        }
        h1.wait().unwrap();
        h2.wait().unwrap();
        svc.wait_idle();
        let h3 = svc.submit_on(quick_spec("again", 5), tiny_ds(5))
            .unwrap();
        h3.wait().unwrap();
        svc.wait_idle();
        assert_eq!(svc.load(), (0, 0));
    }
}
