//! Balancing stage (Fig 2 stage 3).
//!
//! Balancers act on the *training rows only*: they return an augmented
//! training index/row set while validation and test rows stay
//! untouched. Two operators ship by default (`none`,
//! `weight_balancer` — implemented as class re-sampling since the
//! compiled trainers take binary row masks, not per-row weights), and
//! `smote_balancer` is the search-space *enrichment* of Table 2 that
//! auto-sklearn cannot express.

use crate::data::dataset::Dataset;
use crate::space::{Config, ConfigSpace};
use crate::util::rng::Rng;

pub fn balancer_names(enriched: bool) -> Vec<&'static str> {
    if enriched {
        vec!["none", "weight_balancer", "smote_balancer"]
    } else {
        vec!["none", "weight_balancer"]
    }
}

pub fn balancer_space(name: &str) -> ConfigSpace {
    match name {
        "smote_balancer" => ConfigSpace::new()
            .int("k_neighbors", 1, 7, 5)
            .float("target_ratio", 0.5, 1.0, 1.0),
        _ => ConfigSpace::new(),
    }
}

/// Result of balancing: synthetic/duplicated rows to append to the
/// dataset, all of which belong to the training set.
pub struct Balanced {
    pub extra_x: Vec<f32>,
    pub extra_y: Vec<f32>,
    pub n_extra: usize,
}

pub fn apply_balancer(name: &str, ds: &Dataset, train: &[usize],
                      cfg: &Config, rng: &mut Rng) -> Balanced {
    let empty = Balanced { extra_x: Vec::new(), extra_y: Vec::new(),
                           n_extra: 0 };
    if !ds.task.is_classification() || name == "none" {
        return empty;
    }
    let k = ds.task.n_classes();
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); k];
    for &i in train {
        by_class[ds.label(i).min(k - 1)].push(i);
    }
    let max_count = by_class.iter().map(|v| v.len()).max().unwrap_or(0);
    if max_count == 0 {
        return empty;
    }
    match name {
        "weight_balancer" => {
            // oversample minority classes by duplication up to parity
            let mut out = empty;
            let mut buf = Vec::with_capacity(ds.d);
            for members in by_class.iter().filter(|m| !m.is_empty()) {
                let deficit = max_count - members.len();
                for _ in 0..deficit {
                    let &i = rng.choice(members);
                    ds.gather_row(i, &mut buf);
                    out.extra_x.extend_from_slice(&buf);
                    out.extra_y.push(ds.y[i]);
                    out.n_extra += 1;
                }
            }
            out
        }
        "smote_balancer" => {
            // synthetic minority oversampling: interpolate towards a
            // random one of the k nearest same-class neighbours
            let kn = cfg.usize_or("k_neighbors", 5).max(1);
            let ratio = cfg.f64_or("target_ratio", 1.0).clamp(0.1, 1.0);
            let mut out = empty;
            let mut buf = Vec::with_capacity(ds.d);
            let mut nbr = Vec::with_capacity(ds.d);
            for members in by_class.iter().filter(|m| !m.is_empty()) {
                let target = (max_count as f64 * ratio) as usize;
                if members.len() >= target {
                    continue;
                }
                let deficit = target - members.len();
                for _ in 0..deficit {
                    let &i = rng.choice(members);
                    ds.gather_row(i, &mut buf);
                    // k nearest same-class neighbours of i (brute force
                    // over the minority class, which is small)
                    let mut dists: Vec<(f64, usize)> = members
                        .iter()
                        .filter(|&&j| j != i)
                        .map(|&j| {
                            let d2: f64 = buf
                                .iter()
                                .enumerate()
                                .map(|(c, &a)| {
                                    ((a - ds.at(j, c)) as f64).powi(2)
                                })
                                .sum();
                            (d2, j)
                        })
                        .collect();
                    if dists.is_empty() {
                        // singleton class: duplicate
                        out.extra_x.extend_from_slice(&buf);
                        out.extra_y.push(ds.y[i]);
                        out.n_extra += 1;
                        continue;
                    }
                    dists.sort_by(|a, b| a.0.partial_cmp(&b.0)
                        .unwrap_or(std::cmp::Ordering::Equal));
                    let (_, j) = dists[rng.below(dists.len().min(kn))];
                    let t = rng.f64();
                    ds.gather_row(j, &mut nbr);
                    let row: Vec<f32> = buf
                        .iter()
                        .zip(&nbr)
                        .map(|(a, b)| a + (t as f32) * (b - a))
                        .collect();
                    out.extra_x.extend_from_slice(&row);
                    out.extra_y.push(ds.y[i]);
                    out.n_extra += 1;
                }
            }
            out
        }
        other => panic!("unknown balancer {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn imbalanced_ds() -> (Dataset, Vec<usize>) {
        let p = Profile {
            name: "imb".into(),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Blobs { sep: 2.0 },
            n: 300,
            d: 4,
            noise: 0.0,
            imbalance: 8.0,
            redundant: 0,
            wild_scales: false,
            seed: 5,
        };
        let ds = generate(&p);
        let train: Vec<usize> = (0..240).collect();
        (ds, train)
    }

    fn class_counts(ds: &Dataset, train: &[usize], extra_y: &[f32])
        -> Vec<usize> {
        let k = ds.task.n_classes();
        let mut c = vec![0usize; k];
        for &i in train {
            c[ds.label(i)] += 1;
        }
        for &y in extra_y {
            c[y as usize] += 1;
        }
        c
    }

    #[test]
    fn none_is_noop() {
        let (ds, train) = imbalanced_ds();
        let mut rng = Rng::new(0);
        let b = apply_balancer("none", &ds, &train, &Config::new(), &mut rng);
        assert_eq!(b.n_extra, 0);
    }

    #[test]
    fn weight_balancer_reaches_parity() {
        let (ds, train) = imbalanced_ds();
        let mut rng = Rng::new(1);
        let b = apply_balancer("weight_balancer", &ds, &train,
                               &Config::new(), &mut rng);
        let counts = class_counts(&ds, &train, &b.extra_y);
        assert_eq!(counts[0], counts[1]);
        assert!(b.n_extra > 0);
        assert_eq!(b.extra_x.len(), b.n_extra * ds.d);
    }

    #[test]
    fn smote_generates_interpolated_minority_rows() {
        let (ds, train) = imbalanced_ds();
        let mut rng = Rng::new(2);
        let cfg = balancer_space("smote_balancer").default_config();
        let b = apply_balancer("smote_balancer", &ds, &train, &cfg,
                               &mut rng);
        assert!(b.n_extra > 0);
        // synthetic rows are minority class only
        assert!(b.extra_y.iter().all(|&y| y == 1.0));
        // every synthetic row lies within the minority bounding box
        let minority: Vec<usize> = train.iter().copied()
            .filter(|&i| ds.label(i) == 1).collect();
        for col in 0..ds.d {
            let lo = minority.iter()
                .map(|&i| ds.at(i, col))
                .fold(f32::INFINITY, f32::min);
            let hi = minority.iter()
                .map(|&i| ds.at(i, col))
                .fold(f32::NEG_INFINITY, f32::max);
            for r in 0..b.n_extra {
                let v = b.extra_x[r * ds.d + col];
                assert!(v >= lo - 1e-4 && v <= hi + 1e-4,
                        "col {col} val {v} outside [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn regression_tasks_skip_balancing() {
        let p = Profile {
            name: "r".into(),
            task: Task::Regression,
            gen: GenKind::LinearReg { informative: 2 },
            n: 50,
            d: 3,
            noise: 0.1,
            imbalance: 1.0,
            redundant: 0,
            wild_scales: false,
            seed: 1,
        };
        let ds = generate(&p);
        let train: Vec<usize> = (0..40).collect();
        let mut rng = Rng::new(3);
        let b = apply_balancer("weight_balancer", &ds, &train,
                               &Config::new(), &mut rng);
        assert_eq!(b.n_extra, 0);
    }
}
