//! Feature-engineering pipeline (Fig 2): a fixed sequence of stages,
//! each choosing one operator from a pool, with per-operator
//! hyper-parameters — exactly the search-space structure of
//! auto-sklearn, plus the extensions the paper adds (smote balancer,
//! embedding-selection stage, user-defined operators/stages).

pub mod balance;
pub mod embedding;
pub mod ops;

use std::borrow::Cow;
use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::space::{Config, ConfigSpace};
use crate::util::rng::Rng;

/// User-defined feature operator (the `update_FEPipeline` API analogue
/// from Appendix A.2.2).
pub trait CustomOp: Send + Sync {
    fn name(&self) -> &str;
    fn space(&self) -> ConfigSpace;
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           rng: &mut Rng) -> ops::Fitted;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageKind {
    /// Frozen pre-trained embeddings (applied before everything).
    Embedding,
    /// Column scalers (fit on train).
    Scaler,
    /// Training-set balancers (append synthetic/duplicate rows).
    Balancer,
    /// Feature transformers (fit on train).
    Transformer,
    /// User-defined stage of custom operators.
    Custom,
}

#[derive(Clone)]
pub struct FeStage {
    pub name: String,
    pub kind: StageKind,
    pub ops: Vec<String>,
    pub custom: Vec<Arc<dyn CustomOp>>,
}

impl std::fmt::Debug for FeStage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FeStage")
            .field("name", &self.name)
            .field("kind", &self.kind)
            .field("ops", &self.ops)
            .finish()
    }
}

#[derive(Clone, Debug, Default)]
pub struct FePipeline {
    pub stages: Vec<FeStage>,
}

impl FePipeline {
    /// The auto-sklearn-equivalent pipeline: scaler -> balancer ->
    /// transformer. `enriched_smote` adds the Table 2 smote operator;
    /// `with_embedding` prepends the §6.3 embedding-selection stage.
    pub fn standard(enriched_smote: bool, with_embedding: bool)
        -> FePipeline {
        let mut stages = Vec::new();
        if with_embedding {
            stages.push(FeStage {
                name: "embedding".into(),
                kind: StageKind::Embedding,
                ops: embedding::embedding_names().iter()
                    .map(|s| s.to_string()).collect(),
                custom: Vec::new(),
            });
        }
        stages.push(FeStage {
            name: "scaler".into(),
            kind: StageKind::Scaler,
            ops: ops::scaler_names().iter().map(|s| s.to_string()).collect(),
            custom: Vec::new(),
        });
        stages.push(FeStage {
            name: "balancer".into(),
            kind: StageKind::Balancer,
            ops: balance::balancer_names(enriched_smote).iter()
                .map(|s| s.to_string()).collect(),
            custom: Vec::new(),
        });
        stages.push(FeStage {
            name: "transformer".into(),
            kind: StageKind::Transformer,
            ops: ops::transformer_names().iter()
                .map(|s| s.to_string()).collect(),
            custom: Vec::new(),
        });
        FePipeline { stages }
    }

    /// A reduced pipeline with only the four feature selectors of the
    /// paper's *small/medium* search spaces (§6.5).
    pub fn selectors_only() -> FePipeline {
        FePipeline {
            stages: vec![FeStage {
                name: "transformer".into(),
                kind: StageKind::Transformer,
                ops: vec![
                    "none".into(),
                    "select_percentile".into(),
                    "select_generic_univariate".into(),
                    "extra_trees_preproc".into(),
                    "linear_svm_preproc".into(),
                ],
                custom: Vec::new(),
            }],
        }
    }

    /// Append a user-defined stage (`update_FEPipeline` analogue).
    pub fn add_custom_stage(&mut self, name: &str,
                            ops: Vec<Arc<dyn CustomOp>>) {
        let mut names: Vec<String> = vec!["none".into()];
        names.extend(ops.iter().map(|o| o.name().to_string()));
        self.stages.push(FeStage {
            name: name.into(),
            kind: StageKind::Custom,
            ops: names,
            custom: ops,
        });
    }

    /// Add an operator to an existing stage (the `smote_balancer`-style
    /// fine-grained enrichment auto-sklearn cannot express).
    pub fn add_operator(&mut self, stage: &str, op: &str) {
        let st = self
            .stages
            .iter_mut()
            .find(|s| s.name == stage)
            .unwrap_or_else(|| panic!("no stage named {stage}"));
        if !st.ops.iter().any(|o| o == op) {
            st.ops.push(op.to_string());
        }
    }

    fn op_space(&self, stage: &FeStage, op: &str) -> ConfigSpace {
        match stage.kind {
            StageKind::Embedding => embedding::embedding_space(op),
            StageKind::Scaler => ops::scaler_space(op),
            StageKind::Balancer => balance::balancer_space(op),
            StageKind::Transformer => ops::transformer_space(op),
            StageKind::Custom => stage
                .custom
                .iter()
                .find(|c| c.name() == op)
                .map(|c| c.space())
                .unwrap_or_default(),
        }
    }

    /// Joint FE configuration space: one categorical per stage plus
    /// conditional per-operator hyper-parameters named
    /// `<stage>.<op>:<hp>`.
    pub fn space(&self) -> ConfigSpace {
        let mut cs = ConfigSpace::new();
        for stage in &self.stages {
            let op_refs: Vec<&str> =
                stage.ops.iter().map(|s| s.as_str()).collect();
            let default = if stage.ops.iter().any(|o| o == "none") {
                "none"
            } else {
                op_refs[0]
            };
            cs = cs.cat(&stage.name, &op_refs, default);
            for op in &stage.ops {
                for p in self.op_space(stage, op).params {
                    let mut q = p.clone();
                    q.name = format!("{}.{}:{}", stage.name, op, p.name);
                    // operator HPs activate when the stage picks the op;
                    // preserve any intra-op condition by AND-ing is not
                    // needed (op spaces here are flat).
                    q.condition = Some(crate::space::Condition {
                        parent: stage.name.clone(),
                        values: vec![op.clone()],
                    });
                    cs.params.push(q);
                }
            }
        }
        cs
    }

    /// Extract the operator-local config for `stage`/`op` from a joint
    /// FE config (strips the `<stage>.<op>:` prefix).
    fn local_cfg(stage: &str, op: &str, cfg: &Config) -> Config {
        let prefix = format!("{stage}.{op}:");
        let mut out = Config::new();
        for (k, v) in cfg.iter() {
            if let Some(rest) = k.strip_prefix(&prefix) {
                out.set(rest, v.clone());
            }
        }
        out
    }

    /// Fit on `train` rows and produce the transformed dataset plus
    /// the (possibly augmented) training index set. Validation/test
    /// indices remain valid because balancer rows are appended at the
    /// end.
    ///
    /// Copy-on-write: the input dataset is *borrowed* until a stage
    /// actually changes it — identity operators (`none` scalers and
    /// transformers, the `raw` embedding, balancers that add no rows)
    /// pass the borrow straight through, so a pipeline of no-ops
    /// performs zero row copies per evaluation instead of cloning the
    /// whole dataset, and any pipeline saves the old unconditional
    /// up-front clone (the first transforming stage writes its output
    /// into fresh storage directly).
    pub fn fit_apply<'d>(&self, ds: &'d Dataset, cfg: &Config,
                         train: &[usize], rng: &mut Rng)
        -> AppliedFe<'d> {
        let mut data: Cow<'d, Dataset> = Cow::Borrowed(ds);
        let mut train: Vec<usize> = train.to_vec();
        for stage in &self.stages {
            let fallback = if stage.ops.iter().any(|o| o == "none") {
                "none"
            } else {
                stage.ops[0].as_str()
            };
            let op = cfg.str_or(&stage.name, fallback).to_string();
            let local = Self::local_cfg(&stage.name, &op, cfg);
            match stage.kind {
                StageKind::Embedding => {
                    // the raw embedding is the identity
                    if op != "raw" {
                        data = Cow::Owned(
                            embedding::apply_embedding(&op, &data));
                    }
                }
                StageKind::Scaler => {
                    let f = ops::fit_scaler(&op, &data, &train, &local);
                    if !matches!(f, ops::Fitted::Identity) {
                        data = Cow::Owned(f.apply(&data));
                    }
                }
                StageKind::Balancer => {
                    let b = balance::apply_balancer(&op, &data, &train,
                                                    &local, rng);
                    if b.n_extra > 0 {
                        let d = data.to_mut();
                        let first_new = d.n;
                        d.x.extend_from_slice(&b.extra_x);
                        d.y.extend_from_slice(&b.extra_y);
                        d.n += b.n_extra;
                        train.extend(first_new..first_new + b.n_extra);
                    }
                }
                StageKind::Transformer => {
                    let f = ops::fit_transformer(&op, &data, &train,
                                                 &local, rng);
                    if !matches!(f, ops::Fitted::Identity) {
                        data = Cow::Owned(f.apply(&data));
                    }
                }
                StageKind::Custom => {
                    if op != "none" {
                        let c = stage
                            .custom
                            .iter()
                            .find(|c| c.name() == op)
                            .unwrap_or_else(|| panic!("no op {op}"));
                        let f = c.fit(&data, &train, &local, rng);
                        if !matches!(f, ops::Fitted::Identity) {
                            data = Cow::Owned(f.apply(&data));
                        }
                    }
                }
            }
        }
        AppliedFe { data, train }
    }
}

/// Output of the FE pipeline. `data` stays a borrow of the input
/// dataset when no stage modified it (see
/// [`FePipeline::fit_apply`]); callers read it through deref.
pub struct AppliedFe<'d> {
    pub data: Cow<'d, Dataset>,
    pub train: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::data::synthetic::{generate, GenKind, Profile};
    use crate::space::Value;

    fn ds() -> (Dataset, Vec<usize>) {
        let p = Profile {
            name: "pipe".into(),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Blobs { sep: 2.0 },
            n: 150,
            d: 6,
            noise: 0.02,
            imbalance: 5.0,
            redundant: 1,
            wild_scales: true,
            seed: 9,
        };
        (generate(&p), (0..120).collect())
    }

    #[test]
    fn standard_space_matches_paper_structure() {
        let pipe = FePipeline::standard(false, false);
        let cs = pipe.space();
        // three stage selectors
        assert!(cs.param("scaler").is_some());
        assert!(cs.param("balancer").is_some());
        assert!(cs.param("transformer").is_some());
        // conditional op HPs exist and are gated
        let p = cs.param("transformer.pca:keep_frac").unwrap();
        assert_eq!(p.condition.as_ref().unwrap().parent, "transformer");
        // ~dozens of FE hyper-parameters like the paper's 52
        assert!(cs.len() >= 30, "FE space too small: {}", cs.len());
    }

    #[test]
    fn enrichment_adds_smote_only_when_asked() {
        let plain = FePipeline::standard(false, false);
        assert!(!plain.space().param("balancer").map(|p| match &p.domain {
            crate::space::Domain::Cat(c) =>
                c.iter().any(|o| o == "smote_balancer"),
            _ => false,
        }).unwrap());
        let rich = FePipeline::standard(true, false);
        assert!(rich.space().param("balancer").map(|p| match &p.domain {
            crate::space::Domain::Cat(c) =>
                c.iter().any(|o| o == "smote_balancer"),
            _ => false,
        }).unwrap());
    }

    #[test]
    fn fit_apply_default_config_roundtrips() {
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let cfg = pipe.space().default_config();
        let mut rng = Rng::new(0);
        let out = pipe.fit_apply(&data, &cfg, &train, &mut rng);
        assert_eq!(out.data.n, data.n); // default balancer = none
        assert_eq!(out.train, train);
    }

    #[test]
    fn fit_apply_shares_untouched_data_without_copying() {
        // an all-identity pipeline (none scaler/balancer/transformer)
        // must pass the dataset through as a borrow — same storage,
        // zero row copies — instead of cloning it per evaluation
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let cfg = pipe.space().default_config();
        let mut rng = Rng::new(7);
        let out = pipe.fit_apply(&data, &cfg, &train, &mut rng);
        assert!(matches!(out.data, Cow::Borrowed(_)),
                "identity pipeline must not copy the dataset");
        assert_eq!(out.data.x.as_ptr(), data.x.as_ptr(),
                   "feature storage must be shared, not cloned");
        assert_eq!(out.data.y.as_ptr(), data.y.as_ptr(),
                   "label storage must be shared, not cloned");

        // ...and a modifying stage still materialises a fresh copy
        let scaled_cfg = cfg.merged(&Config::new().with(
            "scaler", Value::C("standard".into())));
        let mut rng2 = Rng::new(7);
        let out2 = pipe.fit_apply(&data, &scaled_cfg, &train,
                                  &mut rng2);
        assert!(matches!(out2.data, Cow::Owned(_)));
        assert_ne!(out2.data.x.as_ptr(), data.x.as_ptr());
        // the borrowed-through original is untouched
        assert_eq!(data.n, 150);
    }

    #[test]
    fn sampled_configs_all_run() {
        let (data, train) = ds();
        let pipe = FePipeline::standard(true, false);
        let cs = pipe.space();
        let mut rng = Rng::new(1);
        for _ in 0..25 {
            let cfg = cs.sample(&mut rng);
            let out = pipe.fit_apply(&data, &cfg, &train, &mut rng);
            assert!(out.data.d >= 1 && out.data.d <= ops::MAX_WIDTH);
            assert!(out.data.x.iter().all(|v| v.is_finite()),
                    "cfg {:?}", cfg.key());
            assert!(out.train.len() >= train.len());
            // balancer rows must be appended at the end
            for (a, b) in out.train.iter().zip(&train) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn balancer_augments_train_only() {
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let cfg = pipe.space().default_config()
            .merged(&Config::new().with("balancer",
                Value::C("weight_balancer".into())));
        let mut rng = Rng::new(2);
        let out = pipe.fit_apply(&data, &cfg, &train, &mut rng);
        assert!(out.data.n > data.n);
        assert!(out.train.len() > train.len());
        // appended indices point past the original rows
        assert!(out.train[train.len()..].iter().all(|&i| i >= data.n));
    }

    struct ClipOp;
    impl CustomOp for ClipOp {
        fn name(&self) -> &str {
            "clip3"
        }
        fn space(&self) -> ConfigSpace {
            ConfigSpace::new().float("limit", 1.0, 5.0, 3.0)
        }
        fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
               _rng: &mut Rng) -> ops::Fitted {
            let (mean, std) = ds.col_stats(train);
            let limit = cfg.f64_or("limit", 3.0);
            // winsorise via affine trick: here just standardise with a
            // widened scale as a stand-in custom transform
            let scale = std.iter()
                .map(|s| 1.0 / (s.max(1e-9) * limit)).collect();
            ops::Fitted::Affine { shift: mean, scale }
        }
    }

    #[test]
    fn custom_stage_is_searchable_and_runs() {
        let (data, train) = ds();
        let mut pipe = FePipeline::standard(false, false);
        pipe.add_custom_stage("postprocess", vec![Arc::new(ClipOp)]);
        let cs = pipe.space();
        assert!(cs.param("postprocess").is_some());
        assert!(cs.param("postprocess.clip3:limit").is_some());
        let cfg = cs.default_config()
            .merged(&Config::new().with("postprocess",
                Value::C("clip3".into()))
                .with("postprocess.clip3:limit", Value::F(2.0)));
        let mut rng = Rng::new(3);
        let out = pipe.fit_apply(&data, &cfg, &train, &mut rng);
        assert_eq!(out.data.d, data.d);
    }

    #[test]
    #[should_panic(expected = "no stage named")]
    fn add_operator_rejects_unknown_stage() {
        let mut pipe = FePipeline::standard(false, false);
        pipe.add_operator("nonexistent", "x");
    }
}
