//! Feature-engineering pipeline (Fig 2): a fixed sequence of stages,
//! each choosing one operator from a pool, with per-operator
//! hyper-parameters — exactly the search-space structure of
//! auto-sklearn, plus the extensions the paper adds (smote balancer,
//! embedding-selection stage, user-defined operators/stages).
//!
//! `fit_apply` is *staged and content-addressed*: every stage's
//! output is a deterministic function of (dataset identity, fit rows,
//! the stage-prefix config) — the per-stage rng streams are derived
//! from a rolling [`Fingerprint`] of exactly those inputs, never from
//! anything else in the joint configuration. That contract is what
//! lets the shared FE artifact store ([`crate::cache::FeStore`])
//! serve a cached prefix bit-identically to recomputing it: the
//! evaluator resolves the longest cached stage prefix and fits only
//! the suffix, and transforming stages row-shard their apply across
//! the worker pool ([`crate::fe::ops::Fitted::apply_sharded`]).
//! With no store and a serial executor the staged path degenerates to
//! the plain sequential loop.

pub mod balance;
pub mod embedding;
pub mod ops;

use std::ops::Deref;
use std::sync::Arc;

use crate::cache::{FeStore, Fingerprint, Resolved};
use crate::data::dataset::Dataset;
use crate::runtime::executor::Executor;
use crate::space::{Config, ConfigSpace};
use crate::util::rng::Rng;

/// User-defined feature operator (the `update_FEPipeline` API analogue
/// from Appendix A.2.2). Implementations must be deterministic given
/// `(ds, train, cfg, rng)` — the artifact store assumes a stage's
/// output is fully determined by its content address.
pub trait CustomOp: Send + Sync {
    fn name(&self) -> &str;
    fn space(&self) -> ConfigSpace;
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           rng: &mut Rng) -> ops::Fitted;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageKind {
    /// Frozen pre-trained embeddings (applied before everything).
    Embedding,
    /// Column scalers (fit on train).
    Scaler,
    /// Training-set balancers (append synthetic/duplicate rows).
    Balancer,
    /// Feature transformers (fit on train).
    Transformer,
    /// User-defined stage of custom operators.
    Custom,
}

#[derive(Clone)]
pub struct FeStage {
    pub name: String,
    pub kind: StageKind,
    pub ops: Vec<String>,
    pub custom: Vec<Arc<dyn CustomOp>>,
}

impl std::fmt::Debug for FeStage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FeStage")
            .field("name", &self.name)
            .field("kind", &self.kind)
            .field("ops", &self.ops)
            .finish()
    }
}

#[derive(Clone, Debug, Default)]
pub struct FePipeline {
    pub stages: Vec<FeStage>,
}

impl FePipeline {
    /// The auto-sklearn-equivalent pipeline: scaler -> balancer ->
    /// transformer. `enriched_smote` adds the Table 2 smote operator;
    /// `with_embedding` prepends the §6.3 embedding-selection stage.
    pub fn standard(enriched_smote: bool, with_embedding: bool)
        -> FePipeline {
        let mut stages = Vec::new();
        if with_embedding {
            stages.push(FeStage {
                name: "embedding".into(),
                kind: StageKind::Embedding,
                ops: embedding::embedding_names().iter()
                    .map(|s| s.to_string()).collect(),
                custom: Vec::new(),
            });
        }
        stages.push(FeStage {
            name: "scaler".into(),
            kind: StageKind::Scaler,
            ops: ops::scaler_names().iter().map(|s| s.to_string()).collect(),
            custom: Vec::new(),
        });
        stages.push(FeStage {
            name: "balancer".into(),
            kind: StageKind::Balancer,
            ops: balance::balancer_names(enriched_smote).iter()
                .map(|s| s.to_string()).collect(),
            custom: Vec::new(),
        });
        stages.push(FeStage {
            name: "transformer".into(),
            kind: StageKind::Transformer,
            ops: ops::transformer_names().iter()
                .map(|s| s.to_string()).collect(),
            custom: Vec::new(),
        });
        FePipeline { stages }
    }

    /// A reduced pipeline with only the four feature selectors of the
    /// paper's *small/medium* search spaces (§6.5).
    pub fn selectors_only() -> FePipeline {
        FePipeline {
            stages: vec![FeStage {
                name: "transformer".into(),
                kind: StageKind::Transformer,
                ops: vec![
                    "none".into(),
                    "select_percentile".into(),
                    "select_generic_univariate".into(),
                    "extra_trees_preproc".into(),
                    "linear_svm_preproc".into(),
                ],
                custom: Vec::new(),
            }],
        }
    }

    /// Append a user-defined stage (`update_FEPipeline` analogue).
    pub fn add_custom_stage(&mut self, name: &str,
                            ops: Vec<Arc<dyn CustomOp>>) {
        let mut names: Vec<String> = vec!["none".into()];
        names.extend(ops.iter().map(|o| o.name().to_string()));
        self.stages.push(FeStage {
            name: name.into(),
            kind: StageKind::Custom,
            ops: names,
            custom: ops,
        });
    }

    /// Add an operator to an existing stage (the `smote_balancer`-style
    /// fine-grained enrichment auto-sklearn cannot express).
    pub fn add_operator(&mut self, stage: &str, op: &str) {
        let st = self
            .stages
            .iter_mut()
            .find(|s| s.name == stage)
            .unwrap_or_else(|| panic!("no stage named {stage}"));
        if !st.ops.iter().any(|o| o == op) {
            st.ops.push(op.to_string());
        }
    }

    fn op_space(&self, stage: &FeStage, op: &str) -> ConfigSpace {
        match stage.kind {
            StageKind::Embedding => embedding::embedding_space(op),
            StageKind::Scaler => ops::scaler_space(op),
            StageKind::Balancer => balance::balancer_space(op),
            StageKind::Transformer => ops::transformer_space(op),
            StageKind::Custom => stage
                .custom
                .iter()
                .find(|c| c.name() == op)
                .map(|c| c.space())
                .unwrap_or_default(),
        }
    }

    /// Joint FE configuration space: one categorical per stage plus
    /// conditional per-operator hyper-parameters named
    /// `<stage>.<op>:<hp>`.
    pub fn space(&self) -> ConfigSpace {
        let mut cs = ConfigSpace::new();
        for stage in &self.stages {
            let op_refs: Vec<&str> =
                stage.ops.iter().map(|s| s.as_str()).collect();
            let default = if stage.ops.iter().any(|o| o == "none") {
                "none"
            } else {
                op_refs[0]
            };
            cs = cs.cat(&stage.name, &op_refs, default);
            for op in &stage.ops {
                for p in self.op_space(stage, op).params {
                    let mut q = p.clone();
                    q.name = format!("{}.{}:{}", stage.name, op, p.name);
                    // operator HPs activate when the stage picks the op;
                    // preserve any intra-op condition by AND-ing is not
                    // needed (op spaces here are flat).
                    q.condition = Some(crate::space::Condition {
                        parent: stage.name.clone(),
                        values: vec![op.clone()],
                    });
                    cs.params.push(q);
                }
            }
        }
        cs
    }

    /// The operator `cfg` picks for `stage`. The *joint* AutoML space
    /// carries FE parameters under the `fe:` prefix
    /// (`coordinator::joint_space` merges them as `fe:<stage>`), the
    /// pipeline-local space uses the bare stage name — both spellings
    /// resolve, prefixed first.
    fn stage_op<'c>(stage: &'c FeStage, cfg: &'c Config) -> &'c str {
        let fallback = if stage.ops.iter().any(|o| o == "none") {
            "none"
        } else {
            stage.ops[0].as_str()
        };
        let prefixed = format!("fe:{}", stage.name);
        match cfg.get(&prefixed) {
            Some(crate::space::Value::C(s)) => s.as_str(),
            _ => cfg.str_or(&stage.name, fallback),
        }
    }

    /// Extract the operator-local config for `stage`/`op` from a joint
    /// FE config (strips the `fe:<stage>.<op>:` / `<stage>.<op>:`
    /// prefix — joint and pipeline-local spellings both resolve).
    fn local_cfg(stage: &str, op: &str, cfg: &Config) -> Config {
        let bare = format!("{stage}.{op}:");
        let prefixed = format!("fe:{bare}");
        let mut out = Config::new();
        for (k, v) in cfg.iter() {
            if let Some(rest) = k
                .strip_prefix(&prefixed)
                .or_else(|| k.strip_prefix(&bare))
            {
                out.set(rest, v.clone());
            }
        }
        out
    }

    /// Resolve the per-stage execution plan for `cfg`: chosen op,
    /// operator-local config, and the rolling content fingerprint of
    /// the stage *prefix* ending at each stage (seeded from
    /// `fx.base`, which carries the dataset/split/seed identity).
    fn plan_stages(&self, cfg: &Config, base: Fingerprint)
        -> Vec<StagePlan<'_>> {
        let mut fp = base;
        let mut plans = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            let op = Self::stage_op(stage, cfg).to_string();
            let local = Self::local_cfg(&stage.name, &op, cfg);
            fp = fp
                .push_str(&stage.name)
                .push_str(&op)
                .push_config(&local);
            plans.push(StagePlan { stage, op, local, fp });
        }
        plans
    }

    /// Fit on `train` rows and produce the transformed dataset plus
    /// the (possibly augmented) training index set. Validation/test
    /// indices remain valid because balancer rows are appended at the
    /// end.
    ///
    /// Staged execution (see module docs):
    /// 1. resolve every stage's (op, local config, prefix
    ///    fingerprint) — pure config work, no data touched;
    /// 2. with a store, the **longest cached prefix wins**: probe the
    ///    fingerprints from the last stage backwards and resume from
    ///    the deepest artifact found;
    /// 3. run the remaining stages. Statically-identity stages
    ///    (`none` ops, the `raw` embedding) are skipped outright.
    ///    With a store, each remaining stage first coalesces with any
    ///    concurrent fit of the same prefix
    ///    ([`crate::cache::FeStore::begin`]); a transforming stage
    ///    publishes its output for every other in-flight evaluation.
    ///
    /// Copy-on-write is preserved: the input dataset is *borrowed*
    /// until a stage actually changes it — an all-identity pipeline
    /// performs zero row copies — and a cached artifact is *shared*
    /// (`Arc`), never cloned.
    pub fn fit_apply<'d>(&self, ds: &'d Dataset, cfg: &Config,
                         train: &'d [usize], fx: &FeExec)
        -> AppliedFe<'d> {
        let plans = self.plan_stages(cfg, fx.base);
        let mut data: FeData<'d> = FeData::Borrowed(ds);
        let mut rows: FeRows<'d> = FeRows::Borrowed(train);
        let mut start = 0usize;
        if let Some(store) = fx.store {
            for (k, plan) in plans.iter().enumerate().rev() {
                // static-identity fingerprints are never published:
                // skip the guaranteed-miss shard lookups
                if plan.is_static_identity() {
                    continue;
                }
                if let Some(art) = store.lookup_as(plan.fp, fx.tenant) {
                    data = FeData::Shared(art.data.clone());
                    rows = FeRows::Shared(art.train.clone());
                    start = k + 1;
                    break;
                }
            }
        }
        for plan in &plans[start..] {
            if plan.is_static_identity() {
                continue;
            }
            match fx.store {
                None => {
                    self.run_stage(plan, &mut data, &mut rows, fx);
                }
                Some(store) => match store.begin_as(plan.fp, fx.tenant) {
                    Resolved::Ready(art) => {
                        data = FeData::Shared(art.data.clone());
                        rows = FeRows::Shared(art.train.clone());
                    }
                    Resolved::Compute(ticket) => {
                        // snapshot the stage input (shallow: column
                        // Arcs only) so the publish can charge the
                        // byte bound for novel columns alone
                        let before: Dataset = (*data).clone();
                        let changed = self.run_stage(plan, &mut data,
                                                     &mut rows, fx);
                        if changed {
                            data = data.into_shared();
                            if let FeData::Shared(a) = &data {
                                ticket.publish_vs(a.clone(),
                                                  rows.share(),
                                                  &before);
                            } else {
                                debug_assert!(
                                    false,
                                    "changed stage must own its output");
                            }
                        } else if let FeData::Shared(a) = &data {
                            // dynamic identity (a balancer that adds
                            // no rows, a transformer whose fit
                            // degenerates): alias the unchanged state
                            // under this stage's fingerprint —
                            // zero-copy, since the state is already
                            // an artifact — so later evaluations
                            // sharing the prefix skip the (possibly
                            // expensive) fit instead of rediscovering
                            // the identity every time. Aliased vs
                            // itself: every column reads as shared,
                            // so the alias is charged ~nothing.
                            let base = a.clone();
                            ticket.publish_vs(a.clone(), rows.share(),
                                              &base);
                        }
                        // remaining !changed case (the state is still
                        // the pristine borrow): the dropped ticket
                        // abandons the pending entry and wakes any
                        // coalesced waiters — publishing would cost a
                        // full dataset copy to cache a no-op
                    }
                },
            }
        }
        AppliedFe { data, train: rows }
    }

    /// Execute one stage against the current `(data, rows)` state,
    /// returning whether the stage changed it. The stage's private
    /// rng stream is seeded from its prefix fingerprint, so the
    /// output depends on nothing outside the content address.
    fn run_stage(&self, plan: &StagePlan, data: &mut FeData<'_>,
                 rows: &mut FeRows<'_>, fx: &FeExec) -> bool {
        // Static span names (the tracer interns `&'static str`), one
        // per stage kind.
        let span_name = match plan.stage.kind {
            StageKind::Embedding => "fe.embedding",
            StageKind::Scaler => "fe.scaler",
            StageKind::Balancer => "fe.balancer",
            StageKind::Transformer => "fe.transformer",
            StageKind::Custom => "fe.custom",
        };
        let _span = crate::obs::span!("fe", span_name,
                                      "tenant" => fx.tenant);
        let mut rng = Rng::new(plan.fp.seed64());
        let op = plan.op.as_str();
        match plan.stage.kind {
            StageKind::Embedding => {
                // the raw (identity) embedding is filtered out by
                // is_static_identity before we get here
                let out = embedding::apply_embedding(op, &**data);
                *data = FeData::Owned(out);
                true
            }
            StageKind::Scaler => {
                // mergeable fits (min/max, moments, quantile grids)
                // row-shard over the pool; the blocked merge keeps
                // them bit-identical at every worker count
                let f = ops::fit_scaler_with(op, &**data, rows,
                                             &plan.local, fx.exec);
                if matches!(f, ops::Fitted::Identity) {
                    false
                } else {
                    let out = Self::apply_fitted(&f, &**data, fx);
                    *data = FeData::Owned(out);
                    true
                }
            }
            StageKind::Balancer => {
                let b = balance::apply_balancer(op, &**data, rows,
                                                &plan.local, &mut rng);
                if b.n_extra == 0 {
                    false
                } else {
                    let d = data.make_mut();
                    let first_new = d.n;
                    d.append_rows(&b.extra_x, &b.extra_y);
                    rows.make_mut()
                        .extend(first_new..first_new + b.n_extra);
                    true
                }
            }
            StageKind::Transformer => {
                let f = ops::fit_transformer(op, &**data, rows,
                                             &plan.local, &mut rng);
                if matches!(f, ops::Fitted::Identity) {
                    false
                } else {
                    let out = Self::apply_fitted(&f, &**data, fx);
                    *data = FeData::Owned(out);
                    true
                }
            }
            StageKind::Custom => {
                let c = plan
                    .stage
                    .custom
                    .iter()
                    .find(|c| c.name() == op)
                    .unwrap_or_else(|| panic!("no op {op}"));
                let f = c.fit(&**data, rows, &plan.local, &mut rng);
                if matches!(f, ops::Fitted::Identity) {
                    false
                } else {
                    let out = Self::apply_fitted(&f, &**data, fx);
                    *data = FeData::Owned(out);
                    true
                }
            }
        }
    }

    /// Apply a fitted transform, row-sharded across the worker pool
    /// when one is attached (bit-identical to the serial apply; see
    /// [`ops::Fitted::apply_sharded`]).
    fn apply_fitted(f: &ops::Fitted, ds: &Dataset, fx: &FeExec)
        -> Dataset {
        match fx.exec {
            Some(ex) => f.apply_sharded(ds, ex),
            None => f.apply(ds),
        }
    }
}

/// Per-stage execution plan resolved from the joint config (see
/// [`FePipeline::plan_stages`]).
struct StagePlan<'s> {
    stage: &'s FeStage,
    op: String,
    local: Config,
    /// Content fingerprint of the stage prefix ending here.
    fp: Fingerprint,
}

impl StagePlan<'_> {
    /// Ops that are the identity by construction: nothing to compute,
    /// nothing to cache (their output state *is* the previous one).
    fn is_static_identity(&self) -> bool {
        match self.stage.kind {
            StageKind::Embedding => self.op == "raw",
            _ => self.op == "none",
        }
    }
}

/// Execution context of a staged [`FePipeline::fit_apply`]: the
/// artifact store (None = caching off), the worker pool for
/// row-sharded applies (None = single-threaded), and the base
/// fingerprint carrying everything outside the FE config that stage
/// outputs depend on (evaluator seed, dataset identity, fit rows).
pub struct FeExec<'e> {
    pub store: Option<&'e FeStore>,
    pub exec: Option<&'e Executor>,
    pub base: Fingerprint,
    /// Fair-share tenant the store traffic is attributed to (the
    /// submitting search's `Executor::tenant`); purely observational
    /// — artifacts are content-addressed, so tenants share them.
    pub tenant: u64,
}

impl FeExec<'static> {
    /// Store-less, single-threaded context (unit tests, standalone
    /// pipeline use): stage rng streams still derive from `seed` via
    /// the same fingerprint scheme as the evaluator path.
    pub fn local(seed: u64) -> FeExec<'static> {
        FeExec {
            store: None,
            exec: None,
            base: Fingerprint::new().push_u64(seed),
            tenant: 0,
        }
    }
}

/// The dataset state flowing through a staged `fit_apply`: borrowed
/// from the caller until a stage changes it, owned after a fresh
/// transform (store off), or shared with the artifact store / other
/// in-flight evaluations (`Arc`). Derefs to [`Dataset`].
pub enum FeData<'d> {
    Borrowed(&'d Dataset),
    Owned(Dataset),
    Shared(Arc<Dataset>),
}

impl Deref for FeData<'_> {
    type Target = Dataset;

    fn deref(&self) -> &Dataset {
        match self {
            FeData::Borrowed(d) => d,
            FeData::Owned(d) => d,
            FeData::Shared(d) => d,
        }
    }
}

impl<'d> FeData<'d> {
    /// Mutable access, cloning out of a borrow or a shared artifact
    /// first (copy-on-write: artifacts are immutable once published).
    fn make_mut(&mut self) -> &mut Dataset {
        if !matches!(self, FeData::Owned(_)) {
            let cloned: Dataset = (**self).clone();
            *self = FeData::Owned(cloned);
        }
        match self {
            FeData::Owned(d) => d,
            _ => unreachable!("made owned above"),
        }
    }

    /// Move an owned dataset behind an `Arc` (for publication);
    /// borrows and already-shared states pass through.
    fn into_shared(self) -> FeData<'d> {
        match self {
            FeData::Owned(d) => FeData::Shared(Arc::new(d)),
            other => other,
        }
    }
}

/// The training-row index set flowing alongside [`FeData`]: borrowed
/// from the caller until a balancer augments it, owned after an
/// augmentation (store off), or `Arc`-shared with the artifact store.
/// Derefs to `[usize]`, so callers read it as a slice; the
/// copy-on-write mirror of `FeData` keeps store hits O(1) instead of
/// cloning the row set per evaluation.
pub enum FeRows<'d> {
    Borrowed(&'d [usize]),
    Owned(Vec<usize>),
    Shared(Arc<Vec<usize>>),
}

impl Deref for FeRows<'_> {
    type Target = [usize];

    fn deref(&self) -> &[usize] {
        match self {
            FeRows::Borrowed(r) => r,
            FeRows::Owned(v) => v,
            FeRows::Shared(a) => a,
        }
    }
}

impl<'d> FeRows<'d> {
    /// Mutable access, cloning out of a borrow or a shared artifact
    /// first (artifacts are immutable once published).
    fn make_mut(&mut self) -> &mut Vec<usize> {
        if !matches!(self, FeRows::Owned(_)) {
            let v: Vec<usize> = self.to_vec();
            *self = FeRows::Owned(v);
        }
        match self {
            FeRows::Owned(v) => v,
            _ => unreachable!("made owned above"),
        }
    }

    /// An `Arc` of the current row set for publication: owned rows
    /// convert to shared in place (no copy), already-shared rows
    /// clone the `Arc`, borrowed rows are copied once (per published
    /// artifact, never per hit).
    fn share(&mut self) -> Arc<Vec<usize>> {
        match self {
            FeRows::Borrowed(r) => Arc::new(r.to_vec()),
            FeRows::Shared(a) => a.clone(),
            FeRows::Owned(_) => {
                let taken =
                    std::mem::replace(self, FeRows::Borrowed(&[]));
                let FeRows::Owned(v) = taken else {
                    unreachable!("matched Owned above");
                };
                let a = Arc::new(v);
                *self = FeRows::Shared(a.clone());
                a
            }
        }
    }
}

/// Output of the FE pipeline. `data` stays a borrow of the input
/// dataset when no stage modified it, and an `Arc` into the artifact
/// store when the final stage was served from (or published to) the
/// cache; callers read both `data` and `train` through deref.
pub struct AppliedFe<'d> {
    pub data: FeData<'d>,
    pub train: FeRows<'d>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::data::synthetic::{generate, GenKind, Profile};
    use crate::space::Value;

    fn assert_bits_eq(a: &Dataset, b: &Dataset) {
        assert_eq!((a.n, a.d), (b.n, b.d));
        for j in 0..a.d {
            for (x, y) in a.col(j).iter().zip(b.col(j)) {
                assert_eq!(x.to_bits(), y.to_bits(), "col {j}");
            }
        }
    }

    fn ds() -> (Dataset, Vec<usize>) {
        let p = Profile {
            name: "pipe".into(),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Blobs { sep: 2.0 },
            n: 150,
            d: 6,
            noise: 0.02,
            imbalance: 5.0,
            redundant: 1,
            wild_scales: true,
            seed: 9,
        };
        (generate(&p), (0..120).collect())
    }

    #[test]
    fn standard_space_matches_paper_structure() {
        let pipe = FePipeline::standard(false, false);
        let cs = pipe.space();
        // three stage selectors
        assert!(cs.param("scaler").is_some());
        assert!(cs.param("balancer").is_some());
        assert!(cs.param("transformer").is_some());
        // conditional op HPs exist and are gated
        let p = cs.param("transformer.pca:keep_frac").unwrap();
        assert_eq!(p.condition.as_ref().unwrap().parent, "transformer");
        // ~dozens of FE hyper-parameters like the paper's 52
        assert!(cs.len() >= 30, "FE space too small: {}", cs.len());
    }

    #[test]
    fn enrichment_adds_smote_only_when_asked() {
        let plain = FePipeline::standard(false, false);
        assert!(!plain.space().param("balancer").map(|p| match &p.domain {
            crate::space::Domain::Cat(c) =>
                c.iter().any(|o| o == "smote_balancer"),
            _ => false,
        }).unwrap());
        let rich = FePipeline::standard(true, false);
        assert!(rich.space().param("balancer").map(|p| match &p.domain {
            crate::space::Domain::Cat(c) =>
                c.iter().any(|o| o == "smote_balancer"),
            _ => false,
        }).unwrap());
    }

    #[test]
    fn fit_apply_default_config_roundtrips() {
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let cfg = pipe.space().default_config();
        let out = pipe.fit_apply(&data, &cfg, &train,
                                 &FeExec::local(0));
        assert_eq!(out.data.n, data.n); // default balancer = none
        assert_eq!(&out.train[..], &train[..]);
        // the untouched row set is borrowed, not copied
        assert!(matches!(out.train, FeRows::Borrowed(_)));
    }

    #[test]
    fn fit_apply_shares_untouched_data_without_copying() {
        // an all-identity pipeline (none scaler/balancer/transformer)
        // must pass the dataset through as a borrow — same storage,
        // zero row copies — instead of cloning it per evaluation
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let cfg = pipe.space().default_config();
        let out = pipe.fit_apply(&data, &cfg, &train,
                                 &FeExec::local(7));
        assert!(matches!(out.data, FeData::Borrowed(_)),
                "identity pipeline must not copy the dataset");
        for j in 0..data.d {
            assert!(Arc::ptr_eq(out.data.col_arc(j), data.col_arc(j)),
                    "column {j} must be shared, not cloned");
        }
        assert!(Arc::ptr_eq(&out.data.y, &data.y),
                "label storage must be shared, not cloned");

        // ...and a modifying stage still materialises fresh columns
        let scaled_cfg = cfg.merged(&Config::new().with(
            "scaler", Value::C("standard".into())));
        let out2 = pipe.fit_apply(&data, &scaled_cfg, &train,
                                  &FeExec::local(7));
        assert!(matches!(out2.data, FeData::Owned(_)));
        assert!(!Arc::ptr_eq(out2.data.col_arc(0), data.col_arc(0)));
        // labels ride through shared even when features change
        assert!(Arc::ptr_eq(&out2.data.y, &data.y));
        // the borrowed-through original is untouched
        assert_eq!(data.n, 150);
    }

    #[test]
    fn joint_prefixed_fe_keys_drive_the_stages() {
        // the joint AutoML space names FE parameters `fe:<stage>` /
        // `fe:<stage>.<op>:<hp>` (coordinator::joint_space); those
        // spellings must drive fit_apply exactly like the bare ones —
        // a searched FE config is not allowed to fall back to the
        // identity defaults
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let bare = Config::new()
            .with("scaler", Value::C("quantile".into()))
            .with("scaler.quantile:n_quantiles", Value::I(32));
        let prefixed = Config::new()
            .with("fe:scaler", Value::C("quantile".into()))
            .with("fe:scaler.quantile:n_quantiles", Value::I(32));
        let a = pipe.fit_apply(&data, &bare, &train,
                               &FeExec::local(3));
        let b = pipe.fit_apply(&data, &prefixed, &train,
                               &FeExec::local(3));
        // the stage genuinely transformed...
        assert!(!Arc::ptr_eq(a.data.col_arc(0), data.col_arc(0)),
                "quantile scaler must transform");
        // ...and both spellings produce the identical output
        assert_bits_eq(&a.data, &b.data);
    }

    #[test]
    fn sampled_configs_all_run() {
        let (data, train) = ds();
        let pipe = FePipeline::standard(true, false);
        let cs = pipe.space();
        let mut rng = Rng::new(1);
        let fx = FeExec::local(1);
        for _ in 0..25 {
            let cfg = cs.sample(&mut rng);
            let out = pipe.fit_apply(&data, &cfg, &train, &fx);
            assert!(out.data.d >= 1 && out.data.d <= ops::MAX_WIDTH);
            assert!((0..out.data.d).all(|j| out.data.col(j).iter()
                        .all(|v| v.is_finite())),
                    "cfg {:?}", cfg.key());
            assert!(out.train.len() >= train.len());
            // balancer rows must be appended at the end
            for (a, b) in out.train.iter().zip(&train) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn fit_apply_is_deterministic_per_config() {
        // same config + same FeExec seed => bit-identical output,
        // regardless of what other configs ran in between
        let (data, train) = ds();
        let pipe = FePipeline::standard(true, false);
        let cs = pipe.space();
        let cfg = cs.sample(&mut Rng::new(5));
        let a = pipe.fit_apply(&data, &cfg, &train, &FeExec::local(4));
        let other = cs.sample(&mut Rng::new(6));
        let _ = pipe.fit_apply(&data, &other, &train,
                               &FeExec::local(4));
        let b = pipe.fit_apply(&data, &cfg, &train, &FeExec::local(4));
        assert_bits_eq(&a.data, &b.data);
        assert_eq!(&a.train[..], &b.train[..]);
    }

    #[test]
    fn store_on_is_bit_identical_to_store_off() {
        // the artifact store is a pure wall-clock knob: with it on
        // (any bound), every sampled config produces the identical
        // bytes as the store-less run — including on the second pass,
        // when everything is served from the cache
        let (data, train) = ds();
        let pipe = FePipeline::standard(true, false);
        let cs = pipe.space();
        let store = FeStore::new(64 * 1024 * 1024);
        let base = Fingerprint::new().push_u64(11);
        let off = FeExec { store: None, exec: None, base, tenant: 0 };
        let on = FeExec { store: Some(&store), exec: None, base, tenant: 0 };
        let mut rng = Rng::new(2);
        let cfgs: Vec<Config> =
            (0..12).map(|_| cs.sample(&mut rng)).collect();
        for pass in 0..2 {
            for cfg in &cfgs {
                let a = pipe.fit_apply(&data, cfg, &train, &off);
                let b = pipe.fit_apply(&data, cfg, &train, &on);
                assert_eq!(a.data.n, b.data.n, "pass {pass}");
                assert_eq!(a.data.d, b.data.d, "pass {pass}");
                assert_bits_eq(&a.data, &b.data);
                assert_eq!(&a.train[..], &b.train[..],
                           "pass {pass}");
            }
        }
        let st = store.stats();
        assert!(st.hits > 0, "second pass must hit the store");
        assert!(st.bytes <= st.cap_bytes);
    }

    #[test]
    fn longest_cached_prefix_wins() {
        // cfg1 publishes the scaler artifact; cfg2 shares that prefix
        // and only computes its transformer suffix
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let store = FeStore::new(64 * 1024 * 1024);
        let base = Fingerprint::new().push_u64(21);
        let fx = FeExec { store: Some(&store), exec: None, base, tenant: 0 };
        let cfg1 = Config::new()
            .with("scaler", Value::C("standard".into()));
        let _ = pipe.fit_apply(&data, &cfg1, &train, &fx);
        let st = store.stats();
        assert_eq!((st.misses, st.published), (1, 1),
                   "one transforming stage => one artifact");
        let cfg2 = Config::new()
            .with("scaler", Value::C("standard".into()))
            .with("transformer", Value::C("pca".into()));
        let out2 = pipe.fit_apply(&data, &cfg2, &train, &fx);
        let st = store.stats();
        assert_eq!(st.hits, 1, "scaler prefix must be served");
        assert_eq!((st.misses, st.published), (2, 2),
                   "only the pca suffix is computed");
        // and the result matches the store-less computation bitwise
        let off = pipe.fit_apply(&data, &cfg2, &train,
                                 &FeExec { store: None, exec: None,
                                           base, tenant: 0 });
        assert_bits_eq(&out2.data, &off.data);
    }

    #[test]
    fn balancer_artifacts_capture_augmented_train_rows() {
        // a cached balancer stage must hand back the augmented train
        // index set, not just the data
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let store = FeStore::new(64 * 1024 * 1024);
        let base = Fingerprint::new().push_u64(31);
        let fx = FeExec { store: Some(&store), exec: None, base, tenant: 0 };
        let cfg = Config::new()
            .with("balancer", Value::C("weight_balancer".into()));
        let first = pipe.fit_apply(&data, &cfg, &train, &fx);
        assert!(first.train.len() > train.len());
        let again = pipe.fit_apply(&data, &cfg, &train, &fx);
        assert!(matches!(again.data, FeData::Shared(_)),
                "second run must be served from the store");
        assert!(matches!(again.train, FeRows::Shared(_)),
                "cached train rows must be Arc-shared, not cloned");
        assert_eq!(&first.train[..], &again.train[..]);
        assert_eq!(first.data.n, again.data.n);
    }

    #[test]
    fn balancer_augments_train_only() {
        let (data, train) = ds();
        let pipe = FePipeline::standard(false, false);
        let cfg = pipe.space().default_config()
            .merged(&Config::new().with("balancer",
                Value::C("weight_balancer".into())));
        let out = pipe.fit_apply(&data, &cfg, &train,
                                 &FeExec::local(2));
        assert!(out.data.n > data.n);
        assert!(out.train.len() > train.len());
        // appended indices point past the original rows
        assert!(out.train[train.len()..].iter().all(|&i| i >= data.n));
    }

    struct ClipOp;
    impl CustomOp for ClipOp {
        fn name(&self) -> &str {
            "clip3"
        }
        fn space(&self) -> ConfigSpace {
            ConfigSpace::new().float("limit", 1.0, 5.0, 3.0)
        }
        fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
               _rng: &mut Rng) -> ops::Fitted {
            let (mean, std) = ds.col_stats(train);
            let limit = cfg.f64_or("limit", 3.0);
            // winsorise via affine trick: here just standardise with a
            // widened scale as a stand-in custom transform
            let scale = std.iter()
                .map(|s| 1.0 / (s.max(1e-9) * limit)).collect();
            ops::Fitted::Affine { shift: mean, scale }
        }
    }

    #[test]
    fn custom_stage_is_searchable_and_runs() {
        let (data, train) = ds();
        let mut pipe = FePipeline::standard(false, false);
        pipe.add_custom_stage("postprocess", vec![Arc::new(ClipOp)]);
        let cs = pipe.space();
        assert!(cs.param("postprocess").is_some());
        assert!(cs.param("postprocess.clip3:limit").is_some());
        let cfg = cs.default_config()
            .merged(&Config::new().with("postprocess",
                Value::C("clip3".into()))
                .with("postprocess.clip3:limit", Value::F(2.0)));
        let out = pipe.fit_apply(&data, &cfg, &train,
                                 &FeExec::local(3));
        assert_eq!(out.data.d, data.d);
    }

    #[test]
    #[should_panic(expected = "no stage named")]
    fn add_operator_rejects_unknown_stage() {
        let mut pipe = FePipeline::standard(false, false);
        pipe.add_operator("nonexistent", "x");
    }
}
