//! Embedding-selection stage (§6.3 / Fig 5).
//!
//! Substitution for the paper's TensorFlow-Hub pre-trained models (see
//! DESIGN.md): two fixed "pre-trained" embedding extractors for the
//! texture-signal datasets. Like TF-Hub embeddings they are *frozen*
//! (no fitting on the task's training data) and they expose structure
//! that raw "pixels" hide from tabular models: spectral band energies
//! via a Goertzel-style DFT probe, plus coarse signal statistics.

use crate::data::dataset::Dataset;
use crate::space::ConfigSpace;

pub fn embedding_names() -> Vec<&'static str> {
    vec!["raw", "spectral_small", "spectral_large"]
}

pub fn embedding_space(_name: &str) -> ConfigSpace {
    ConfigSpace::new() // frozen extractors: no hyper-parameters
}

/// Energy of frequency band `f` (cycles over the row) via a direct DFT
/// probe — the analogue of one "pre-trained filter".
fn band_energy(row: &[f32], f: f64) -> f32 {
    let n = row.len() as f64;
    let (mut re, mut im) = (0.0f64, 0.0f64);
    for (t, &v) in row.iter().enumerate() {
        let ang = std::f64::consts::TAU * f * t as f64 / n;
        re += v as f64 * ang.cos();
        im += v as f64 * ang.sin();
    }
    (((re * re + im * im).sqrt()) / n) as f32
}

fn stats_features(row: &[f32]) -> Vec<f32> {
    let n = row.len().max(1) as f32;
    let mean: f32 = row.iter().sum::<f32>() / n;
    let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>()
        / n;
    // zero-crossing rate of the centred signal: a cheap frequency cue
    let mut zc = 0.0f32;
    for w in row.windows(2) {
        if (w[0] - mean) * (w[1] - mean) < 0.0 {
            zc += 1.0;
        }
    }
    vec![mean, var.sqrt(), zc / n]
}

/// Apply a frozen embedding to every row.
pub fn apply_embedding(name: &str, ds: &Dataset) -> Dataset {
    let bands: Vec<f64> = match name {
        "raw" => return ds.clone(),
        "spectral_small" => (1..=8).map(|b| b as f64).collect(),
        "spectral_large" => (1..=16).map(|b| b as f64).collect(),
        other => panic!("unknown embedding {other}"),
    };
    let with_stats = name == "spectral_large";
    let d_out = bands.len() + if with_stats { 3 } else { 0 };
    let mut out = Dataset::new(&ds.name, ds.task, d_out);
    let mut row = Vec::with_capacity(ds.d);
    for i in 0..ds.n {
        ds.gather_row(i, &mut row);
        let mut feats: Vec<f32> =
            bands.iter().map(|&f| band_energy(&row, f)).collect();
        if with_stats {
            feats.extend(stats_features(&row));
        }
        out.push_row(&feats, ds.y[i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::data::registry;
    use crate::data::synthetic::generate;

    #[test]
    fn raw_is_identity() {
        let mut p = registry::dogs_vs_cats();
        p.n = 40;
        let ds = generate(&p);
        let out = apply_embedding("raw", &ds);
        assert_eq!(out.to_row_major(), ds.to_row_major());
    }

    #[test]
    fn spectral_embedding_separates_texture_classes() {
        let mut p = registry::dogs_vs_cats();
        p.n = 200;
        let ds = generate(&p);
        let emb = apply_embedding("spectral_small", &ds);
        assert_eq!(emb.d, 8);
        // the dominant band index should correlate with the class: a
        // 1-NN-style centroid test must beat 85% where raw pixels are
        // near chance for a linear centroid rule.
        let acc = centroid_accuracy(&emb);
        assert!(acc > 0.85, "embedding centroid acc = {acc}");
        let raw_acc = centroid_accuracy(&ds);
        assert!(raw_acc < acc, "raw {raw_acc} >= emb {acc}");
    }

    fn centroid_accuracy(ds: &Dataset) -> f64 {
        let k = match ds.task {
            Task::Classification { n_classes } => n_classes,
            _ => unreachable!(),
        };
        let mut centroids = vec![vec![0.0f64; ds.d]; k];
        let mut counts = vec![0usize; k];
        let half = ds.n / 2;
        for i in 0..half {
            let c = ds.label(i);
            counts[c] += 1;
            for j in 0..ds.d {
                centroids[c][j] += ds.at(i, j) as f64;
            }
        }
        for (c, cent) in centroids.iter_mut().enumerate() {
            for v in cent.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut hits = 0;
        for i in half..ds.n {
            let row = ds.row_vec(i);
            let pred = (0..k)
                .min_by(|&a, &b| {
                    let da: f64 = row.iter().enumerate()
                        .map(|(j, &v)| (v as f64 - centroids[a][j]).powi(2))
                        .sum();
                    let db: f64 = row.iter().enumerate()
                        .map(|(j, &v)| (v as f64 - centroids[b][j]).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == ds.label(i) {
                hits += 1;
            }
        }
        hits as f64 / (ds.n - half) as f64
    }

    #[test]
    fn large_embedding_appends_stats() {
        let mut p = registry::dogs_vs_cats();
        p.n = 20;
        let ds = generate(&p);
        let out = apply_embedding("spectral_large", &ds);
        assert_eq!(out.d, 19);
        assert!((0..out.d).all(|j| out.col(j).iter()
            .all(|v| v.is_finite())));
    }
}
