//! Feature-engineering operators (the paper's Table 13 analogue).
//!
//! Every operator follows the leak-free protocol: `fit` sees only the
//! training rows, the returned [`Fitted`] op transforms *all* rows.
//! Operators are grouped into the pipeline stages of Fig 2: scalers,
//! balancers (see `fe::balance`), and feature transformers.

use crate::data::dataset::Dataset;
use crate::space::{Config, ConfigSpace};
use crate::util::kernels;
use crate::util::linalg::{top_eigs, Mat};
use crate::util::rng::Rng;

/// Maximum output width any transformer may produce (the evaluator
/// further projects to the PJRT canonical D for compiled algorithms).
pub const MAX_WIDTH: usize = 64;

/// A fitted, immutable transform applied row-wise to a dataset.
#[derive(Clone, Debug)]
pub enum Fitted {
    Identity,
    /// x' = (x - shift) * scale, per column.
    Affine { shift: Vec<f64>, scale: Vec<f64> },
    /// Row-wise L2 normalisation.
    RowNorm,
    /// Rank-normalise through per-column training quantiles.
    Quantile { grids: Vec<Vec<f64>>, normal_out: bool },
    /// Keep the listed column indices.
    Select(Vec<usize>),
    /// x' = (x - mean) @ proj  (PCA/SVD/ICA/LDA projections).
    Project { mean: Vec<f64>, proj: Mat },
    /// Append products of column pairs.
    CrossPairs(Vec<(usize, usize)>),
    /// Random Fourier features: cos(x @ w + b) * sqrt(2/m).
    Rff { w: Mat, b: Vec<f64> },
    /// RBF similarity to landmark rows.
    Nystroem { landmarks: Mat, gamma: f64 },
    /// Random-threshold trees: each tree maps a row to its leaf index.
    RandTrees { trees: Vec<Vec<(usize, f64)>> },
    /// Cluster features and output cluster means.
    Agglomerate { clusters: Vec<Vec<usize>> },
    /// Composition (e.g. whiten then rotate, RFF then project).
    Chain(Vec<Fitted>),
}

impl Fitted {
    pub fn out_dim(&self, d_in: usize) -> usize {
        match self {
            Fitted::Identity | Fitted::Affine { .. } | Fitted::RowNorm
            | Fitted::Quantile { .. } => d_in,
            Fitted::Select(idx) => idx.len(),
            Fitted::Project { proj, .. } => proj.cols,
            Fitted::CrossPairs(pairs) => d_in + pairs.len(),
            Fitted::Rff { w, .. } => w.cols,
            Fitted::Nystroem { landmarks, .. } => landmarks.rows,
            Fitted::RandTrees { trees } => trees.len(),
            Fitted::Agglomerate { clusters } => clusters.len(),
            Fitted::Chain(ops) => {
                let mut d = d_in;
                for op in ops {
                    d = op.out_dim(d);
                }
                d
            }
        }
    }

    pub fn apply_row(&self, row: &[f32]) -> Vec<f32> {
        match self {
            Fitted::Identity => row.to_vec(),
            Fitted::Affine { shift, scale } => row
                .iter()
                .enumerate()
                .map(|(j, &v)| ((v as f64 - shift[j]) * scale[j]) as f32)
                .collect(),
            Fitted::RowNorm => {
                let x: Vec<f64> =
                    row.iter().map(|&v| v as f64).collect();
                let n = kernels::norm2(&x).max(1e-12);
                row.iter().map(|&v| (v as f64 / n) as f32).collect()
            }
            Fitted::Quantile { grids, normal_out } => row
                .iter()
                .enumerate()
                .map(|(j, &v)| {
                    let g = &grids[j];
                    let rank = match g.binary_search_by(|x| {
                        x.partial_cmp(&(v as f64))
                            .unwrap_or(std::cmp::Ordering::Less)
                    }) {
                        Ok(i) => i,
                        Err(i) => i,
                    };
                    let q = rank as f64 / g.len().max(1) as f64;
                    let q = q.clamp(0.001, 0.999);
                    if *normal_out {
                        inv_norm_cdf(q) as f32
                    } else {
                        q as f32
                    }
                })
                .collect(),
            Fitted::Select(idx) => idx.iter().map(|&j| row[j]).collect(),
            Fitted::Project { mean, proj } => {
                let centered: Vec<f64> = row
                    .iter()
                    .enumerate()
                    .map(|(j, &v)| v as f64 - mean[j])
                    .collect();
                // DETLINT: allow(kernel-scalar): per-row reference
                // path; proj columns are strided, and dataset-sized
                // traffic takes the columnar kernel arm in apply_with
                // (which must stay bit-identical to this exact
                // j-ascending accumulation).
                (0..proj.cols)
                    .map(|c| {
                        let mut s = 0.0;
                        for (j, &x) in centered.iter().enumerate() {
                            s += x * proj[(j, c)];
                        }
                        s as f32
                    })
                    .collect()
            }
            Fitted::CrossPairs(pairs) => {
                let mut out = row.to_vec();
                for &(a, b) in pairs {
                    out.push(row[a] * row[b]);
                }
                out
            }
            Fitted::Rff { w, b } => {
                let m = w.cols;
                let norm = (2.0 / m as f64).sqrt();
                // DETLINT: allow(kernel-scalar): w columns are
                // strided (fit-time layout is d×m); the row-wise
                // fallback is already gather-blocked, and m·d per
                // row is small at MAX_WIDTH. The phase argument must
                // keep this exact j-ascending accumulation.
                (0..m)
                    .map(|c| {
                        let mut s = b[c];
                        for (j, &x) in row.iter().enumerate() {
                            s += x as f64 * w[(j, c)];
                        }
                        (norm * s.cos()) as f32
                    })
                    .collect()
            }
            Fitted::Nystroem { landmarks, gamma } => {
                let x: Vec<f64> =
                    row.iter().map(|&v| v as f64).collect();
                (0..landmarks.rows)
                    .map(|l| {
                        let d2 = kernels::sqdist(&x, landmarks.row(l));
                        (-gamma * d2).exp() as f32
                    })
                    .collect()
            }
            Fitted::RandTrees { trees } => trees
                .iter()
                .map(|splits| {
                    let mut leaf = 0usize;
                    for (depth, &(feat, thresh)) in splits.iter().enumerate() {
                        let go_right =
                            row.get(feat).map(|&v| v as f64 > thresh)
                                .unwrap_or(false);
                        if go_right {
                            leaf |= 1 << depth;
                        }
                    }
                    // scale to [0,1] for numeric stability downstream
                    leaf as f32 / (1u32 << splits.len()) as f32
                })
                .collect(),
            // DETLINT: allow(kernel-scalar): cluster member lists are
            // tiny (≤ d ≤ MAX_WIDTH) and the f32 accumulation is part
            // of the op's fitted semantics — widening through a lane
            // kernel would change every downstream trajectory for no
            // measurable win.
            Fitted::Agglomerate { clusters } => clusters
                .iter()
                .map(|members| {
                    let s: f32 = members.iter().map(|&j| row[j]).sum();
                    s / members.len().max(1) as f32
                })
                .collect(),
            Fitted::Chain(ops) => {
                let mut cur = row.to_vec();
                for op in ops {
                    cur = op.apply_row(&cur);
                }
                cur
            }
        }
    }

    /// Transform a whole dataset (labels `Arc`-shared through).
    ///
    /// Columnar zero-copy contract: output columns that are
    /// bit-for-bit the input column are *pointer-shared* (`Arc`
    /// clone), never copied — `Identity` shares every column,
    /// `Select` shares the kept ones, `Affine` shares columns whose
    /// `(shift, scale)` is a no-op, and `CrossPairs` shares the
    /// original `d` columns under the appended products. Every
    /// computed cell goes through the exact per-row / per-column math
    /// the row-major layout used, so values are bit-identical.
    pub fn apply(&self, ds: &Dataset) -> Dataset {
        self.apply_with(ds, None)
    }

    /// [`Self::apply`], row-sharded across the executor's worker pool:
    /// contiguous row ranges are transformed in parallel and spliced
    /// back in order (per column). Every row's output is computed by
    /// the identical per-row math, so the result is bit-identical to
    /// the serial [`Self::apply`] at every worker count and chunking
    /// — sharding is a pure wall-clock knob. Falls back to the serial
    /// loop on a serial executor, below [`SHARD_MIN_ROWS`] rows, or
    /// when called from a pool worker (the evaluation level already
    /// owns the pool; see `runtime::executor::Executor::map_ranges`).
    pub fn apply_sharded(&self, ds: &Dataset,
                         exec: &crate::runtime::executor::Executor)
        -> Dataset {
        self.apply_with(ds, Some(exec))
    }

    fn apply_with(&self, ds: &Dataset,
                  exec: Option<&crate::runtime::executor::Executor>)
        -> Dataset {
        use std::sync::Arc;
        let cols: Vec<Arc<Vec<f32>>> = match self {
            // ---- column-sharing fast paths (zero-copy) -------------
            Fitted::Identity => {
                (0..ds.d).map(|j| Arc::clone(ds.col_arc(j))).collect()
            }
            Fitted::Select(idx) => {
                idx.iter().map(|&j| Arc::clone(ds.col_arc(j))).collect()
            }
            Fitted::Affine { shift, scale } => (0..ds.d)
                .map(|j| {
                    if shift[j] == 0.0 && scale[j] == 1.0 {
                        // no-op column: share, don't copy
                        Arc::clone(ds.col_arc(j))
                    } else {
                        Arc::new(kernels::affine_apply_f32(
                            ds.col(j), shift[j], scale[j]))
                    }
                })
                .collect(),
            Fitted::Quantile { grids, normal_out } => (0..ds.d)
                .map(|j| {
                    Arc::new(kernels::quantile_apply_f32(
                        ds.col(j),
                        &grids[j],
                        |q| if *normal_out {
                            inv_norm_cdf(q) as f32
                        } else {
                            q as f32
                        },
                    ))
                })
                .collect(),
            Fitted::CrossPairs(pairs) => {
                let mut cols: Vec<Arc<Vec<f32>>> = (0..ds.d)
                    .map(|j| Arc::clone(ds.col_arc(j)))
                    .collect();
                for &(a, b) in pairs {
                    cols.push(Arc::new(kernels::mul_f32(ds.col(a),
                                                        ds.col(b))));
                }
                cols
            }
            // ---- columnar kernel arm: centered projection ----------
            // Per output column c the accumulator runs j-ascending
            // over input columns — the identical operation sequence
            // `apply_row` performs per row, so every cell is
            // bit-identical to the historical row-wise math at any
            // sharding (each row's value is independent of every
            // other row's).
            Fitted::Project { mean, proj } => {
                let run = |lo: usize, hi: usize| -> Vec<Vec<f32>> {
                    (0..proj.cols)
                        .map(|c| {
                            let mut acc = vec![0.0f64; hi - lo];
                            for j in 0..ds.d {
                                kernels::axpy_centered_f32(
                                    &mut acc,
                                    &ds.col(j)[lo..hi],
                                    mean[j],
                                    proj[(j, c)],
                                );
                            }
                            acc.iter().map(|&s| s as f32).collect()
                        })
                        .collect()
                };
                let parts = match exec {
                    Some(ex) => {
                        ex.map_ranges(ds.n, SHARD_MIN_ROWS, run)
                    }
                    None => vec![run(0, ds.n)],
                };
                splice_segments(proj.cols, ds.n, parts)
            }
            // ---- stage-wise composition: each stage takes its own
            // fast path (columnar arms compose, untouched columns
            // stay Arc-shared through the whole chain) ---------------
            Fitted::Chain(ops) => {
                let mut cur = ds.clone();
                for op in ops {
                    cur = op.apply_with(&cur, exec);
                }
                return cur;
            }
            // ---- row-wise ops: blocked gather / apply_row / scatter
            // (the gather streams each source column once per
            // G_BLOCK-row block instead of striding across all
            // columns per row; pure data movement, bit-exact) --------
            _ => {
                let d_out = self.out_dim(ds.d);
                let col_refs: Vec<&[f32]> =
                    (0..ds.d).map(|j| ds.col(j)).collect();
                let run = |lo: usize, hi: usize| -> Vec<Vec<f32>> {
                    let mut seg: Vec<Vec<f32>> = (0..d_out)
                        .map(|_| Vec::with_capacity(hi - lo))
                        .collect();
                    let mut block = Vec::new();
                    for blo in (lo..hi).step_by(kernels::G_BLOCK) {
                        let bhi = (blo + kernels::G_BLOCK).min(hi);
                        kernels::gather_range_rowmajor(
                            &col_refs, blo, bhi, &mut block);
                        for r in 0..bhi - blo {
                            let row = self.apply_row(
                                &block[r * ds.d..(r + 1) * ds.d]);
                            debug_assert_eq!(row.len(), d_out);
                            kernels::scatter_row_f32(&row, &mut seg);
                        }
                    }
                    seg
                };
                let parts = match exec {
                    Some(ex) => ex.map_ranges(ds.n, SHARD_MIN_ROWS, run),
                    None => vec![run(0, ds.n)],
                };
                return Dataset::from_columns(
                    &ds.name, ds.task,
                    splice_segments(d_out, ds.n, parts),
                    Arc::clone(&ds.y));
            }
        };
        Dataset::from_columns(&ds.name, ds.task, cols,
                              Arc::clone(&ds.y))
    }
}

/// Splice per-range, per-column output segments (range order) back
/// into whole columns.
fn splice_segments(d_out: usize, n: usize,
                   parts: Vec<Vec<Vec<f32>>>)
    -> Vec<std::sync::Arc<Vec<f32>>> {
    let mut cols: Vec<Vec<f32>> =
        (0..d_out).map(|_| Vec::with_capacity(n)).collect();
    for part in &parts {
        for (c, seg) in cols.iter_mut().zip(part) {
            c.extend_from_slice(seg);
        }
    }
    cols.into_iter().map(std::sync::Arc::new).collect()
}

/// Minimum rows per shard of a row-parallel [`Fitted::apply_sharded`]:
/// below this the per-batch bookkeeping outweighs the row work.
pub const SHARD_MIN_ROWS: usize = 512;

/// Acklam-style rational approximation of the standard normal inverse
/// CDF (enough precision for quantile-normal output).
fn inv_norm_cdf(p: f64) -> f64 {
    debug_assert!((0.0..1.0).contains(&p));
    let a = [-3.969683028665376e+01, 2.209460984245205e+02,
             -2.759285104469687e+02, 1.383577518672690e+02,
             -3.066479806614716e+01, 2.506628277459239e+00];
    let b = [-5.447609879822406e+01, 1.615858368580409e+02,
             -1.556989798598866e+02, 6.680131188771972e+01,
             -1.328068155288572e+01];
    let c = [-7.784894002430293e-03, -3.223964580411365e-01,
             -2.400758277161838e+00, -2.549732539343734e+00,
             4.374664141464968e+00, 2.938163982698783e+00];
    let d = [7.784695709041462e-03, 3.224671290700398e-01,
             2.445134137142996e+00, 3.754408661907416e+00];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5])
            / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5])
            * q
            / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r
                + 1.0)
    } else {
        -inv_norm_cdf(1.0 - p)
    }
}

// ====================================================================
// Mergeable fit kernels (row-sharded fits with deterministic merges)
// ====================================================================
//
// `Executor::map_ranges` chunk boundaries depend on the worker count,
// so a fit that accumulates floats per chunk would change bits with
// the pool size. Each kernel here is mergeable with a merge whose
// result is *independent of the chunking*:
//
//   * min/max and integer counts — associative + commutative, exact;
//   * sorted runs — merged output is the totally-ordered multiset,
//     the same sequence of bit patterns whatever the run boundaries
//     (comparisons use `total_cmp`, a total order);
//   * mean/var — float addition is NOT associative, so partial sums
//     are computed over fixed [`FIT_CHUNK`]-row blocks and merged in
//     block order. Serial and sharded paths both use the identical
//     block structure, so the result is bit-identical at every worker
//     count (and the serial path defines the reference bits).

/// Canonical block size for float partial sums in mergeable fits:
/// fixed (worker-independent) so block boundaries never move with the
/// pool size.
pub const FIT_CHUNK: usize = 4096;

/// Minimum rows before a fit bothers sharding (mirrors
/// [`SHARD_MIN_ROWS`] on the apply side).
pub const FIT_SHARD_MIN_ROWS: usize = 2 * FIT_CHUNK;

type Exec = crate::runtime::executor::Executor;

/// Run `block` over canonical [`FIT_CHUNK`] blocks of `0..n` (serial
/// or sharded at block granularity) and return the per-block results
/// in block order. Because blocks are fixed, the returned sequence is
/// identical however the blocks were distributed over workers.
fn map_fit_blocks<T, F>(n: usize, exec: Option<&Exec>, block: F)
    -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    let n_blocks = n.div_ceil(FIT_CHUNK).max(1);
    let run = |blo: usize, bhi: usize| -> Vec<T> {
        (blo..bhi)
            .map(|b| block(b * FIT_CHUNK, ((b + 1) * FIT_CHUNK).min(n)))
            .collect()
    };
    let parts = match exec {
        Some(ex) if n >= FIT_SHARD_MIN_ROWS => {
            ex.map_ranges(n_blocks, 1, run)
        }
        _ => vec![run(0, n_blocks)],
    };
    parts.into_iter().flatten().collect()
}

/// Column mean/std over `rows`, mergeable: fixed-block fused
/// `(Σx, Σx²)` partials ([`kernels::moments_indexed_f32`], one pass
/// over the data instead of the historical two) merged in block
/// order (see module notes above). Variance comes out as
/// `(Σx²/n − mean²).max(0)` — the clamp guards the tiny negative
/// residue cancellation can leave on near-constant columns. This is
/// the fit kernel for the `standard` scaler; it intentionally does
/// NOT match `Dataset::col_stats` bit-for-bit (that one is a
/// straight sequential sum kept for meta-features and non-sharded
/// ops).
pub fn col_moments(ds: &Dataset, rows: &[usize], exec: Option<&Exec>)
    -> (Vec<f64>, Vec<f64>) {
    let d = ds.d;
    let n = rows.len().max(1) as f64;
    let parts = map_fit_blocks(rows.len(), exec, |lo, hi| {
        (0..d)
            .map(|j| {
                kernels::moments_indexed_f32(ds.col(j), &rows[lo..hi])
            })
            .collect::<Vec<(f64, f64)>>()
    });
    let mut sum = vec![0.0f64; d];
    let mut sumsq = vec![0.0f64; d];
    for part in &parts {
        for (j, &(s, q)) in part.iter().enumerate() {
            sum[j] += s;
            sumsq[j] += q;
        }
    }
    let mean: Vec<f64> = sum.iter().map(|s| s / n).collect();
    let std = sumsq
        .iter()
        .zip(&mean)
        .map(|(q, m)| (q / n - m * m).max(0.0).sqrt())
        .collect();
    (mean, std)
}

/// Column min/max over `rows`, mergeable exactly (min/max are
/// associative and commutative — any chunking gives the same bits;
/// the lane-striped [`kernels::minmax_indexed_f32`] absorbs NaNs the
/// same way a sequential `f64::min` fold does).
pub fn col_minmax(ds: &Dataset, rows: &[usize], exec: Option<&Exec>)
    -> (Vec<f64>, Vec<f64>) {
    let d = ds.d;
    let parts = map_fit_blocks(rows.len(), exec, |lo, hi| {
        let mut lo_v = vec![f64::INFINITY; d];
        let mut hi_v = vec![f64::NEG_INFINITY; d];
        for (j, (l, h)) in lo_v.iter_mut().zip(&mut hi_v).enumerate() {
            let (bl, bh) =
                kernels::minmax_indexed_f32(ds.col(j), &rows[lo..hi]);
            *l = bl;
            *h = bh;
        }
        (lo_v, hi_v)
    });
    let mut lo = vec![f64::INFINITY; d];
    let mut hi = vec![f64::NEG_INFINITY; d];
    for (pl, ph) in &parts {
        for (j, (l, h)) in lo.iter_mut().zip(&mut hi).enumerate() {
            *l = l.min(pl[j]);
            *h = h.max(ph[j]);
        }
    }
    (lo, hi)
}

/// `total_cmp`-sorted values of column `j` over `rows`, mergeable:
/// shards sort runs, then a k-way merge in run order reassembles the
/// totally-ordered multiset — the identical bit sequence a full sort
/// produces, whatever the run boundaries.
pub fn col_sorted(ds: &Dataset, rows: &[usize], j: usize,
                  exec: Option<&Exec>) -> Vec<f64> {
    let c = ds.col(j);
    let mut runs = map_fit_blocks(rows.len(), exec, |lo, hi| {
        let mut xs: Vec<f64> =
            rows[lo..hi].iter().map(|&i| c[i] as f64).collect();
        xs.sort_unstable_by(|a, b| a.total_cmp(b));
        xs
    });
    if runs.len() == 1 {
        return runs.pop().unwrap();
    }
    // k-way merge, lowest run index wins ties: deterministic, and the
    // output sequence only depends on the multiset being merged.
    let mut out = Vec::with_capacity(rows.len());
    let mut heads = vec![0usize; runs.len()];
    loop {
        let mut best: Option<usize> = None;
        for (r, run) in runs.iter().enumerate() {
            if heads[r] < run.len() {
                match best {
                    None => best = Some(r),
                    Some(b) => {
                        if runs[b][heads[b]]
                            .total_cmp(&run[heads[r]])
                            == std::cmp::Ordering::Greater
                        {
                            best = Some(r);
                        }
                    }
                }
            }
        }
        match best {
            Some(r) => {
                out.push(runs[r][heads[r]]);
                heads[r] += 1;
            }
            None => break,
        }
    }
    out
}

/// Per-class row partition of `rows` (classification "category
/// counts" fit), mergeable exactly: per-shard partitions concatenated
/// in range order equal the serial scan order.
pub fn class_partition(ds: &Dataset, rows: &[usize], k: usize,
                       exec: Option<&Exec>) -> Vec<Vec<usize>> {
    if k == 0 {
        return Vec::new();
    }
    let parts = map_fit_blocks(rows.len(), exec, |lo, hi| {
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); k];
        for &i in &rows[lo..hi] {
            let c = ds.label(i);
            debug_assert!(c < k, "label {c} out of range for {k} classes");
            by_class[c.min(k.saturating_sub(1))].push(i);
        }
        by_class
    });
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); k];
    for part in parts {
        for (acc, mut p) in by_class.iter_mut().zip(part) {
            acc.append(&mut p);
        }
    }
    by_class
}

// ====================================================================
// Fitting helpers
// ====================================================================

fn train_stats(ds: &Dataset, train: &[usize]) -> (Vec<f64>, Vec<f64>) {
    ds.col_stats(train)
}

fn col_values(ds: &Dataset, train: &[usize], j: usize) -> Vec<f64> {
    let c = ds.col(j);
    train.iter().map(|&i| c[i] as f64).collect()
}

/// |pearson correlation| of feature j with the label/target: center
/// both series, then three lane-striped dots.
fn label_corr(ds: &Dataset, train: &[usize], j: usize) -> f64 {
    let mut xs = col_values(ds, train, j);
    let mut ys: Vec<f64> =
        train.iter().map(|&i| ds.y[i] as f64).collect();
    let (mx, my) = (crate::util::stats::mean(&xs),
                    crate::util::stats::mean(&ys));
    for x in &mut xs {
        *x -= mx;
    }
    for y in &mut ys {
        *y -= my;
    }
    let num = kernels::dot(&xs, &ys);
    let vx = kernels::dot(&xs, &xs);
    let vy = kernels::dot(&ys, &ys);
    if vx <= 0.0 || vy <= 0.0 {
        0.0
    } else {
        (num / (vx.sqrt() * vy.sqrt())).abs()
    }
}

/// Feature-major (d × |train|) widened copy of the training rows:
/// each feature is one contiguous row, built by streaming each
/// source column once — the layout [`Mat::covariance_t`] /
/// [`Mat::second_moment_t`] lane-dot directly, with no transpose.
fn train_mat_t(ds: &Dataset, train: &[usize]) -> Mat {
    let mut m = Mat::zeros(ds.d, train.len());
    for j in 0..ds.d {
        let c = ds.col(j);
        for (x, &i) in m.row_mut(j).iter_mut().zip(train) {
            *x = c[i] as f64;
        }
    }
    m
}

fn train_cov(ds: &Dataset, train: &[usize]) -> Mat {
    train_mat_t(ds, train).covariance_t()
}

fn top_k_by_score(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a])
        .unwrap_or(std::cmp::Ordering::Equal));
    idx.truncate(k.max(1));
    idx.sort_unstable();
    idx
}

// ====================================================================
// Scalers (Fig 2 stage 2)
// ====================================================================

pub fn scaler_names() -> Vec<&'static str> {
    vec!["none", "minmax", "standard", "robust", "quantile", "normalizer"]
}

pub fn scaler_space(name: &str) -> ConfigSpace {
    match name {
        "quantile" => ConfigSpace::new()
            .int("n_quantiles", 10, 200, 100)
            .cat("output", &["uniform", "normal"], "uniform"),
        "robust" => ConfigSpace::new()
            .float("q_low", 0.05, 0.35, 0.25)
            .float("q_high", 0.65, 0.95, 0.75),
        _ => ConfigSpace::new(),
    }
}

pub fn fit_scaler(name: &str, ds: &Dataset, train: &[usize], cfg: &Config)
    -> Fitted {
    fit_scaler_with(name, ds, train, cfg, None)
}

/// [`fit_scaler`] with an optional executor: the mergeable fits
/// (mean/var, min/max, quantile grids) row-shard over
/// `Executor::map_ranges` with deterministic ordered merges, so the
/// fitted operator is bit-identical at every worker count (see the
/// mergeable-fit kernel notes above).
pub fn fit_scaler_with(name: &str, ds: &Dataset, train: &[usize],
                       cfg: &Config, exec: Option<&Exec>) -> Fitted {
    match name {
        "none" => Fitted::Identity,
        "normalizer" => Fitted::RowNorm,
        "minmax" => {
            let (lo, hi) = col_minmax(ds, train, exec);
            let scale: Vec<f64> = lo
                .iter()
                .zip(&hi)
                .map(|(l, h)| if h > l { 1.0 / (h - l) } else { 1.0 })
                .collect();
            Fitted::Affine { shift: lo, scale }
        }
        "standard" => {
            let (mean, std) = col_moments(ds, train, exec);
            let scale = std.iter().map(|s| 1.0 / s.max(1e-9)).collect();
            Fitted::Affine { shift: mean, scale }
        }
        "robust" => {
            let ql = cfg.f64_or("q_low", 0.25);
            let qh = cfg.f64_or("q_high", 0.75);
            let mut shift = Vec::with_capacity(ds.d);
            let mut scale = Vec::with_capacity(ds.d);
            for j in 0..ds.d {
                let xs = col_values(ds, train, j);
                let med = crate::util::stats::median(&xs);
                let iqr = crate::util::stats::quantile(&xs, qh)
                    - crate::util::stats::quantile(&xs, ql);
                shift.push(med);
                scale.push(1.0 / iqr.abs().max(1e-9));
            }
            Fitted::Affine { shift, scale }
        }
        "quantile" => {
            let nq = cfg.usize_or("n_quantiles", 100).clamp(4, 512);
            let normal_out = cfg.str_or("output", "uniform") == "normal";
            let grids = (0..ds.d)
                .map(|j| {
                    // sorted-run merge: equals a full total_cmp sort
                    let xs = col_sorted(ds, train, j, exec);
                    // subsample to nq grid points
                    let step = (xs.len().max(1) as f64 / nq as f64).max(1.0);
                    let mut g: Vec<f64> = (0..nq)
                        .map(|q| xs[((q as f64 * step) as usize)
                            .min(xs.len().saturating_sub(1))])
                        .collect();
                    g.dedup_by(|a, b| a == b);
                    g
                })
                .collect();
            Fitted::Quantile { grids, normal_out }
        }
        other => panic!("unknown scaler {other}"),
    }
}

// ====================================================================
// Feature transformers (Fig 2 stage 4; Table 13)
// ====================================================================

pub fn transformer_names() -> Vec<&'static str> {
    vec![
        "none", "pca", "svd", "fast_ica", "kernel_pca", "kitchen_sinks",
        "nystroem", "polynomial", "cross_features", "feature_agglomeration",
        "random_trees_embed", "select_percentile",
        "select_generic_univariate", "extra_trees_preproc",
        "linear_svm_preproc", "lda_decomposer",
    ]
}

pub fn transformer_space(name: &str) -> ConfigSpace {
    match name {
        "pca" => ConfigSpace::new()
            .float("keep_frac", 0.3, 0.999, 0.9)
            .cat("whiten", &["false", "true"], "false"),
        "svd" => ConfigSpace::new().int("n_components", 2, 24, 8),
        "fast_ica" => ConfigSpace::new().int("n_components", 2, 24, 8),
        "kernel_pca" => ConfigSpace::new()
            .int("n_components", 2, 24, 10)
            .log_float("gamma", 1e-3, 8.0, 0.5),
        "kitchen_sinks" => ConfigSpace::new()
            .int("n_components", 8, 48, 24)
            .log_float("gamma", 1e-3, 8.0, 1.0),
        "nystroem" => ConfigSpace::new()
            .int("n_components", 8, 48, 24)
            .log_float("gamma", 1e-3, 8.0, 0.5),
        "polynomial" => ConfigSpace::new()
            .cat("interaction_only", &["false", "true"], "false")
            .int("top_k", 3, 8, 6),
        "cross_features" => ConfigSpace::new().int("n_pairs", 2, 24, 8),
        "feature_agglomeration" => ConfigSpace::new()
            .int("n_clusters", 2, 24, 8)
            .cat("linkage", &["mean"], "mean"),
        "random_trees_embed" => ConfigSpace::new()
            .int("n_trees", 4, 24, 10)
            .int("depth", 2, 6, 4),
        "select_percentile" => ConfigSpace::new()
            .float("percentile", 0.1, 0.99, 0.5),
        "select_generic_univariate" => ConfigSpace::new()
            .float("alpha", 0.1, 0.99, 0.5)
            .cat("score_func", &["corr", "variance"], "corr")
            .cat("mode", &["percentile", "k_best"], "percentile"),
        "extra_trees_preproc" => ConfigSpace::new()
            .float("keep_frac", 0.2, 0.95, 0.6)
            .int("n_stumps", 8, 64, 24),
        "linear_svm_preproc" => ConfigSpace::new()
            .float("keep_frac", 0.2, 0.95, 0.6)
            .log_float("l2", 1e-5, 1.0, 1e-3),
        _ => ConfigSpace::new(),
    }
}

pub fn fit_transformer(name: &str, ds: &Dataset, train: &[usize],
                       cfg: &Config, rng: &mut Rng) -> Fitted {
    let d = ds.d;
    match name {
        "none" => Fitted::Identity,
        "pca" => {
            let keep = cfg.f64_or("keep_frac", 0.9);
            let whiten = cfg.str_or("whiten", "false") == "true";
            let tm = train_mat_t(ds, train);
            let cov = tm.covariance_t();
            let eigs = top_eigs(&cov, d.min(MAX_WIDTH), rng);
            // DETLINT: allow(kernel-scalar): spectrum-mass fold over
            // at most MAX_WIDTH eigenvalues — cold and tiny.
            let total: f64 = eigs.iter().map(|(l, _)| l.max(0.0)).sum();
            let mut cum = 0.0;
            let mut k = 0;
            for (l, _) in &eigs {
                cum += l.max(0.0);
                k += 1;
                if total > 0.0 && cum / total >= keep {
                    break;
                }
            }
            let k = k.max(1);
            let nf = train.len().max(1) as f64;
            let mean: Vec<f64> = (0..d)
                .map(|j| kernels::sum(tm.row(j)) / nf)
                .collect();
            let mut proj = Mat::zeros(d, k);
            for (c, (l, v)) in eigs.iter().take(k).enumerate() {
                let w = if whiten { 1.0 / l.abs().sqrt().max(1e-9) } else { 1.0 };
                for j in 0..d {
                    proj[(j, c)] = v[j] * w;
                }
            }
            Fitted::Project { mean, proj }
        }
        "svd" => {
            let k = cfg.usize_or("n_components", 8).clamp(1, d);
            // second-moment matrix (no centering): one lane-dot per
            // feature pair over the feature-major copy, instead of
            // the historical O(n·d²) scalar rank-1 accumulation
            let sm = train_mat_t(ds, train).second_moment_t();
            let eigs = top_eigs(&sm, k, rng);
            let mut proj = Mat::zeros(d, eigs.len());
            for (c, (_, v)) in eigs.iter().enumerate() {
                for j in 0..d {
                    proj[(j, c)] = v[j];
                }
            }
            Fitted::Project { mean: vec![0.0; d], proj }
        }
        "fast_ica" => {
            // whiten via PCA then apply a random orthogonal rotation —
            // the rotation-invariant subspace is what downstream models
            // consume; true negentropy iteration adds little here.
            let k = cfg.usize_or("n_components", 8).clamp(1, d);
            let cov = train_cov(ds, train);
            let eigs = top_eigs(&cov, k, rng);
            let mean = {
                let xs: Vec<usize> = train.to_vec();
                ds.col_stats(&xs).0
            };
            let mut white = Mat::zeros(d, eigs.len());
            for (c, (l, v)) in eigs.iter().enumerate() {
                let w = 1.0 / l.abs().sqrt().max(1e-9);
                for j in 0..d {
                    white[(j, c)] = v[j] * w;
                }
            }
            let rot = random_orthogonal(eigs.len(), rng);
            let proj = white.matmul(&rot);
            Fitted::Project { mean, proj }
        }
        "kernel_pca" => {
            let k = cfg.usize_or("n_components", 10).clamp(1, MAX_WIDTH);
            let gamma = cfg.f64_or("gamma", 0.5);
            let m = (2 * k).clamp(8, MAX_WIDTH);
            let rff = fit_rff(d, m, gamma, rng);
            // project RFF features to top-k principal components
            let rff_ds = rff.apply(ds);
            let cov = train_cov(&rff_ds, train);
            let eigs = top_eigs(&cov, k, rng);
            let mean = rff_ds.col_stats(train).0;
            let mut proj = Mat::zeros(m, eigs.len());
            for (c, (_, v)) in eigs.iter().enumerate() {
                for j in 0..m {
                    proj[(j, c)] = v[j];
                }
            }
            Fitted::Chain(vec![rff, Fitted::Project { mean, proj }])
        }
        "kitchen_sinks" => {
            let m = cfg.usize_or("n_components", 24).clamp(4, MAX_WIDTH);
            let gamma = cfg.f64_or("gamma", 1.0);
            fit_rff(d, m, gamma, rng)
        }
        "nystroem" => {
            let m = cfg.usize_or("n_components", 24)
                .clamp(2, MAX_WIDTH.min(train.len()));
            let gamma = cfg.f64_or("gamma", 0.5);
            let picks = rng.sample_indices(train.len(), m);
            let mut landmarks = Mat::zeros(m, d);
            for (r, &pi) in picks.iter().enumerate() {
                let i = train[pi];
                for j in 0..d {
                    landmarks[(r, j)] = ds.at(i, j) as f64;
                }
            }
            Fitted::Nystroem { landmarks, gamma }
        }
        "polynomial" => {
            let inter_only = cfg.str_or("interaction_only", "false") == "true";
            let top_k = cfg.usize_or("top_k", 6).clamp(2, 8).min(d);
            // restrict to the highest-variance columns so width stays
            // bounded (auto-sklearn caps width similarly)
            let (_, std) = train_stats(ds, train);
            let cols = top_k_by_score(&std, top_k);
            let mut pairs = Vec::new();
            for (ai, &a) in cols.iter().enumerate() {
                let start = if inter_only { ai + 1 } else { ai };
                for &b in &cols[start..] {
                    pairs.push((a, b));
                    if d + pairs.len() >= MAX_WIDTH {
                        break;
                    }
                }
            }
            Fitted::CrossPairs(pairs)
        }
        "cross_features" => {
            let np = cfg.usize_or("n_pairs", 8)
                .clamp(1, MAX_WIDTH.saturating_sub(d).max(1));
            let pairs = (0..np)
                .map(|_| (rng.below(d), rng.below(d)))
                .collect();
            Fitted::CrossPairs(pairs)
        }
        "feature_agglomeration" => {
            let k = cfg.usize_or("n_clusters", 8).clamp(1, d);
            let cov = train_cov(ds, train);
            // greedy union-find on |correlation|
            let mut parent: Vec<usize> = (0..d).collect();
            fn find(p: &mut Vec<usize>, i: usize) -> usize {
                if p[i] != i {
                    let r = find(p, p[i]);
                    p[i] = r;
                }
                p[i]
            }
            let mut pairs: Vec<(f64, usize, usize)> = Vec::new();
            for a in 0..d {
                for b in a + 1..d {
                    let denom = (cov[(a, a)] * cov[(b, b)]).sqrt().max(1e-12);
                    pairs.push(((cov[(a, b)] / denom).abs(), a, b));
                }
            }
            pairs.sort_by(|x, y| y.0.partial_cmp(&x.0)
                .unwrap_or(std::cmp::Ordering::Equal));
            let mut n_clusters = d;
            for (_, a, b) in pairs {
                if n_clusters <= k {
                    break;
                }
                let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
                if ra != rb {
                    parent[ra] = rb;
                    n_clusters -= 1;
                }
            }
            let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
                Default::default();
            for j in 0..d {
                let r = find(&mut parent, j);
                groups.entry(r).or_default().push(j);
            }
            Fitted::Agglomerate { clusters: groups.into_values().collect() }
        }
        "random_trees_embed" => {
            let nt = cfg.usize_or("n_trees", 10).clamp(1, MAX_WIDTH);
            let depth = cfg.usize_or("depth", 4).clamp(1, 8);
            let (mean, std) = train_stats(ds, train);
            let trees = (0..nt)
                .map(|_| {
                    (0..depth)
                        .map(|_| {
                            let f = rng.below(d);
                            let t = mean[f] + rng.normal() * std[f].max(1e-9);
                            (f, t)
                        })
                        .collect()
                })
                .collect();
            Fitted::RandTrees { trees }
        }
        "select_percentile" => {
            let pct = cfg.f64_or("percentile", 0.5).clamp(0.05, 1.0);
            let scores: Vec<f64> =
                (0..d).map(|j| label_corr(ds, train, j)).collect();
            let k = ((d as f64 * pct).ceil() as usize).clamp(1, d);
            Fitted::Select(top_k_by_score(&scores, k))
        }
        "select_generic_univariate" => {
            let alpha = cfg.f64_or("alpha", 0.5).clamp(0.05, 1.0);
            let score_fn = cfg.str_or("score_func", "corr");
            let scores: Vec<f64> = (0..d)
                .map(|j| {
                    if score_fn == "variance" {
                        crate::util::stats::variance(
                            &col_values(ds, train, j))
                    } else {
                        label_corr(ds, train, j)
                    }
                })
                .collect();
            let k = if cfg.str_or("mode", "percentile") == "k_best" {
                ((d as f64 * alpha).round() as usize).clamp(1, d)
            } else {
                ((d as f64 * alpha).ceil() as usize).clamp(1, d)
            };
            Fitted::Select(top_k_by_score(&scores, k))
        }
        "extra_trees_preproc" => {
            // stump-gain importances on a subsample
            let keep = cfg.f64_or("keep_frac", 0.6).clamp(0.1, 1.0);
            let n_stumps = cfg.usize_or("n_stumps", 24);
            let sub: Vec<usize> = (0..train.len().min(256))
                .map(|_| train[rng.below(train.len())])
                .collect();
            let mut scores = vec![0.0f64; d];
            for _ in 0..n_stumps {
                let j = rng.below(d);
                let xs = col_values(ds, &sub, j);
                let t = xs[rng.below(xs.len().max(1))];
                // gain proxy: |mean(y|x>t) - mean(y|x<=t)|
                let (mut above, mut below): (Vec<f64>, Vec<f64>) =
                    (Vec::new(), Vec::new());
                for (&i, &x) in sub.iter().zip(&xs) {
                    if x > t {
                        above.push(ds.y[i] as f64);
                    } else {
                        below.push(ds.y[i] as f64);
                    }
                }
                if !above.is_empty() && !below.is_empty() {
                    scores[j] += (crate::util::stats::mean(&above)
                        - crate::util::stats::mean(&below)).abs();
                }
            }
            let k = ((d as f64 * keep).ceil() as usize).clamp(1, d);
            Fitted::Select(top_k_by_score(&scores, k))
        }
        "linear_svm_preproc" => {
            // few perceptron epochs, select by |w|
            let keep = cfg.f64_or("keep_frac", 0.6).clamp(0.1, 1.0);
            let l2 = cfg.f64_or("l2", 1e-3);
            let (mean, std) = train_stats(ds, train);
            let mut w = vec![0.0f64; d];
            let ys: Vec<f64> =
                train.iter().map(|&i| ds.y[i] as f64).collect();
            let y_mean =
                kernels::sum(&ys) / train.len().max(1) as f64;
            let mut row = Vec::with_capacity(d);
            let mut x = vec![0.0f64; d];
            for _epoch in 0..3 {
                for &i in train {
                    ds.gather_row(i, &mut row);
                    let target = if ds.task.is_classification() {
                        if ds.y[i] as f64 > y_mean { 1.0 } else { -1.0 }
                    } else if ds.y[i] as f64 > y_mean { 1.0 } else { -1.0 };
                    for j in 0..d {
                        x[j] = (row[j] as f64 - mean[j])
                            / std[j].max(1e-9);
                    }
                    let z = kernels::dot(&w, &x);
                    if z * target < 1.0 {
                        for j in 0..d {
                            w[j] += 0.01 * (target * x[j] - l2 * w[j]);
                        }
                    }
                }
            }
            let scores: Vec<f64> = w.iter().map(|x| x.abs()).collect();
            let k = ((d as f64 * keep).ceil() as usize).clamp(1, d);
            Fitted::Select(top_k_by_score(&scores, k))
        }
        "lda_decomposer" => {
            // project onto (orthogonalised) class-mean directions
            if !ds.task.is_classification() {
                return Fitted::Identity;
            }
            let kcls = ds.task.n_classes();
            let (gmean, _) = train_stats(ds, train);
            let mut dirs: Vec<Vec<f64>> = Vec::new();
            for c in 0..kcls {
                let rows: Vec<usize> = train.iter().copied()
                    .filter(|&i| ds.label(i) == c).collect();
                if rows.is_empty() {
                    continue;
                }
                let (cmean, _) = ds.col_stats(&rows);
                let mut dir: Vec<f64> = cmean.iter().zip(&gmean)
                    .map(|(a, b)| a - b).collect();
                // Gram-Schmidt against existing directions
                // (x − proj·p ≡ x + (−proj)·p bitwise)
                for prev in &dirs {
                    let proj = crate::util::linalg::dot(&dir, prev);
                    kernels::axpy(&mut dir, -proj, prev);
                }
                let n = crate::util::linalg::norm2(&dir);
                if n > 1e-9 {
                    for x in &mut dir {
                        *x /= n;
                    }
                    dirs.push(dir);
                }
                if dirs.len() + 1 >= kcls {
                    break;
                }
            }
            if dirs.is_empty() {
                return Fitted::Identity;
            }
            let mut proj = Mat::zeros(d, dirs.len());
            for (c, v) in dirs.iter().enumerate() {
                for j in 0..d {
                    proj[(j, c)] = v[j];
                }
            }
            Fitted::Project { mean: gmean, proj }
        }
        other => panic!("unknown transformer {other}"),
    }
}

fn fit_rff(d: usize, m: usize, gamma: f64, rng: &mut Rng) -> Fitted {
    let mut w = Mat::zeros(d, m);
    let s = (2.0 * gamma).sqrt();
    for x in &mut w.data {
        *x = rng.normal() * s;
    }
    let b = (0..m)
        .map(|_| rng.uniform(0.0, std::f64::consts::TAU))
        .collect();
    Fitted::Rff { w, b }
}

fn random_orthogonal(k: usize, rng: &mut Rng) -> Mat {
    // Gram-Schmidt on a random Gaussian matrix
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut v: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        for prev in &cols {
            let p = crate::util::linalg::dot(&v, prev);
            kernels::axpy(&mut v, -p, prev);
        }
        let n = crate::util::linalg::norm2(&v).max(1e-12);
        for x in &mut v {
            *x /= n;
        }
        cols.push(v);
    }
    let mut m = Mat::zeros(k, k);
    for (c, v) in cols.iter().enumerate() {
        for j in 0..k {
            m[(j, c)] = v[j];
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Task;
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn toy_ds() -> (Dataset, Vec<usize>) {
        let p = Profile {
            name: "fe-toy".into(),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Blobs { sep: 2.0 },
            n: 200,
            d: 8,
            noise: 0.05,
            imbalance: 1.0,
            redundant: 2,
            wild_scales: true,
            seed: 11,
        };
        let ds = generate(&p);
        let train: Vec<usize> = (0..150).collect();
        (ds, train)
    }

    fn all_finite(ds: &Dataset) -> bool {
        (0..ds.d).all(|j| ds.col(j).iter().all(|v| v.is_finite()))
    }

    #[test]
    fn every_scaler_fits_and_applies() {
        let (ds, train) = toy_ds();
        for name in scaler_names() {
            let cfg = scaler_space(name).default_config();
            let f = fit_scaler(name, &ds, &train, &cfg);
            let out = f.apply(&ds);
            assert_eq!(out.n, ds.n, "{name}");
            assert_eq!(out.d, ds.d, "{name}");
            assert!(all_finite(&out), "{name}");
        }
    }

    #[test]
    fn standard_scaler_zero_mean_unit_var_on_train() {
        let (ds, train) = toy_ds();
        let f = fit_scaler("standard", &ds, &train, &Config::new());
        let out = f.apply(&ds);
        let (mean, std) = out.col_stats(&train);
        for j in 0..out.d {
            assert!(mean[j].abs() < 1e-4, "mean[{j}]={}", mean[j]);
            assert!((std[j] - 1.0).abs() < 1e-3, "std[{j}]={}", std[j]);
        }
    }

    #[test]
    fn minmax_bounds_train_to_unit_interval() {
        let (ds, train) = toy_ds();
        let f = fit_scaler("minmax", &ds, &train, &Config::new());
        let out = f.apply(&ds);
        for &i in &train {
            for v in out.row_vec(i) {
                assert!((-1e-6..=1.0 + 1e-6).contains(&(v as f64)));
            }
        }
    }

    #[test]
    fn every_transformer_fits_and_applies() {
        let (ds, train) = toy_ds();
        let mut rng = Rng::new(0);
        for name in transformer_names() {
            let cfg = transformer_space(name).default_config();
            let f = fit_transformer(name, &ds, &train, &cfg, &mut rng);
            let out = f.apply(&ds);
            assert_eq!(out.n, ds.n, "{name}");
            assert!(out.d >= 1 && out.d <= MAX_WIDTH, "{name}: d={}", out.d);
            assert_eq!(out.d, f.out_dim(ds.d), "{name}");
            assert!(all_finite(&out), "{name}");
        }
    }

    #[test]
    fn pca_projection_decorrelates() {
        let (ds, train) = toy_ds();
        let mut rng = Rng::new(1);
        let cfg = transformer_space("pca").default_config();
        let f = fit_transformer("pca", &ds, &train, &cfg, &mut rng);
        let out = f.apply(&ds);
        assert!(out.d <= ds.d);
        // first component captures the most variance
        let (_, std) = out.col_stats(&train);
        assert!(std[0] >= *std.last().unwrap() * 0.9);
    }

    #[test]
    fn select_percentile_keeps_informative_columns() {
        let (ds, train) = toy_ds();
        let mut rng = Rng::new(2);
        let cfg = Config::new().with("percentile", crate::space::Value::F(0.25));
        let f = fit_transformer("select_percentile", &ds, &train, &cfg,
                                &mut rng);
        if let Fitted::Select(idx) = &f {
            assert_eq!(idx.len(), 2);
            // informative dims for Blobs are the first d/2 clamp(2,8)=4
            assert!(idx.iter().all(|&j| j < 6), "{idx:?}");
        } else {
            panic!("expected Select");
        }
    }

    #[test]
    fn quantile_uniform_output_in_unit_interval() {
        let (ds, train) = toy_ds();
        let cfg = scaler_space("quantile").default_config();
        let f = fit_scaler("quantile", &ds, &train, &cfg);
        let out = f.apply(&ds);
        assert!((0..out.d).all(|j| out.col(j).iter()
            .all(|&v| (0.0..=1.0).contains(&(v as f64)))));
    }

    #[test]
    fn inv_norm_cdf_symmetry() {
        assert!((inv_norm_cdf(0.5)).abs() < 1e-9);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-3);
        assert!((inv_norm_cdf(0.025) + 1.959964).abs() < 1e-3);
    }

    #[test]
    fn sharded_apply_is_bitwise_identical_to_serial() {
        // a dataset large enough to clear SHARD_MIN_ROWS, with a
        // projection (float-heavy) and a selector (index-heavy) op
        let p = Profile {
            name: "fe-shard".into(),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Blobs { sep: 1.5 },
            n: 3000,
            d: 8,
            noise: 0.05,
            imbalance: 1.0,
            redundant: 2,
            wild_scales: true,
            seed: 12,
        };
        let ds = generate(&p);
        let train: Vec<usize> = (0..2400).collect();
        for op in ["pca", "select_percentile", "kitchen_sinks"] {
            let mut rng = Rng::new(4);
            let cfg = transformer_space(op).default_config();
            let f = fit_transformer(op, &ds, &train, &cfg, &mut rng);
            let serial = f.apply(&ds);
            for workers in [1usize, 3] {
                let ex = crate::runtime::executor::Executor::new(
                    workers);
                let sharded = f.apply_sharded(&ds, &ex);
                assert_eq!(sharded.n, serial.n, "{op}");
                assert_eq!(sharded.d, serial.d, "{op}");
                assert_eq!(sharded.y, serial.y, "{op}");
                for j in 0..serial.d {
                    for (a, b) in serial.col(j).iter()
                        .zip(sharded.col(j)) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "{op} workers={workers} col={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_fit_is_bitwise_identical_to_serial() {
        // mergeable fits must be worker-count invariant: canonical
        // FIT_CHUNK blocks merged in block order, so the executor's
        // own chunking never leaks into the accumulation order
        let p = Profile {
            name: "fe-fitshard".into(),
            task: Task::Classification { n_classes: 2 },
            gen: GenKind::Blobs { sep: 1.5 },
            n: 3 * FIT_CHUNK,
            d: 6,
            noise: 0.05,
            imbalance: 1.0,
            redundant: 2,
            wild_scales: true,
            seed: 13,
        };
        let ds = generate(&p);
        let train: Vec<usize> = (0..ds.n).collect();
        for name in ["minmax", "standard", "quantile", "robust"] {
            let cfg = scaler_space(name).default_config();
            let serial = fit_scaler_with(name, &ds, &train, &cfg, None);
            for workers in [1usize, 3] {
                let ex = crate::runtime::executor::Executor::new(workers);
                let sharded =
                    fit_scaler_with(name, &ds, &train, &cfg, Some(&ex));
                let a = serial.apply(&ds);
                let b = sharded.apply(&ds);
                for j in 0..a.d {
                    for (x, y) in a.col(j).iter().zip(b.col(j)) {
                        assert_eq!(x.to_bits(), y.to_bits(),
                                   "{name} workers={workers} col={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn zero_copy_ops_share_column_arcs() {
        let (ds, train) = toy_ds();
        // identity: every column pointer-shared
        let out = Fitted::Identity.apply(&ds);
        for j in 0..ds.d {
            assert!(std::sync::Arc::ptr_eq(out.col_arc(j), ds.col_arc(j)));
        }
        assert!(std::sync::Arc::ptr_eq(&out.y, &ds.y));
        // select: the chosen columns are pointer-shared, none copied
        let sel = Fitted::Select(vec![1, 4, 6]);
        let out = sel.apply(&ds);
        assert_eq!(out.d, 3);
        for (o, &j) in [1usize, 4, 6].iter().enumerate() {
            assert!(std::sync::Arc::ptr_eq(out.col_arc(o), ds.col_arc(j)));
        }
        // cross pairs: original columns shared, products appended
        let cp = Fitted::CrossPairs(vec![(0, 2)]);
        let out = cp.apply(&ds);
        assert_eq!(out.d, ds.d + 1);
        for j in 0..ds.d {
            assert!(std::sync::Arc::ptr_eq(out.col_arc(j), ds.col_arc(j)));
        }
        // affine no-op lanes (shift 0, scale 1) stay shared; the
        // touched lane gets a fresh column
        let mut shift = vec![0.0f64; ds.d];
        let mut scale = vec![1.0f64; ds.d];
        shift[3] = 1.0;
        scale[3] = 2.0;
        let aff = Fitted::Affine { shift, scale };
        let out = aff.apply(&ds);
        for j in 0..ds.d {
            assert_eq!(std::sync::Arc::ptr_eq(out.col_arc(j),
                                              ds.col_arc(j)),
                       j != 3, "col {j}");
        }
        // and the touched lane matches the scalar math
        let _ = train;
        for i in 0..ds.n {
            let want = ((ds.at(i, 3) as f64 - 1.0) * 2.0) as f32;
            assert_eq!(out.at(i, 3).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn columnar_arms_match_apply_row_bitwise() {
        // every op with a columnar kernel arm in apply_with must
        // reproduce apply_row's bits cell-for-cell (the columnar
        // path re-orders memory traffic, never arithmetic)
        let (ds, train) = toy_ds();
        let mut rng = Rng::new(5);
        let mut ops: Vec<(&str, Fitted)> = vec![
            ("affine",
             fit_scaler("standard", &ds, &train, &Config::new())),
            ("quantile",
             fit_scaler("quantile", &ds, &train,
                        &scaler_space("quantile").default_config())),
        ];
        let pca_cfg = transformer_space("pca").default_config();
        ops.push(("project",
                  fit_transformer("pca", &ds, &train, &pca_cfg,
                                  &mut rng)));
        let std_fit = fit_scaler("standard", &ds, &train,
                                 &Config::new());
        let svd_cfg = transformer_space("svd").default_config();
        let svd_fit = fit_transformer("svd", &ds, &train, &svd_cfg,
                                      &mut rng);
        ops.push(("chain", Fitted::Chain(vec![std_fit, svd_fit])));
        for (name, f) in &ops {
            let out = f.apply(&ds);
            let mut buf = Vec::new();
            for i in 0..ds.n {
                ds.gather_row(i, &mut buf);
                let want = f.apply_row(&buf);
                assert_eq!(out.d, want.len(), "{name}");
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(out.at(i, j).to_bits(), w.to_bits(),
                               "{name} row={i} col={j}");
                }
            }
        }
    }

    #[test]
    fn chain_shares_untouched_columns_through_stages() {
        // a chain of two zero-copy stages must still pointer-share:
        // Select keeps Arc identity, and a no-op Affine lane after it
        // keeps sharing the original column
        let (ds, _) = toy_ds();
        let chain = Fitted::Chain(vec![
            Fitted::Select(vec![0, 2, 5]),
            Fitted::Affine {
                shift: vec![0.0, 1.0, 0.0],
                scale: vec![1.0, 2.0, 1.0],
            },
        ]);
        let out = chain.apply(&ds);
        assert_eq!(out.d, 3);
        assert!(std::sync::Arc::ptr_eq(out.col_arc(0), ds.col_arc(0)));
        assert!(std::sync::Arc::ptr_eq(out.col_arc(2), ds.col_arc(5)));
        for i in 0..ds.n {
            let want = ((ds.at(i, 2) as f64 - 1.0) * 2.0) as f32;
            assert_eq!(out.at(i, 1).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn chain_composes_dims() {
        let (ds, train) = toy_ds();
        let mut rng = Rng::new(3);
        let a = fit_scaler("standard", &ds, &train, &Config::new());
        let cfg = transformer_space("svd").default_config();
        let b = fit_transformer("svd", &ds, &train, &cfg, &mut rng);
        let chain = Fitted::Chain(vec![a, b]);
        let out = chain.apply(&ds);
        assert_eq!(out.d, 8);
    }
}
