//! Hyper-parameter search-space substrate.
//!
//! Mirrors the paper's space structure (§3.1, Appendix A.2): float
//! (optionally log-scale), integer and categorical parameters, with
//! *conditional* parameters that are only active when a parent
//! categorical takes given values. Spaces compose: the end-to-end
//! AutoML space is built by prefix-merging FE-stage spaces and
//! per-algorithm spaces, and the building blocks decompose it again by
//! fixing subsets of variables (`f[x̄_g / c̄_g]` in the paper).

use std::collections::BTreeMap;

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F(f64),
    I(i64),
    C(String),
}

impl Value {
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::F(x) => *x,
            Value::I(i) => *i as f64,
            Value::C(_) => f64::NAN,
        }
    }
    pub fn as_i64(&self) -> i64 {
        match self {
            Value::F(x) => x.round() as i64,
            Value::I(i) => *i,
            Value::C(_) => 0,
        }
    }
    pub fn as_str(&self) -> &str {
        match self {
            Value::C(s) => s,
            _ => "",
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::F(x) => write!(f, "{x:.5}"),
            Value::I(i) => write!(f, "{i}"),
            Value::C(s) => write!(f, "{s}"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Domain {
    Float { lo: f64, hi: f64, log: bool },
    Int { lo: i64, hi: i64, log: bool },
    Cat(Vec<String>),
}

impl Domain {
    /// Number of grid levels a discretising optimizer (TPOT-style)
    /// would use.
    pub fn cardinality_hint(&self) -> usize {
        match self {
            Domain::Cat(c) => c.len(),
            Domain::Int { lo, hi, .. } => ((hi - lo + 1) as usize).min(8),
            Domain::Float { .. } => 8,
        }
    }
}

/// Condition: parameter is active iff `parent` (a categorical) takes a
/// value in `values`.
#[derive(Clone, Debug, PartialEq)]
pub struct Condition {
    pub parent: String,
    pub values: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub domain: Domain,
    pub default: Value,
    pub condition: Option<Condition>,
}

/// A concrete assignment of (a subset of) parameters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn new() -> Config {
        Config::default()
    }
    pub fn set(&mut self, name: &str, v: Value) {
        self.values.insert(name.to_string(), v);
    }
    pub fn with(mut self, name: &str, v: Value) -> Config {
        self.set(name, v);
        self
    }
    pub fn get(&self, name: &str) -> Option<&Value> {
        self.values.get(name)
    }
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn i64_or(&self, name: &str, default: i64) -> i64 {
        self.get(name).map(|v| v.as_i64()).unwrap_or(default)
    }
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.i64_or(name, default as i64).max(0) as usize
    }
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        match self.get(name) {
            Some(Value::C(s)) => s,
            _ => default,
        }
    }
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.values.iter()
    }
    pub fn len(&self) -> usize {
        self.values.len()
    }
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
    /// Overlay: other's values win on conflicts.
    pub fn merged(&self, other: &Config) -> Config {
        let mut out = self.clone();
        for (k, v) in other.iter() {
            out.values.insert(k.clone(), v.clone());
        }
        out
    }
    /// Stable identity string (used for caching evaluations).
    pub fn key(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.values {
            s.push_str(k);
            s.push('=');
            s.push_str(&v.to_string());
            s.push(';');
        }
        s
    }
}

#[derive(Clone, Debug, Default)]
pub struct ConfigSpace {
    pub params: Vec<Param>,
}

impl ConfigSpace {
    pub fn new() -> ConfigSpace {
        ConfigSpace::default()
    }

    // ---- declaration helpers --------------------------------------
    pub fn float(mut self, name: &str, lo: f64, hi: f64, default: f64)
        -> Self {
        self.params.push(Param {
            name: name.into(),
            domain: Domain::Float { lo, hi, log: false },
            default: Value::F(default),
            condition: None,
        });
        self
    }
    pub fn log_float(mut self, name: &str, lo: f64, hi: f64, default: f64)
        -> Self {
        assert!(lo > 0.0, "log-scale lower bound must be positive");
        self.params.push(Param {
            name: name.into(),
            domain: Domain::Float { lo, hi, log: true },
            default: Value::F(default),
            condition: None,
        });
        self
    }
    pub fn int(mut self, name: &str, lo: i64, hi: i64, default: i64)
        -> Self {
        self.params.push(Param {
            name: name.into(),
            domain: Domain::Int { lo, hi, log: false },
            default: Value::I(default),
            condition: None,
        });
        self
    }
    pub fn cat(mut self, name: &str, choices: &[&str], default: &str)
        -> Self {
        assert!(choices.contains(&default));
        self.params.push(Param {
            name: name.into(),
            domain: Domain::Cat(choices.iter().map(|s| s.to_string())
                .collect()),
            default: Value::C(default.into()),
            condition: None,
        });
        self
    }
    /// Make the most recently added parameter conditional.
    pub fn when(mut self, parent: &str, values: &[&str]) -> Self {
        let p = self.params.last_mut().expect("no parameter to condition");
        p.condition = Some(Condition {
            parent: parent.into(),
            values: values.iter().map(|s| s.to_string()).collect(),
        });
        self
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }
    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    pub fn param(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Merge another space with every parameter (and condition parent)
    /// renamed to `prefix:<name>`.
    pub fn merge_prefixed(mut self, prefix: &str, other: &ConfigSpace)
        -> Self {
        for p in &other.params {
            let mut q = p.clone();
            q.name = format!("{prefix}:{}", p.name);
            if let Some(c) = &mut q.condition {
                c.parent = format!("{prefix}:{}", c.parent);
            }
            self.params.push(q);
        }
        self
    }

    /// Subspace containing only the named parameters (conditions on
    /// missing parents are dropped — they are assumed fixed-active).
    pub fn subspace(&self, names: &[&str]) -> ConfigSpace {
        // DETLINT: allow(hash-iter): membership tests only — the
        // output order is `self.params` order, never the set's.
        let keep: std::collections::HashSet<&str> =
            names.iter().copied().collect();
        let mut out = ConfigSpace::new();
        for p in &self.params {
            if keep.contains(p.name.as_str()) {
                let mut q = p.clone();
                if let Some(c) = &q.condition {
                    if !keep.contains(c.parent.as_str()) {
                        q.condition = None;
                    }
                }
                out.params.push(q);
            }
        }
        out
    }

    /// Subspace of parameters whose names start with `prefix`.
    pub fn subspace_prefixed(&self, prefix: &str) -> ConfigSpace {
        let names: Vec<&str> = self
            .params
            .iter()
            .filter(|p| p.name.starts_with(prefix))
            .map(|p| p.name.as_str())
            .collect();
        self.subspace(&names)
    }

    /// Is `param` active under `cfg` (transitively through parents)?
    pub fn is_active(&self, name: &str, cfg: &Config) -> bool {
        match self.param(name) {
            None => false,
            Some(p) => match &p.condition {
                None => true,
                Some(c) => {
                    if !self.is_active(&c.parent, cfg) {
                        return false;
                    }
                    match cfg.get(&c.parent) {
                        Some(Value::C(v)) => c.values.contains(v),
                        _ => false,
                    }
                }
            },
        }
    }

    fn sample_domain(&self, d: &Domain, rng: &mut Rng) -> Value {
        match d {
            Domain::Float { lo, hi, log } => Value::F(if *log {
                rng.log_uniform(*lo, *hi)
            } else {
                rng.uniform(*lo, *hi)
            }),
            Domain::Int { lo, hi, log } => Value::I(if *log {
                rng.log_uniform(*lo as f64, *hi as f64).round() as i64
            } else {
                rng.int_range(*lo, *hi)
            }),
            Domain::Cat(choices) => Value::C(rng.choice(choices).clone()),
        }
    }

    /// Sample a complete configuration (only active params present).
    /// Parents must be declared before their children.
    pub fn sample(&self, rng: &mut Rng) -> Config {
        let mut cfg = Config::new();
        for p in &self.params {
            if self.is_active(&p.name, &cfg) {
                cfg.set(&p.name, self.sample_domain(&p.domain, rng));
            }
        }
        cfg
    }

    pub fn default_config(&self) -> Config {
        let mut cfg = Config::new();
        for p in &self.params {
            if self.is_active(&p.name, &cfg) {
                cfg.set(&p.name, p.default.clone());
            }
        }
        cfg
    }

    /// Mutate one active parameter of `cfg` (local-search neighbour /
    /// evolutionary mutation). Numeric params move locally; categorical
    /// params resample. Children are (re)sampled or dropped as activity
    /// changes.
    pub fn neighbor(&self, cfg: &Config, rng: &mut Rng) -> Config {
        let active: Vec<&Param> = self
            .params
            .iter()
            .filter(|p| self.is_active(&p.name, cfg))
            .collect();
        if active.is_empty() {
            return cfg.clone();
        }
        let target = active[rng.below(active.len())].name.clone();
        let mut out = Config::new();
        for p in &self.params {
            if !self.is_active(&p.name, &out) {
                continue;
            }
            let v = if p.name == target {
                self.mutate_value(p, cfg.get(&p.name), rng)
            } else {
                match cfg.get(&p.name) {
                    Some(v) => v.clone(),
                    None => self.sample_domain(&p.domain, rng),
                }
            };
            out.set(&p.name, v);
        }
        out
    }

    fn mutate_value(&self, p: &Param, cur: Option<&Value>, rng: &mut Rng)
        -> Value {
        match (&p.domain, cur) {
            (Domain::Float { lo, hi, log }, Some(Value::F(x))) => {
                if *log {
                    let (l, h) = (lo.ln(), hi.ln());
                    let z = (x.ln() + rng.normal() * 0.2 * (h - l))
                        .clamp(l, h);
                    Value::F(z.exp())
                } else {
                    Value::F((x + rng.normal() * 0.2 * (hi - lo))
                        .clamp(*lo, *hi))
                }
            }
            (Domain::Int { lo, hi, .. }, Some(Value::I(i))) => {
                let span = ((hi - lo) as f64 * 0.25).max(1.0);
                let z = (*i as f64 + rng.normal() * span).round() as i64;
                Value::I(z.clamp(*lo, *hi))
            }
            _ => self.sample_domain(&p.domain, rng),
        }
    }

    /// Uniform crossover for evolutionary search.
    pub fn crossover(&self, a: &Config, b: &Config, rng: &mut Rng)
        -> Config {
        let mut out = Config::new();
        for p in &self.params {
            if !self.is_active(&p.name, &out) {
                continue;
            }
            let pick = if rng.bool(0.5) { a } else { b };
            let v = pick
                .get(&p.name)
                .cloned()
                .unwrap_or_else(|| self.sample_domain(&p.domain, rng));
            out.set(&p.name, v);
        }
        out
    }

    /// Encode a config as a fixed-length feature vector in [0,1] for
    /// surrogate models; inactive parameters encode as -1 (SMAC-style).
    pub fn to_features(&self, cfg: &Config) -> Vec<f64> {
        self.params
            .iter()
            .map(|p| {
                if !self.is_active(&p.name, cfg) {
                    return -1.0;
                }
                let v = match cfg.get(&p.name) {
                    Some(v) => v,
                    None => return -1.0,
                };
                match &p.domain {
                    Domain::Float { lo, hi, log } => {
                        let x = v.as_f64();
                        if *log {
                            (x.ln() - lo.ln()) / (hi.ln() - lo.ln())
                        } else {
                            (x - lo) / (hi - lo)
                        }
                    }
                    Domain::Int { lo, hi, .. } => {
                        if hi == lo {
                            0.5
                        } else {
                            (v.as_i64() - lo) as f64 / (hi - lo) as f64
                        }
                    }
                    Domain::Cat(choices) => {
                        let idx = choices
                            .iter()
                            .position(|c| c == v.as_str())
                            .unwrap_or(0);
                        if choices.len() <= 1 {
                            0.5
                        } else {
                            idx as f64 / (choices.len() - 1) as f64
                        }
                    }
                }
                .clamp(0.0, 1.0)
            })
            .collect()
    }

    /// Grid levels per parameter for discretising optimizers.
    pub fn grid_values(&self, p: &Param, levels: usize) -> Vec<Value> {
        match &p.domain {
            Domain::Cat(choices) => {
                choices.iter().map(|c| Value::C(c.clone())).collect()
            }
            Domain::Int { lo, hi, .. } => {
                let span = (hi - lo) as usize + 1;
                let lv = levels.min(span).max(1);
                (0..lv)
                    .map(|i| {
                        Value::I(lo + ((hi - lo) as f64 * i as f64
                            / (lv.max(2) - 1) as f64).round() as i64)
                    })
                    .collect()
            }
            Domain::Float { lo, hi, log } => (0..levels.max(2))
                .map(|i| {
                    let t = i as f64 / (levels.max(2) - 1) as f64;
                    Value::F(if *log {
                        (lo.ln() + t * (hi.ln() - lo.ln())).exp()
                    } else {
                        lo + t * (hi - lo)
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_space() -> ConfigSpace {
        ConfigSpace::new()
            .cat("kernel", &["linear", "rbf", "poly"], "rbf")
            .log_float("gamma", 1e-4, 10.0, 0.1)
            .when("kernel", &["rbf", "poly"])
            .int("degree", 2, 5, 3)
            .when("kernel", &["poly"])
            .float("c", 0.1, 10.0, 1.0)
    }

    #[test]
    fn conditionals_gate_sampling() {
        let s = demo_space();
        let mut rng = Rng::new(0);
        let mut saw_inactive_gamma = false;
        for _ in 0..100 {
            let cfg = s.sample(&mut rng);
            match cfg.str_or("kernel", "") {
                "linear" => {
                    assert!(cfg.get("gamma").is_none());
                    assert!(cfg.get("degree").is_none());
                    saw_inactive_gamma = true;
                }
                "rbf" => {
                    assert!(cfg.get("gamma").is_some());
                    assert!(cfg.get("degree").is_none());
                }
                "poly" => {
                    assert!(cfg.get("gamma").is_some());
                    assert!(cfg.get("degree").is_some());
                }
                other => panic!("unexpected kernel {other}"),
            }
            assert!(cfg.get("c").is_some());
        }
        assert!(saw_inactive_gamma);
    }

    #[test]
    fn samples_respect_bounds_and_log_scale() {
        let s = demo_space();
        let mut rng = Rng::new(1);
        let mut low_gamma = 0;
        for _ in 0..500 {
            let cfg = s.sample(&mut rng);
            if let Some(g) = cfg.get("gamma") {
                let g = g.as_f64();
                assert!((1e-4..=10.0).contains(&g));
                if g < 0.03 {
                    low_gamma += 1; // log scale => many small draws
                }
            }
            let c = cfg.f64_or("c", -1.0);
            assert!((0.1..=10.0).contains(&c));
        }
        assert!(low_gamma > 50, "log sampling looks linear: {low_gamma}");
    }

    #[test]
    fn default_config_is_complete_and_active_only() {
        let s = demo_space();
        let d = s.default_config();
        assert_eq!(d.str_or("kernel", ""), "rbf");
        assert!(d.get("gamma").is_some());
        assert!(d.get("degree").is_none()); // rbf doesn't use degree
    }

    #[test]
    fn features_encode_inactive_as_minus_one() {
        let s = demo_space();
        let cfg = Config::new()
            .with("kernel", Value::C("linear".into()))
            .with("c", Value::F(0.1));
        let f = s.to_features(&cfg);
        assert_eq!(f.len(), 4);
        assert_eq!(f[1], -1.0); // gamma inactive
        assert_eq!(f[2], -1.0); // degree inactive
        assert!((f[3] - 0.0).abs() < 1e-9); // c at lower bound
    }

    #[test]
    fn neighbor_changes_but_stays_valid() {
        let s = demo_space();
        let mut rng = Rng::new(2);
        let cfg = s.default_config();
        let mut changed = 0;
        for _ in 0..50 {
            let nb = s.neighbor(&cfg, &mut rng);
            if nb != cfg {
                changed += 1;
            }
            // validity: active params present, inactive absent
            for p in &s.params {
                assert_eq!(s.is_active(&p.name, &nb),
                           nb.get(&p.name).is_some(), "{}", p.name);
            }
        }
        assert!(changed > 30);
    }

    #[test]
    fn merge_prefixed_rewrites_conditions() {
        let joint = ConfigSpace::new()
            .cat("algo", &["svm"], "svm")
            .merge_prefixed("fe", &demo_space());
        assert!(joint.param("fe:gamma").is_some());
        let cond = joint.param("fe:gamma").unwrap().condition.clone()
            .unwrap();
        assert_eq!(cond.parent, "fe:kernel");
        let sub = joint.subspace_prefixed("fe:");
        assert_eq!(sub.len(), 4);
    }

    #[test]
    fn config_merge_and_key_stable() {
        let a = Config::new().with("x", Value::F(1.0));
        let b = Config::new().with("y", Value::I(2));
        let m = a.merged(&b);
        assert_eq!(m.len(), 2);
        assert_eq!(m.key(), m.clone().key());
        assert_ne!(a.key(), m.key());
    }

    #[test]
    fn grid_values_cover_domain() {
        let s = demo_space();
        let p = s.param("c").unwrap();
        let g = s.grid_values(p, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0].as_f64() - 0.1).abs() < 1e-9);
        assert!((g[4].as_f64() - 10.0).abs() < 1e-9);
        let k = s.param("kernel").unwrap();
        assert_eq!(s.grid_values(k, 5).len(), 3);
    }
}
