//! VolcanoML-RS: scalable end-to-end AutoML via search-space
//! decomposition (reproduction of Li et al., VLDB-J 2022).
//!
//! Layer 3 of the three-layer Rust + JAX + Pallas stack: the
//! coordinator owning building blocks, execution plans, optimizers,
//! meta-learning, ensembles, and the PJRT runtime that executes the
//! AOT-compiled model trainers. See DESIGN.md for the full inventory.

pub mod baselines;
pub mod bench;
pub mod blocks;
pub mod cache;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod algos;
pub mod ensemble;
pub mod fe;
pub mod meta;
pub mod space;
pub mod opt;
pub mod plan;
pub mod runtime;
pub mod service;
pub mod surrogate;
pub mod util;
