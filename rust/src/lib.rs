//! VolcanoML-RS: scalable end-to-end AutoML via search-space
//! decomposition (reproduction of Li et al., VLDB-J 2022).
//!
//! Layer 3 of the three-layer Rust + JAX + Pallas stack: the
//! coordinator owning building blocks, execution plans, optimizers,
//! meta-learning, ensembles, and the PJRT runtime that executes the
//! AOT-compiled model trainers. See DESIGN.md for the full inventory.
//!
//! Concurrency-correctness policy (enforced by `tools/detlint` and
//! the loom models in `rust/tests/loom_models.rs`): every `unsafe`
//! block carries a `// SAFETY:` argument, every `Ordering::Relaxed`
//! a `// SYNC:` justification, search-path modules never iterate
//! hash-ordered containers, and wall-clock reads stay inside the
//! deadline/bench whitelist — see README.md "Verification".

// Unsafe code must be explicit about each unsafe operation even
// inside an `unsafe fn` — the executor's type-erased task queue is
// load-bearing for every workload, so no implicit unsafety.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod bench;
pub mod blocks;
pub mod cache;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod algos;
pub mod ensemble;
pub mod fe;
pub mod meta;
pub mod obs;
pub mod space;
pub mod opt;
pub mod plan;
pub mod runtime;
pub mod service;
pub mod surrogate;
pub mod util;

/// Crate-level alias for the sync shim, so concurrent subsystems
/// write `crate::sync::{Mutex, Condvar, ...}` (std normally, `loom`
/// under `--features loom` — see `util::sync`).
pub use util::sync;
