//! Persistent worker-pool executor for batched candidate evaluation.
//!
//! The Volcano-style `do_next!` pull proposes a *batch* of candidate
//! configurations per pull (and, with cross-leaf super-batching, a
//! whole elimination round of pulls); this executor fans each batch
//! out across a pool of **long-lived** worker threads and returns the
//! results in request order. Determinism contract: the executor never
//! reorders results — `workers = 1` and `workers = N` produce
//! identical output for the same input batch, so worker count is
//! purely a performance knob (the *batch size* is what changes search
//! semantics).
//!
//! The pool is spawned once (per search, via
//! `PipelineEvaluator::with_workers`) and its threads are reused
//! across every batch, so per-thread state — notably the PJRT
//! executable caches in `runtime::mod`, which live in thread-locals —
//! is amortised over the whole search instead of being rebuilt for
//! every batch as the previous `std::thread::scope`-per-batch design
//! did. Work is claimed through an atomic cursor so uneven
//! per-candidate costs balance across the pool, and a panic inside
//! the work closure propagates to the submitting thread once the
//! batch joins, exactly like the serial path.
//!
//! Batches can also be issued **asynchronously**: [`Executor::submit`]
//! returns a [`Submitted`] handle without blocking, so the submitting
//! thread can keep working (the coordinator uses the window to
//! speculatively propose the next round — the async pipeline depth,
//! `Env::pipeline_depth`) and join later with [`Submitted::drain`].
//! A worker panic is re-raised at the `drain` join, mirroring the
//! blocking path, and the pool stays usable afterwards.
//!
//! Batches can carry a **cancellation predicate**
//! ([`Executor::submit_cancellable`]): workers re-check it before
//! claiming each item and stop claiming once it flips, so a
//! wall-clock deadline kills a super-batch mid-run (the unstarted
//! suffix comes back as `None` from [`Submitted::drain_partial`])
//! instead of overshooting by one full batch.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

use crate::util::lock;

thread_local! {
    /// True on threads spawned by a [`WorkerPool`]. A data-parallel
    /// [`Executor::map_ranges`] issued *from* a pool worker (an
    /// evaluation row-sharding its FE apply while the batch that
    /// scheduled it is still running) must execute inline: submitting
    /// a nested batch and blocking on its drain from a worker could
    /// deadlock the pool (every worker waiting on jobs only an idle
    /// worker could run), and eval-level parallelism already has the
    /// pool saturated in that situation anyway.
    static POOL_WORKER: std::cell::Cell<bool> =
        std::cell::Cell::new(false);
}

/// True when the current thread is a [`WorkerPool`] worker.
pub fn on_pool_thread() -> bool {
    POOL_WORKER.with(|c| c.get())
}

/// A fixed-size pool of persistent worker threads fed over a shared
/// channel. Threads are spawned at construction and live until the
/// pool is dropped; every [`WorkerPool::run`] reuses them.
pub struct WorkerPool {
    injector: Mutex<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
                std::thread::Builder::new()
                    .name(format!("volcano-worker-{i}"))
                    .spawn(move || {
                        POOL_WORKER.with(|c| c.set(true));
                        loop {
                            // hold the lock only while dequeuing,
                            // never while running a job
                            let job = lock(&rx).recv();
                            match job {
                                Ok(job) => job(),
                                Err(_) => break, // pool dropped
                            }
                        }
                    })
                    .expect("executor: failed to spawn worker thread")
            })
            .collect();
        WorkerPool { injector: Mutex::new(tx), handles }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Apply `f` to every item on the pool, blocking until the batch
    /// completes; results come back in item order. At most
    /// `min(threads, items)` workers claim items via an atomic cursor.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync,
    {
        self.submit(items, f).drain()
    }

    /// Start a batch on the pool **without blocking**: workers begin
    /// claiming items immediately while the caller keeps running
    /// (e.g. speculatively proposing the next round). Join with
    /// [`PoolBatch::drain`] to collect the results in item order; a
    /// worker panic is re-raised there.
    ///
    /// Crate-internal: the returned handle joins the batch when
    /// dropped, so the borrows captured by `f` and `items` always
    /// outlive the workers' use of them — but leaking the handle
    /// (`mem::forget`, a reference cycle) would void that argument,
    /// which is why this is not a public API. Callers inside the
    /// crate must drain (or drop) the handle in the same frame that
    /// owns the borrows; the public surface built on top
    /// (`Objective::evaluate_batch_overlapped`, `Executor::run`)
    /// always does.
    pub(crate) fn submit<'env, T, R, F>(&self, items: &'env [T], f: F)
        -> PoolBatch<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
    {
        self.submit_cancellable(items, f, || false)
    }

    /// [`Self::submit`] with a cancellation predicate: every worker
    /// re-evaluates `cancel()` before claiming each item and stops
    /// claiming once it returns true, so a wall-clock deadline kills
    /// a batch mid-run instead of overshooting by the whole batch.
    /// Items in flight when the predicate flips still finish (an
    /// evaluation cannot be torn); unclaimed items are left as `None`
    /// — a suffix, since the claim cursor is monotone — and must be
    /// collected with [`PoolBatch::drain_partial`].
    pub(crate) fn submit_cancellable<'env, T, R, F, C>(
        &self, items: &'env [T], f: F, cancel: C)
        -> PoolBatch<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
        C: Fn() -> bool + Send + Sync + 'env,
    {
        let state = Arc::new(BatchState {
            items,
            f: Box::new(f),
            cancel: Box::new(cancel),
            next: AtomicUsize::new(0),
            slots: items.iter().map(|_| Mutex::new(None)).collect(),
        });
        let (done_tx, done_rx) = channel::<std::thread::Result<()>>();
        let n_jobs = self.handles.len().min(items.len());
        for _ in 0..n_jobs {
            let st = state.clone();
            let done_tx = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + 'env> =
                Box::new(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| loop {
                        // per-item cancellation check *before* the
                        // claim: once the predicate flips (deadline),
                        // no further work starts on any worker
                        if (st.cancel)() {
                            break;
                        }
                        let i = st.next.fetch_add(1, Ordering::Relaxed);
                        if i >= st.items.len() {
                            break;
                        }
                        let out = (st.f)(&st.items[i]);
                        *lock(&st.slots[i]) = Some(out);
                    }));
                    // release this worker's share of the batch state
                    // *before* signalling: once the join has seen
                    // every signal, only the handle's own Arc is
                    // left, so no 'env drop glue (f's captures,
                    // uncollected results) can ever run on a worker
                    // after the join returned
                    drop(st);
                    // the batch joins on this send, not the return
                    let _ = done_tx.send(r);
                });
            // SAFETY: the job borrows `items` and whatever `f`
            // captures for 'env. We erase the lifetime to ship it
            // through the 'static channel; the `PoolBatch` handle
            // blocks until every submitted job has signalled
            // completion (or panicked) in `drain` — and, failing
            // that, in its Drop — before 'env can end, so the
            // borrows strictly outlive all use. The completion
            // signal is sent after the closure finishes (panic
            // included, via catch_unwind) and after the worker has
            // dropped its `Arc<BatchState>`, so no worker can still
            // touch 'env data — not even through drop glue of the
            // shared state — once recv() has yielded `n_jobs`
            // results. (Leaking the handle with `mem::forget` would
            // void this argument; the handle is never exposed in a
            // way that invites it.)
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>,
                                      Job>(job)
            };
            lock(&self.injector)
                .send(job)
                .expect("executor: worker pool shut down");
        }
        PoolBatch { state, done_rx, pending: n_jobs }
    }

    /// Data-parallel map over the row ranges of `0..n`: split into
    /// contiguous chunks of at least `min_chunk` rows (about two per
    /// worker, so uneven per-row costs balance), run them on the pool
    /// **with the calling thread helping** through the same claim
    /// cursor, and return the per-chunk results in range order.
    /// Chunk boundaries never affect the concatenated output (each
    /// row's result is independent), so worker count stays a pure
    /// wall-clock knob for callers that splice the chunks back
    /// together — the contract the row-sharded FE apply relies on.
    ///
    /// The calling thread churns through the chunks itself while any
    /// free worker claims alongside it; the return then joins the
    /// queued claim jobs (workers dequeue them as they free up — a
    /// no-op once the cursor is exhausted), so the batch never
    /// outlives the borrows of `f`.
    ///
    /// Crate-internal, and self-guarded against being entered *from*
    /// a pool worker: a nested blocking submission there could
    /// deadlock the pool (every worker waiting in `drain` on queued
    /// claim jobs only an idle worker could dequeue), so that case
    /// runs inline — [`Executor::map_ranges`] is the public surface
    /// and routes it inline one layer up already.
    pub(crate) fn map_ranges<R, F>(&self, n: usize, min_chunk: usize,
                                   f: &F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Send + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if on_pool_thread() {
            return vec![f(0, n)];
        }
        let target = self.threads().max(1) * 2;
        let chunk = n.div_ceil(target).max(min_chunk.max(1));
        let ranges: Vec<(usize, usize)> = (0..n)
            .step_by(chunk)
            .map(|lo| (lo, (lo + chunk).min(n)))
            .collect();
        let batch = self.submit(&ranges, |&(lo, hi)| f(lo, hi));
        batch.help();
        batch.drain()
    }
}

/// Shared per-batch state: the items, the work closure, the claim
/// cursor and one result slot per item. Workers hold `Arc` clones
/// for exactly as long as they run jobs of this batch.
struct BatchState<'env, T, R> {
    items: &'env [T],
    f: Box<dyn Fn(&T) -> R + Send + Sync + 'env>,
    /// Checked before every claim; true stops further claiming.
    cancel: Box<dyn Fn() -> bool + Send + Sync + 'env>,
    next: AtomicUsize,
    slots: Vec<Mutex<Option<R>>>,
}

/// An in-flight batch on a [`WorkerPool`], created by
/// [`WorkerPool::submit`]. [`drain`](PoolBatch::drain) joins the
/// batch and returns the results in item order (re-raising a worker
/// panic); dropping the handle joins without collecting, so the
/// batch can never outlive the data it borrows.
pub struct PoolBatch<'env, T, R> {
    state: Arc<BatchState<'env, T, R>>,
    done_rx: Receiver<std::thread::Result<()>>,
    pending: usize,
}

impl<'env, T, R> PoolBatch<'env, T, R> {
    /// Run the batch's claim loop on the *calling* thread: claim and
    /// execute items through the same atomic cursor the workers use,
    /// until the batch is exhausted (or its cancellation predicate
    /// flips). This is how a data-parallel map keeps making progress
    /// when every pool worker is busy — the submitter works its own
    /// batch alongside whatever workers pick it up. A panic in the
    /// work closure unwinds the caller directly, exactly like inline
    /// execution; the [`Drop`] join then waits out the in-flight
    /// workers.
    pub(crate) fn help(&self) {
        let st = &self.state;
        loop {
            if (st.cancel)() {
                break;
            }
            let i = st.next.fetch_add(1, Ordering::Relaxed);
            if i >= st.items.len() {
                break;
            }
            let out = (st.f)(&st.items[i]);
            *lock(&st.slots[i]) = Some(out);
        }
    }

    /// Block until every worker has finished this batch, then return
    /// the results in item order. A panic inside the work closure is
    /// re-raised here — after all workers have signalled, so the
    /// pool (and the batch's borrows) are never left dangling. Only
    /// valid for non-cancellable submissions (every slot filled);
    /// cancellable batches join with
    /// [`drain_partial`](Self::drain_partial).
    pub fn drain(self) -> Vec<R> {
        self.drain_partial()
            .into_iter()
            .map(|r| r.expect("executor: worker left a slot empty"))
            .collect()
    }

    /// Like [`drain`](Self::drain), but items never claimed because
    /// the batch's cancellation predicate flipped come back as
    /// `None`. The `None`s always form a suffix: the claim cursor is
    /// monotone, so everything before the first unclaimed item was
    /// claimed (and, once the join completes, finished).
    pub fn drain_partial(mut self) -> Vec<Option<R>> {
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..self.pending {
            match self.done_rx.recv()
                .expect("executor: worker exited without signalling") {
                Ok(()) => {}
                Err(p) => panic = Some(p),
            }
        }
        self.pending = 0;
        if let Some(p) = panic {
            resume_unwind(p);
        }
        self.state
            .slots
            .iter()
            .map(|m| lock(m).take())
            .collect()
    }
}

impl<'env, T, R> Drop for PoolBatch<'env, T, R> {
    fn drop(&mut self) {
        // join (without collecting) so the workers' borrows of 'env
        // data end before the handle does — this runs during unwind
        // too, keeping an abandoned overlap window panic-safe
        for _ in 0..self.pending {
            let _ = self.done_rx.recv();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // replace the injector with a dangling sender so the original
        // is dropped and every worker's recv() errors out
        let (tx, _) = channel::<Job>();
        *lock(&self.injector) = tx;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Executor facade used by the evaluator: serial inline execution for
/// one worker (or one item), a shared persistent [`WorkerPool`]
/// otherwise. Cloning shares the pool (and its threads).
#[derive(Clone, Default)]
pub struct Executor {
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("workers", &self.workers.max(1))
            .field("persistent", &self.pool.is_some())
            .finish()
    }
}

impl Executor {
    /// Pool with `workers` persistent threads; 0 is clamped to 1
    /// (serial, no threads spawned).
    pub fn new(workers: usize) -> Executor {
        let workers = workers.max(1);
        let pool = if workers > 1 {
            Some(Arc::new(WorkerPool::new(workers)))
        } else {
            None
        };
        Executor { workers, pool }
    }

    /// The strictly sequential executor (the pre-parallel behaviour).
    pub fn serial() -> Executor {
        Executor::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers.max(1)
    }

    /// Apply `f` to every item, returning results in item order.
    ///
    /// With one worker (or at most one item) this runs inline on the
    /// caller's thread — byte-for-byte the serial evaluation path.
    /// Otherwise the batch runs on the persistent pool.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync,
    {
        self.submit(items, f).drain()
    }

    /// Data-parallel map over the row ranges of `0..n` — the
    /// primitive behind the row-sharded FE apply. Returns per-chunk
    /// results in range order; callers concatenate. Runs inline (one
    /// `f(0, n)` call) when the executor is serial, when `n` does not
    /// clear `min_chunk`, or when the calling thread is itself a pool
    /// worker (an evaluation already running on the pool — nesting a
    /// blocking batch there could deadlock, and the pool is saturated
    /// by eval-level parallelism anyway; see [`on_pool_thread`]).
    /// Otherwise the chunks run on the pool with this thread helping
    /// ([`WorkerPool::map_ranges`]). Chunking never changes the
    /// concatenated output, so every path is bit-identical.
    pub fn map_ranges<R, F>(&self, n: usize, min_chunk: usize, f: F)
        -> Vec<R>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Send + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        match &self.pool {
            Some(pool) if n > min_chunk.max(1) && !on_pool_thread() => {
                pool.map_ranges(n, min_chunk, &f)
            }
            _ => vec![f(0, n)],
        }
    }

    /// Start a batch **without blocking** and return a handle to join
    /// it later — the primitive behind the async pipeline depth: the
    /// caller keeps the submitting thread busy (speculative proposal
    /// of the next round) while the pool evaluates, then calls
    /// [`Submitted::drain`].
    ///
    /// With one worker (or at most one item) nothing is scheduled:
    /// the work is deferred and runs inline on the caller's thread at
    /// `drain`, *after* any overlap work — so the relative order of
    /// speculation and evaluation is the same for every worker count
    /// (speculation never sees the batch's results), and a panicking
    /// evaluation always surfaces at the join.
    ///
    /// Crate-internal (see [`WorkerPool::submit`] for why): the
    /// handle must be drained or dropped in the frame that owns the
    /// borrows, never leaked.
    pub(crate) fn submit<'env, T, R, F>(&self, items: &'env [T], f: F)
        -> Submitted<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
    {
        self.submit_cancellable(items, f, || false)
    }

    /// [`Self::submit`] with a per-item cancellation predicate:
    /// workers (or the inline path, item by item at the drain) stop
    /// starting new items once `cancel()` returns true, leaving the
    /// unstarted suffix as `None` in
    /// [`Submitted::drain_partial`]'s output. This is how a
    /// wall-clock deadline kills a super-batch mid-run instead of
    /// overshooting by the full batch.
    pub(crate) fn submit_cancellable<'env, T, R, F, C>(
        &self, items: &'env [T], f: F, cancel: C)
        -> Submitted<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
        C: Fn() -> bool + Send + Sync + 'env,
    {
        match &self.pool {
            Some(pool) if items.len() > 1 => {
                Submitted::Pool(pool.submit_cancellable(items, f,
                                                        cancel))
            }
            _ => Submitted::Lazy {
                items,
                f: Box::new(f),
                cancel: Box::new(cancel),
            },
        }
    }
}

/// A batch issued through [`Executor::submit`]: either truly in
/// flight on the pool, or deferred for inline execution at the join
/// (serial executor / singleton batches).
pub enum Submitted<'env, T, R> {
    /// Deferred inline execution: nothing has run yet; `drain`
    /// evaluates on the caller's thread.
    Lazy {
        items: &'env [T],
        f: Box<dyn Fn(&T) -> R + Send + Sync + 'env>,
        cancel: Box<dyn Fn() -> bool + Send + Sync + 'env>,
    },
    /// In flight on the persistent pool.
    Pool(PoolBatch<'env, T, R>),
}

impl<'env, T, R> Submitted<'env, T, R> {
    /// Join the batch: block for (or inline-run) the evaluations and
    /// return the results in item order. Worker panics re-raise here.
    /// Only valid for non-cancellable submissions; cancellable ones
    /// join with [`drain_partial`](Self::drain_partial).
    pub fn drain(self) -> Vec<R> {
        self.drain_partial()
            .into_iter()
            .map(|r| r.expect("executor: item cancelled in a \
                               non-cancellable batch"))
            .collect()
    }

    /// Join the batch, with items never started (the cancellation
    /// predicate flipped first) as `None` — always a suffix of the
    /// output, for the pool and the inline path alike.
    pub fn drain_partial(self) -> Vec<Option<R>> {
        match self {
            Submitted::Lazy { items, f, cancel } => {
                let mut out: Vec<Option<R>> =
                    Vec::with_capacity(items.len());
                let mut dead = false;
                for t in items {
                    // once the predicate flips the rest of the batch
                    // is an unstarted suffix, same as on the pool
                    dead = dead || cancel();
                    out.push(if dead { None } else { Some(f(t)) });
                }
                out
            }
            Submitted::Pool(batch) => batch.drain_partial(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;
    use std::time::{Duration, Instant};

    #[test]
    fn results_arrive_in_item_order() {
        for workers in [1, 2, 4, 7] {
            let ex = Executor::new(workers);
            let items: Vec<usize> = (0..40).collect();
            let out = ex.run(&items, |&i| i * 3);
            assert_eq!(out, (0..40).map(|i| i * 3).collect::<Vec<_>>(),
                       "workers={workers}");
        }
    }

    #[test]
    fn serial_and_parallel_agree_bitwise() {
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.37).collect();
        let f = |x: &f64| (x.sin() * 1e6).cos() / (1.0 + x.abs());
        let a = Executor::serial().run(&items, f);
        let b = Executor::new(4).run(&items, f);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn pool_actually_overlaps_work() {
        // 8 sleeps of 20ms: serial floor is 160ms; two workers should
        // land well under it even on a loaded box.
        let items: Vec<u32> = (0..8).collect();
        let ex = Executor::new(4);
        let t0 = Instant::now();
        ex.run(&items, |_| {
            std::thread::sleep(Duration::from_millis(20));
        });
        let dt = t0.elapsed();
        assert!(dt < Duration::from_millis(140),
                "no overlap observed: {dt:?}");
    }

    #[test]
    fn zero_workers_clamps_to_serial() {
        let ex = Executor::new(0);
        assert_eq!(ex.workers(), 1);
        assert_eq!(ex.run(&[1, 2, 3], |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let out: Vec<i32> = Executor::new(4).run(&[], |x: &i32| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let out = Executor::new(16).run(&[5, 6], |&x| x * x);
        assert_eq!(out, vec![25, 36]);
    }

    /// Force both pool threads to participate: each of the two items
    /// blocks until two distinct claimants have arrived, so a single
    /// thread can never clear the batch alone.
    fn both_worker_ids(ex: &Executor) -> HashSet<ThreadId> {
        let arrived = AtomicUsize::new(0);
        let ids = ex.run(&[0usize, 1usize], |_| {
            arrived.fetch_add(1, Ordering::SeqCst);
            let t0 = Instant::now();
            while arrived.load(Ordering::SeqCst) < 2 {
                assert!(t0.elapsed() < Duration::from_secs(10),
                        "second worker never arrived");
                std::hint::spin_loop();
            }
            std::thread::current().id()
        });
        ids.into_iter().collect()
    }

    #[test]
    fn pool_threads_persist_across_batches() {
        // the whole point of the persistent pool: consecutive batches
        // run on the *same* threads, so per-thread caches survive
        let ex = Executor::new(2);
        let first = both_worker_ids(&ex);
        assert_eq!(first.len(), 2, "both workers claim one item each");
        assert!(!first.contains(&std::thread::current().id()),
                "work runs on pool threads, not the caller");
        for _ in 0..3 {
            let again = both_worker_ids(&ex);
            assert_eq!(first, again,
                       "batch ran on fresh threads: {again:?} vs \
                        {first:?}");
        }
    }

    #[test]
    fn cloned_executor_shares_the_pool() {
        let ex = Executor::new(2);
        let clone = ex.clone();
        let a = both_worker_ids(&ex);
        let b = both_worker_ids(&clone);
        assert_eq!(a, b, "clone must reuse the same pool threads");
    }

    #[test]
    fn submit_runs_concurrently_with_caller_work() {
        // Ordering, not wall-clock (robust on loaded CI boxes):
        // submit must return before the 30ms jobs can possibly have
        // all finished, and while the caller then works, the pool
        // must make progress on its own — both observable through
        // the completion counter without any tight timing bound.
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..4).collect();
        let hits = AtomicUsize::new(0);
        let pending = ex.submit(&items, |_| {
            std::thread::sleep(Duration::from_millis(30));
            hits.fetch_add(1, Ordering::SeqCst);
        });
        // submit did not block: a 30ms job cannot have completed in
        // the microseconds since
        assert!(hits.load(Ordering::SeqCst) < items.len(),
                "submit ran the whole batch before returning");
        // the pool works while the caller does: wait out (generously)
        // one job's length of caller-side work and expect progress
        let t0 = Instant::now();
        while hits.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10),
                    "pool made no progress during the overlap window");
            std::thread::sleep(Duration::from_millis(5));
        }
        pending.drain();
        assert_eq!(hits.load(Ordering::SeqCst), items.len());
    }

    #[test]
    fn submit_serial_defers_work_until_drain() {
        let ex = Executor::serial();
        let ran = AtomicUsize::new(0);
        let items = [1, 2, 3];
        let pending = ex.submit(&items, |&x| {
            ran.fetch_add(1, Ordering::SeqCst);
            x * 2
        });
        assert_eq!(ran.load(Ordering::SeqCst), 0,
                   "lazy submit must not evaluate before drain");
        assert_eq!(pending.drain(), vec![2, 4, 6]);
        assert_eq!(ran.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn submit_panic_propagates_at_drain_and_pool_survives() {
        for workers in [1, 2] {
            let ex = Executor::new(workers);
            let before = if workers == 2 {
                Some(both_worker_ids(&ex))
            } else {
                None
            };
            let items = [0, 1, 2, 3];
            let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
                let pending = ex.submit(&items, |&i: &i32| {
                    if i == 2 {
                        panic!("boom in flight");
                    }
                    i
                });
                // overlap window: the panic must wait for the join
                let _ = std::hint::black_box(7 * 6);
                pending.drain()
            }));
            assert!(caught.is_err(),
                    "workers={workers}: panic must surface at drain");
            let out = ex.run(&[1, 2, 3], |&x| x + 1);
            assert_eq!(out, vec![2, 3, 4], "workers={workers}");
            // thread identity is pinned across the panic: the same
            // pool threads serve the post-panic batches
            if let Some(before) = before {
                assert_eq!(before, both_worker_ids(&ex),
                           "pool threads changed across the panic");
            }
        }
    }

    #[test]
    fn dropped_submission_joins_without_collecting() {
        // dropping the handle (e.g. during an unwind of the caller)
        // must wait out the in-flight jobs, then leave the pool usable
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..6).collect();
        let hits = AtomicUsize::new(0);
        {
            let _pending = ex.submit(&items, |_| {
                std::thread::sleep(Duration::from_millis(5));
                hits.fetch_add(1, Ordering::SeqCst);
            });
            // handle dropped here, joining the batch
        }
        assert_eq!(hits.load(Ordering::SeqCst), 6);
        assert_eq!(ex.run(&[9], |&x| x), vec![9]);
    }

    #[test]
    fn cancelled_batch_returns_none_suffix_and_pool_survives() {
        // a predicate that flips after k completions must leave the
        // tail unclaimed (None), never tear an in-flight item, and
        // keep the pool usable — on the pool and the inline path
        for workers in [1usize, 3] {
            let ex = Executor::new(workers);
            let items: Vec<u32> = (0..12).collect();
            let started = AtomicUsize::new(0);
            let out = ex
                .submit_cancellable(
                    &items,
                    |&x| {
                        started.fetch_add(1, Ordering::SeqCst);
                        x * 2
                    },
                    || started.load(Ordering::SeqCst) >= 4,
                )
                .drain_partial();
            assert_eq!(out.len(), 12, "workers={workers}");
            // completed prefix, cancelled suffix — no gaps
            let cut = out.iter().position(|r| r.is_none())
                .expect("cancellation must leave an unstarted tail");
            assert!(cut >= 4 && cut < 12, "workers={workers}: {cut}");
            for (i, r) in out.iter().enumerate() {
                if i < cut {
                    assert_eq!(*r, Some(items[i] * 2),
                               "workers={workers}");
                } else {
                    assert!(r.is_none(),
                            "workers={workers}: gap at {i}");
                }
            }
            // pool unaffected
            assert_eq!(ex.run(&[7, 8], |&x| x + 1), vec![8, 9]);
        }
    }

    #[test]
    fn never_cancelled_batch_fills_every_slot() {
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..9).collect();
        let out = ex
            .submit_cancellable(&items, |&x| x + 1, || false)
            .drain_partial();
        assert_eq!(out, (1..=9).map(Some).collect::<Vec<_>>());
    }

    #[test]
    fn map_ranges_concatenation_matches_serial_bitwise() {
        // per-row results spliced from chunks must equal the serial
        // single-range output byte for byte, for any worker count
        let n = 10_000usize;
        let per_row = |i: usize| ((i as f64).sin() * 1e6).cos() as f32;
        let run = |ex: &Executor, min_chunk: usize| -> Vec<f32> {
            let parts = ex.map_ranges(n, min_chunk, |lo, hi| {
                (lo..hi).map(per_row).collect::<Vec<f32>>()
            });
            parts.into_iter().flatten().collect()
        };
        let serial = run(&Executor::serial(), 1);
        assert_eq!(serial.len(), n);
        for workers in [2usize, 4, 7] {
            let ex = Executor::new(workers);
            for min_chunk in [1usize, 64, 5000, 20_000] {
                let out = run(&ex, min_chunk);
                assert_eq!(out.len(), n,
                           "workers={workers} min_chunk={min_chunk}");
                for (a, b) in serial.iter().zip(&out) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "workers={workers} \
                                min_chunk={min_chunk}");
                }
            }
        }
    }

    #[test]
    fn map_ranges_actually_runs_on_the_pool() {
        // with a pool and a small min_chunk, more than one distinct
        // thread participates (the caller helps, workers claim)
        let ex = Executor::new(4);
        let ids = Mutex::new(HashSet::new());
        let parts = ex.map_ranges(64, 1, |lo, hi| {
            lock(&ids).insert(std::thread::current().id());
            // slow the chunks down so workers have time to claim
            std::thread::sleep(Duration::from_millis(5));
            hi - lo
        });
        assert_eq!(parts.iter().sum::<usize>(), 64);
        assert!(lock(&ids).len() >= 2,
                "expected pool participation, got {} thread(s)",
                lock(&ids).len());
    }

    #[test]
    fn map_ranges_from_a_pool_worker_runs_inline() {
        // a nested data-parallel map issued from inside a pool job
        // must not submit to the pool (deadlock risk): it runs inline
        // on the worker, as one chunk, and the outer batch completes
        let ex = Executor::new(2);
        let ex2 = ex.clone();
        let out = ex.run(&[10usize, 20, 30, 40], |&n| {
            assert!(on_pool_thread());
            let parts = ex2.map_ranges(n, 1, |lo, hi| hi - lo);
            assert_eq!(parts.len(), 1,
                       "nested map must run as one inline chunk");
            parts.iter().sum::<usize>()
        });
        assert_eq!(out, vec![10, 20, 30, 40]);
        // and the caller's thread is not a pool worker
        assert!(!on_pool_thread());
    }

    #[test]
    fn map_ranges_below_min_chunk_stays_inline() {
        let ex = Executor::new(4);
        let main_id = std::thread::current().id();
        let parts = ex.map_ranges(100, 512, |lo, hi| {
            assert_eq!(std::thread::current().id(), main_id);
            (lo, hi)
        });
        assert_eq!(parts, vec![(0, 100)]);
        let empty: Vec<(usize, usize)> =
            ex.map_ranges(0, 1, |lo, hi| (lo, hi));
        assert!(empty.is_empty());
    }

    #[test]
    fn map_ranges_issued_against_a_busy_pool_still_completes() {
        // a data-parallel map submitted while the workers are mid-way
        // through another batch completes correctly: the helping
        // caller churns through the chunks, and the queued claim jobs
        // are joined once the workers free up
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..4).collect();
        let pending = ex.submit(&items, |_| {
            std::thread::sleep(Duration::from_millis(30));
        });
        let parts = ex.map_ranges(1000, 1, |lo, hi| hi - lo);
        assert_eq!(parts.iter().sum::<usize>(), 1000);
        pending.drain();
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let ex = Executor::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.run(&[0, 1, 2, 3], |&i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            });
        }));
        assert!(caught.is_err(), "panic must reach the caller");
        // the pool is still usable afterwards
        let out = ex.run(&[1, 2, 3, 4], |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5]);
    }
}
