//! Persistent multi-tenant worker-pool executor for batched candidate
//! evaluation.
//!
//! The Volcano-style `do_next!` pull proposes a *batch* of candidate
//! configurations per pull (and, with cross-leaf super-batching, a
//! whole elimination round of pulls); this executor fans each batch
//! out across a pool of **long-lived** worker threads and returns the
//! results in request order. Determinism contract: the executor never
//! reorders results — `workers = 1` and `workers = N` produce
//! identical output for the same input batch, so worker count is
//! purely a performance knob (the *batch size* is what changes search
//! semantics).
//!
//! One pool can serve **many concurrent searches**: each search
//! registers a [`TenantId`] (see [`WorkerPool::register_tenant`] and
//! [`Executor::shared`]) and submits batches to its own FIFO queue.
//! Workers pick one item at a time by *stride scheduling*: every
//! tenant carries a virtual-time `pass` that advances by
//! `STRIDE_ONE / weight` per claimed item, and the runnable tenant
//! with the smallest pass is picked next — so under saturating load
//! per-tenant claim counts converge to weight proportions, an idle
//! tenant re-enters at the current virtual time instead of
//! monopolising the pool to catch up, and a tenant whose batch is
//! cancelled mid-run (deadline death) simply stops claiming, freeing
//! every subsequent pick to its co-tenants. Per-tenant batches still
//! complete in submission order, and results never reorder, so
//! co-tenancy — like worker count — is a pure wall-clock knob: a
//! search's trajectory is invariant to how many tenants share the
//! pool.
//!
//! The pool is spawned once (per search via
//! `PipelineEvaluator::with_workers`, or per process via the search
//! service) and its threads are reused across every batch, so
//! per-thread state — notably the PJRT executable caches in
//! `runtime::mod`, which live in thread-locals — is amortised over
//! the whole search instead of being rebuilt for every batch. Work is
//! claimed through an atomic cursor so uneven per-candidate costs
//! balance across the pool, and a panic inside the work closure
//! propagates to the submitting thread once the batch joins, exactly
//! like the serial path.
//!
//! Batches can also be issued **asynchronously**: [`Executor::submit`]
//! returns a [`Submitted`] handle without blocking, so the submitting
//! thread can keep working (the coordinator uses the window to
//! speculatively propose the next round — the async pipeline depth,
//! `Env::pipeline_depth`) and join later with [`Submitted::drain`].
//! A worker panic is re-raised at the `drain` join, mirroring the
//! blocking path, and the pool stays usable afterwards.
//!
//! Batches can carry a **cancellation predicate**
//! ([`Executor::submit_cancellable`]): workers re-check it before
//! claiming each item and stop claiming once it flips, so a
//! wall-clock deadline kills a super-batch mid-run (the unstarted
//! suffix comes back as `None` from [`Submitted::drain_partial`])
//! instead of overshooting by one full batch.
//!
//! All synchronisation primitives come through [`crate::sync`] — a
//! plain `std` re-export in normal builds, the loom model checker
//! under `--features loom` — so the scheduler's interleavings are
//! model-checked by `rust/tests/loom_models.rs` against this exact
//! code (the bounded surface is the feature-gated `model` module
//! below, not a reimplementation).

// Every pub type here should explain itself in failure output — the
// scheduler is exactly where Debug printouts get read under pressure.
#![warn(missing_debug_implementations)]

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::thread::JoinHandle;

use crate::sync::{lock, Arc, AtomicBool, AtomicU64, AtomicUsize,
                  Condvar, Mutex, Ordering};

/// Identifies one fair-share claimant on a shared [`WorkerPool`].
/// Tenant 0 is the implicit default for unregistered submissions.
pub type TenantId = u64;

/// Virtual-time increment of one weight-1 claim (stride scheduling):
/// a tenant's pass advances by `STRIDE_ONE / weight` per pick, so the
/// min-pass rule hands out claims in weight proportion.
const STRIDE_ONE: u64 = 1 << 20;

/// Largest accepted fair-share weight. Beyond this the per-claim
/// stride would truncate to zero and the tenant's virtual time would
/// never advance — it would stay the min-pass tenant forever and
/// starve every co-tenant — so [`WorkerPool::register_tenant`] and
/// [`WorkerPool::set_tenant_weight`] clamp into `1..=MAX_TENANT_WEIGHT`.
pub const MAX_TENANT_WEIGHT: u32 = STRIDE_ONE as u32;

thread_local! {
    /// True on threads spawned by a [`WorkerPool`]. A data-parallel
    /// [`Executor::map_ranges`] issued *from* a pool worker (an
    /// evaluation row-sharding its FE apply while the batch that
    /// scheduled it is still running) must execute inline: submitting
    /// a nested batch and blocking on its drain from a worker could
    /// deadlock the pool (every worker waiting on jobs only an idle
    /// worker could run), and eval-level parallelism already has the
    /// pool saturated in that situation anyway.
    static POOL_WORKER: std::cell::Cell<bool> =
        std::cell::Cell::new(false);
}

/// True when the current thread is a [`WorkerPool`] worker.
pub fn on_pool_thread() -> bool {
    POOL_WORKER.with(|c| c.get())
}

/// Outcome of one claim attempt on a queued batch.
#[derive(Clone, Copy, PartialEq)]
enum Step {
    /// An item was claimed and executed; the batch may have more.
    Ran,
    /// Nothing left to claim (cursor exhausted, cancelled or
    /// poisoned by a panic): the batch should leave the queue.
    Retired,
}

/// Claim-one-item interface a worker drives after picking a batch.
/// Implemented by [`BatchState`]; object-safe so the scheduler queue
/// can hold batches of any item/result type.
trait PoolTask: Send + Sync {
    fn run_one(&self) -> Step;
}

/// Completion latch shared between a batch handle and the workers:
/// counts in-flight picks and records retirement. Lives in its own
/// `'static` allocation so workers never touch `'env` batch state
/// after their final [`Latch::post`].
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    /// Picks handed to workers that have not posted back yet.
    active: usize,
    /// No further pick will ever claim an item of this batch.
    retired: bool,
}

impl Latch {
    fn new() -> Latch {
        Latch {
            state: Mutex::new(LatchState { active: 0, retired: false }),
            cv: Condvar::new(),
        }
    }

    fn is_retired(&self) -> bool {
        lock(&self.state).retired
    }

    fn retire(&self) {
        lock(&self.state).retired = true;
        self.cv.notify_all();
    }

    fn post(&self, step: Step) {
        let mut st = lock(&self.state);
        st.active -= 1;
        if step == Step::Retired {
            st.retired = true;
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Block until the batch is retired with no pick in flight.
    fn wait_done(&self) {
        let mut st = lock(&self.state);
        while !(st.retired && st.active == 0) {
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// One queued batch: the type-erased claim task plus the completion
/// latch its handle waits on.
struct QueuedBatch {
    task: Arc<dyn PoolTask>,
    latch: Arc<Latch>,
}

/// Per-tenant scheduler state: fair-share weight, stride virtual
/// time, and a FIFO of this tenant's in-flight batches (only the
/// front one is claimed from, preserving submission order).
struct TenantState {
    weight: u32,
    /// Stride-scheduling virtual time; the min-pass runnable tenant
    /// is picked next.
    pass: u64,
    queue: VecDeque<QueuedBatch>,
}

struct SchedState {
    shutdown: bool,
    /// Global virtual time: the pass of the last picked tenant.
    /// (Re)activated tenants start here, so an idle spell never
    /// turns into a catch-up monopoly.
    vnow: u64,
    tenants: HashMap<TenantId, TenantState>,
}

/// The `'static` heart of the pool, shared by workers and batch
/// handles via `Arc` so a handle can finish its join even while the
/// pool itself is being dropped.
struct PoolInner {
    sched: Mutex<SchedState>,
    work_cv: Condvar,
    next_tenant: AtomicU64,
}

type Picked = (Arc<dyn PoolTask>, Arc<Latch>, TenantId);

/// Virtual-time advance of one claim for a tenant of this weight.
/// Never zero — a zero stride would freeze the tenant's pass at the
/// minimum and starve every co-tenant — so the effective weight range
/// is `1..=STRIDE_ONE` (see [`MAX_TENANT_WEIGHT`]).
fn stride(weight: u32) -> u64 {
    (STRIDE_ONE / u64::from(weight.max(1))).max(1)
}

/// Stride-scheduling pick: prune retired front batches, select the
/// min-pass tenant with runnable work (ties break on the smaller
/// tenant id), advance its virtual time, and hand out its front
/// batch. Retirement check and pick count happen under **one** latch
/// lock acquisition (while the scheduler lock is held, so the queue
/// entry cannot be unlinked underneath): either the count lands
/// before `retired` is observable — and a handle's `wait_done` then
/// blocks until the pick posts back — or the batch is already retired
/// and is popped instead of picked. A handle that has seen
/// `retired && active == 0` therefore knows no further pick of its
/// batch can ever exist.
fn pick_task(st: &mut SchedState) -> Option<Picked> {
    loop {
        let mut best: Option<(u64, TenantId)> = None;
        for (&id, t) in st.tenants.iter_mut() {
            while t.queue.front().is_some_and(|b| b.latch.is_retired())
            {
                t.queue.pop_front();
            }
            if t.queue.is_empty() {
                continue;
            }
            let better = match best {
                None => true,
                Some((bp, bid)) => {
                    t.pass < bp || (t.pass == bp && id < bid)
                }
            };
            if better {
                best = Some((t.pass, id));
            }
        }
        let (_, id) = best?;
        let vnow = st.vnow;
        let t = st.tenants.get_mut(&id).expect("picked tenant exists");
        let counted = {
            let front =
                t.queue.front().expect("picked tenant has work");
            let mut latch = lock(&front.latch.state);
            if latch.retired {
                false
            } else {
                latch.active += 1;
                true
            }
        };
        if !counted {
            // retired between the prune above and the pick: drop it
            // and redo tenant selection from scratch
            t.queue.pop_front();
            continue;
        }
        st.vnow = vnow.max(t.pass);
        t.pass = t.pass.saturating_add(stride(t.weight));
        let front = t.queue.front().expect("picked tenant has work");
        return Some((front.task.clone(), front.latch.clone(), id));
    }
}

/// Queue one batch on a tenant, creating the tenant (weight 1) on
/// first contact and re-anchoring an idle tenant's pass at the
/// current virtual time so an idle spell never turns into a catch-up
/// monopoly. Shared verbatim by [`WorkerPool::submit_cancellable`]
/// and the loom models' `model::MiniSched`, so the checked
/// interleavings drive the production enqueue path.
fn enqueue_batch(st: &mut SchedState, tenant: TenantId,
                 batch: QueuedBatch) {
    let vnow = st.vnow;
    let t = st.tenants.entry(tenant).or_insert_with(|| TenantState {
        weight: 1,
        pass: vnow,
        queue: VecDeque::new(),
    });
    if t.queue.is_empty() {
        // waking from idle: rejoin at the current virtual time
        // instead of replaying the idle spell
        t.pass = t.pass.max(vnow);
    }
    t.queue.push_back(batch);
}

/// Drop a tenant's scheduler entry if (after pruning retired
/// batches) it has no work left; refuses otherwise. Shared by
/// [`WorkerPool::remove_tenant`] and the loom models.
fn remove_tenant_inner(st: &mut SchedState, tenant: TenantId) -> bool {
    if let Some(t) = st.tenants.get_mut(&tenant) {
        t.queue.retain(|b| !b.latch.is_retired());
        if t.queue.is_empty() {
            st.tenants.remove(&tenant);
            return true;
        }
    }
    false
}

fn worker_loop(inner: &PoolInner) {
    POOL_WORKER.with(|c| c.set(true));
    loop {
        let (task, latch, tenant) = {
            let mut st = lock(&inner.sched);
            loop {
                if let Some(p) = pick_task(&mut st) {
                    break p;
                }
                // drain every queued batch before honouring shutdown,
                // so in-flight handles always complete their join
                if st.shutdown {
                    return;
                }
                // Idle-wait accounting is observation only: the clock
                // reads happen around the wait either way the race on
                // the metrics flag goes, and the recorded duration
                // feeds no scheduling decision.
                let t0 = if crate::obs::metrics_on() {
                    crate::obs::clock::now_ns()
                } else {
                    0
                };
                st = inner
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
                if t0 != 0 {
                    crate::obs::metrics::idle_wait_ns(
                        crate::obs::clock::now_ns()
                            .saturating_sub(t0),
                    );
                }
            }
        };
        crate::obs::metrics::pool_claim(tenant);
        crate::obs::event!("pool", "claim", "tenant" => tenant);
        let step = {
            let _span =
                crate::obs::span!("pool", "run", "tenant" => tenant);
            task.run_one()
        };
        // drop the batch state *before* posting: once a join has seen
        // `active` reach zero, no worker clone of the 'env state
        // survives, so not even Arc drop glue can run on a worker
        // after the join returned
        drop(task);
        latch.post(step);
    }
}

/// A fixed-size pool of persistent worker threads scheduled by
/// weighted fair share across tenants. Threads are spawned at
/// construction and live until the pool is dropped; every batch
/// reuses them.
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let inner = Arc::new(PoolInner {
            sched: Mutex::new(SchedState {
                shutdown: false,
                vnow: 0,
                tenants: HashMap::new(),
            }),
            work_cv: Condvar::new(),
            next_tenant: AtomicU64::new(1),
        });
        let handles = (0..threads)
            .map(|i| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("volcano-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("executor: failed to spawn worker thread")
            })
            .collect();
        WorkerPool { inner, handles }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Register a new fair-share tenant with the given weight
    /// (clamped into `1..=`[`MAX_TENANT_WEIGHT`]). Under saturating
    /// load the tenant's share of the pool's claims converges to
    /// `weight / Σ weights`. The entry persists until
    /// [`Self::remove_tenant`].
    pub fn register_tenant(&self, weight: u32) -> TenantId {
        // SYNC: Relaxed suffices — the counter only mints unique ids
        // (fetch_add is atomic at every ordering); the registration
        // itself is published by the scheduler-lock insert below.
        let id = self.inner.next_tenant.fetch_add(1, Ordering::Relaxed);
        let mut st = lock(&self.inner.sched);
        let pass = st.vnow;
        st.tenants.insert(
            id,
            TenantState {
                weight: weight.clamp(1, MAX_TENANT_WEIGHT),
                pass,
                queue: VecDeque::new(),
            },
        );
        id
    }

    /// Update a tenant's fair-share weight (clamped into
    /// `1..=`[`MAX_TENANT_WEIGHT`]). Takes effect from the next pick.
    pub fn set_tenant_weight(&self, tenant: TenantId, weight: u32) {
        let mut st = lock(&self.inner.sched);
        if let Some(t) = st.tenants.get_mut(&tenant) {
            t.weight = weight.clamp(1, MAX_TENANT_WEIGHT);
        }
    }

    /// A tenant's current weight, if it is registered (or has ever
    /// submitted work).
    pub fn tenant_weight(&self, tenant: TenantId) -> Option<u32> {
        lock(&self.inner.sched)
            .tenants
            .get(&tenant)
            .map(|t| t.weight)
    }

    /// Drop a tenant's scheduler entry. Refuses (returns `false`)
    /// while the tenant still has unretired batches queued, so a
    /// search must drain before its tenant can be reclaimed.
    pub fn remove_tenant(&self, tenant: TenantId) -> bool {
        remove_tenant_inner(&mut lock(&self.inner.sched), tenant)
    }

    /// Unretired batches currently queued across all tenants — the
    /// sampling source for the `volcanoml_pool_queue_depth` gauge
    /// (`serve` stats / `run --metrics`). Observation only: takes the
    /// scheduler lock like any submit, never mutates.
    pub fn queue_depth(&self) -> usize {
        lock(&self.inner.sched)
            .tenants
            .values()
            .map(|t| {
                t.queue
                    .iter()
                    .filter(|b| !b.latch.is_retired())
                    .count()
            })
            .sum()
    }

    /// Apply `f` to every item on the pool (as tenant 0), blocking
    /// until the batch completes; results come back in item order.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync,
    {
        self.submit(0, items, f).drain()
    }

    /// Start a batch on the pool **without blocking**: workers begin
    /// claiming items immediately while the caller keeps running
    /// (e.g. speculatively proposing the next round). Join with
    /// [`PoolBatch::drain`] to collect the results in item order; a
    /// worker panic is re-raised there.
    ///
    /// Crate-internal: the returned handle joins the batch when
    /// dropped, so the borrows captured by `f` and `items` always
    /// outlive the workers' use of them — but leaking the handle
    /// (`mem::forget`, a reference cycle) would void that argument,
    /// which is why this is not a public API. Callers inside the
    /// crate must drain (or drop) the handle in the same frame that
    /// owns the borrows; the public surface built on top
    /// (`Objective::evaluate_batch_overlapped`, `Executor::run`)
    /// always does.
    pub(crate) fn submit<'env, T, R, F>(
        &self, tenant: TenantId, items: &'env [T], f: F)
        -> PoolBatch<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
    {
        self.submit_cancellable(tenant, items, f, || false)
    }

    /// [`Self::submit`] with a cancellation predicate: every worker
    /// re-evaluates `cancel()` before claiming each item and stops
    /// claiming once it returns true, so a wall-clock deadline kills
    /// a batch mid-run instead of overshooting by the whole batch.
    /// Items in flight when the predicate flips still finish (an
    /// evaluation cannot be torn); unclaimed items are left as `None`
    /// — a suffix, since the claim cursor is monotone — and must be
    /// collected with [`PoolBatch::drain_partial`]. A cancelled
    /// batch retires from the scheduler, so its unclaimed slots go
    /// straight to co-tenant work.
    pub(crate) fn submit_cancellable<'env, T, R, F, C>(
        &self, tenant: TenantId, items: &'env [T], f: F, cancel: C)
        -> PoolBatch<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
        C: Fn() -> bool + Send + Sync + 'env,
    {
        let state = Arc::new(BatchState {
            items,
            f: Box::new(f),
            cancel: Box::new(cancel),
            next: AtomicUsize::new(0),
            slots: items.iter().map(|_| Mutex::new(None)).collect(),
            poisoned: AtomicBool::new(false),
            panic: Mutex::new(None),
        });
        let latch = Arc::new(Latch::new());
        let mut queued = false;
        if state.items.is_empty() {
            // nothing to claim: born retired, never queued
            latch.retire();
        } else {
            let task: Arc<dyn PoolTask + 'env> = state.clone();
            // SAFETY: the task borrows `items` and whatever `f` and
            // `cancel` capture for 'env; the scheduler queue is
            // 'static, so the lifetime is erased here. The
            // `PoolBatch` handle re-establishes the bound: its join
            // (in `drain_partial`, and failing that in Drop) first
            // waits until the batch is retired with zero in-flight
            // picks — every pick is counted on the latch under the
            // scheduler lock, *atomically with the not-retired check*
            // (one latch-lock hold in `pick_task`), before any worker
            // sees the task — and
            // then removes the queue's own Arc clone under that same
            // lock. When the join returns, neither a queue entry nor
            // a worker clone of this state survives: workers drop
            // their task Arc *before* posting the final latch
            // decrement, so not even drop glue for 'env data can run
            // on a worker afterwards. (Leaking the handle with
            // `mem::forget` would void this argument; the handle is
            // never exposed in a way that invites it.)
            let task: Arc<dyn PoolTask> = unsafe {
                std::mem::transmute::<Arc<dyn PoolTask + 'env>,
                                      Arc<dyn PoolTask>>(task)
            };
            let mut st = lock(&self.inner.sched);
            assert!(!st.shutdown, "executor: worker pool shut down");
            enqueue_batch(&mut st, tenant, QueuedBatch {
                task,
                latch: latch.clone(),
            });
            drop(st);
            self.inner.work_cv.notify_all();
            queued = true;
            crate::obs::event!("pool", "submit", "tenant" => tenant,
                               "items" => items.len());
        }
        PoolBatch {
            state,
            latch,
            inner: self.inner.clone(),
            tenant,
            queued,
            joined: false,
        }
    }

    /// Data-parallel map over the row ranges of `0..n`: split into
    /// contiguous chunks of at least `min_chunk` rows (about two per
    /// worker, so uneven per-row costs balance), run them on the pool
    /// **with the calling thread helping** through the same claim
    /// cursor, and return the per-chunk results in range order.
    /// Chunk boundaries never affect the concatenated output (each
    /// row's result is independent), so worker count stays a pure
    /// wall-clock knob for callers that splice the chunks back
    /// together — the contract the row-sharded FE apply relies on.
    ///
    /// The calling thread churns through the chunks itself while any
    /// free worker claims alongside it; the return then joins the
    /// batch (a no-op pick once the cursor is exhausted), so it never
    /// outlives the borrows of `f`.
    ///
    /// Crate-internal, and self-guarded against being entered *from*
    /// a pool worker: a nested blocking submission there could
    /// deadlock the pool (every worker waiting in `drain` on work
    /// only an idle worker could claim), so that case runs inline —
    /// [`Executor::map_ranges`] is the public surface and routes it
    /// inline one layer up already.
    pub(crate) fn map_ranges<R, F>(&self, tenant: TenantId, n: usize,
                                   min_chunk: usize, f: &F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Send + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if on_pool_thread() {
            return vec![f(0, n)];
        }
        let target = self.threads().max(1) * 2;
        let chunk = n.div_ceil(target).max(min_chunk.max(1));
        let ranges: Vec<(usize, usize)> = (0..n)
            .step_by(chunk)
            .map(|lo| (lo, (lo + chunk).min(n)))
            .collect();
        let batch = self.submit(tenant, &ranges, |&(lo, hi)| f(lo, hi));
        batch.help();
        batch.drain()
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.handles.len())
            .finish_non_exhaustive()
    }
}

/// Shared per-batch state: the items, the work closure, the claim
/// cursor and one result slot per item. Workers hold `Arc` clones
/// for exactly as long as they run picks of this batch.
struct BatchState<'env, T, R> {
    items: &'env [T],
    f: Box<dyn Fn(&T) -> R + Send + Sync + 'env>,
    /// Checked before every claim; true stops further claiming.
    cancel: Box<dyn Fn() -> bool + Send + Sync + 'env>,
    next: AtomicUsize,
    slots: Vec<Mutex<Option<R>>>,
    /// Set when an item panicked: stops further claims; the payload
    /// below re-raises at the join.
    poisoned: AtomicBool,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl<'env, T, R> PoolTask for BatchState<'env, T, R>
where
    T: Sync,
    R: Send,
{
    fn run_one(&self) -> Step {
        let res = catch_unwind(AssertUnwindSafe(|| {
            // per-item cancellation check *before* the claim: once
            // the predicate flips (deadline) or a panic poisoned the
            // batch, no further work starts on any worker
            if self.poisoned.load(Ordering::Acquire)
                || (self.cancel)()
            {
                return Step::Retired;
            }
            // SYNC: Relaxed suffices for the claim cursor — it only
            // partitions indices between claimants (fetch_add is
            // atomic at every ordering, so no index is handed out
            // twice); each result is published by its slot mutex and
            // batch completion by the latch, never by the cursor.
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.items.len() {
                return Step::Retired;
            }
            let out = (self.f)(&self.items[i]);
            *lock(&self.slots[i]) = Some(out);
            Step::Ran
        }));
        match res {
            Ok(step) => step,
            Err(p) => {
                let mut slot = lock(&self.panic);
                if slot.is_none() {
                    *slot = Some(p);
                }
                self.poisoned.store(true, Ordering::Release);
                Step::Retired
            }
        }
    }
}

impl<'env, T, R> BatchState<'env, T, R> {
    /// The helper's claim loop: claim and execute items through the
    /// same atomic cursor the workers use, until the batch is
    /// exhausted, cancelled or poisoned. A panic in `f` unwinds the
    /// caller directly (the helper *is* the submitting thread).
    /// Factored out so the loom models (`model::ModelBatch`) drive
    /// the production helper path, not a lookalike.
    fn claim_loop(&self) {
        loop {
            if self.poisoned.load(Ordering::Acquire)
                || (self.cancel)()
            {
                break;
            }
            // SYNC: Relaxed — same cursor argument as in `run_one`
            // above: the fetch_add only partitions indices between
            // claimants; results are published by the slot mutexes.
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.items.len() {
                break;
            }
            let out = (self.f)(&self.items[i]);
            *lock(&self.slots[i]) = Some(out);
        }
    }
}

/// An in-flight batch on a [`WorkerPool`], created by
/// [`WorkerPool::submit`]. [`drain`](PoolBatch::drain) joins the
/// batch and returns the results in item order (re-raising a worker
/// panic); dropping the handle joins without collecting, so the
/// batch can never outlive the data it borrows.
pub struct PoolBatch<'env, T, R> {
    state: Arc<BatchState<'env, T, R>>,
    latch: Arc<Latch>,
    inner: Arc<PoolInner>,
    tenant: TenantId,
    queued: bool,
    joined: bool,
}

impl<'env, T, R> PoolBatch<'env, T, R> {
    /// Run the batch's claim loop on the *calling* thread: claim and
    /// execute items through the same atomic cursor the workers use,
    /// until the batch is exhausted (or its cancellation predicate
    /// flips), then retire it from the scheduler. This is how a
    /// data-parallel map keeps making progress when every pool worker
    /// is busy — the submitter works its own batch alongside whatever
    /// workers pick it up. A panic in the work closure unwinds the
    /// caller directly, exactly like inline execution; the [`Drop`]
    /// join then waits out the in-flight workers.
    pub(crate) fn help(&self) {
        self.state.claim_loop();
        // exhausted (or cancelled): no pick can claim another item,
        // so retire here rather than waiting for a worker to discover
        // the empty cursor
        self.latch.retire();
    }

    /// Wait until no pick of this batch is or ever will be in
    /// flight, then unlink it from the scheduler queue. After this
    /// returns, no worker holds (or can ever reacquire) a reference
    /// to the batch's `'env` state.
    fn join(&mut self) {
        if self.joined {
            return;
        }
        self.latch.wait_done();
        if self.queued {
            let mut st = lock(&self.inner.sched);
            if let Some(t) = st.tenants.get_mut(&self.tenant) {
                t.queue
                    .retain(|b| !Arc::ptr_eq(&b.latch, &self.latch));
            }
        }
        self.joined = true;
    }

    /// Block until every worker has finished this batch, then return
    /// the results in item order. A panic inside the work closure is
    /// re-raised here — after the join, so the pool (and the batch's
    /// borrows) are never left dangling. Only valid for
    /// non-cancellable submissions (every slot filled); cancellable
    /// batches join with [`drain_partial`](Self::drain_partial).
    pub fn drain(self) -> Vec<R> {
        self.drain_partial()
            .into_iter()
            .map(|r| r.expect("executor: worker left a slot empty"))
            .collect()
    }

    /// Like [`drain`](Self::drain), but items never claimed because
    /// the batch's cancellation predicate flipped come back as
    /// `None`. The `None`s always form a suffix: the claim cursor is
    /// monotone, so everything before the first unclaimed item was
    /// claimed (and, once the join completes, finished).
    pub fn drain_partial(mut self) -> Vec<Option<R>> {
        let _span = crate::obs::span!("pool", "drain",
                                      "tenant" => self.tenant);
        self.join();
        if let Some(p) = lock(&self.state.panic).take() {
            resume_unwind(p);
        }
        self.state.slots.iter().map(|m| lock(m).take()).collect()
    }
}

impl<'env, T, R> std::fmt::Debug for PoolBatch<'env, T, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolBatch")
            .field("items", &self.state.items.len())
            .field("tenant", &self.tenant)
            .field("queued", &self.queued)
            .field("joined", &self.joined)
            .finish_non_exhaustive()
    }
}

impl<'env, T, R> Drop for PoolBatch<'env, T, R> {
    fn drop(&mut self) {
        // join (without collecting) so the workers' borrows of 'env
        // data end before the handle does — this runs during unwind
        // too, keeping an abandoned overlap window panic-safe
        self.join();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        lock(&self.inner.sched).shutdown = true;
        self.inner.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Executor facade used by the evaluator: serial inline execution for
/// one worker (or one item), a persistent [`WorkerPool`] otherwise.
/// Cloning shares the pool (and the tenant identity). An executor
/// built with [`Executor::shared`] submits all its work under its own
/// fair-share tenant on a pool it shares with other searches.
#[derive(Clone, Default)]
pub struct Executor {
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
    tenant: TenantId,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("workers", &self.workers.max(1))
            .field("persistent", &self.pool.is_some())
            .field("tenant", &self.tenant)
            .finish()
    }
}

impl Executor {
    /// Private pool with `workers` persistent threads; 0 is clamped
    /// to 1 (serial, no threads spawned). Submits as tenant 0.
    pub fn new(workers: usize) -> Executor {
        let workers = workers.max(1);
        let pool = if workers > 1 {
            Some(Arc::new(WorkerPool::new(workers)))
        } else {
            None
        };
        Executor { workers, pool, tenant: 0 }
    }

    /// An executor on a **shared** pool, registered as a fresh
    /// fair-share tenant with the given weight. Its `workers()` is
    /// the pool's thread count, so batch sizing derived from it is
    /// identical to a private pool of the same size — co-tenancy
    /// stays a pure wall-clock knob. Remove the tenant with
    /// [`WorkerPool::remove_tenant`] (via [`Self::tenant`]) once the
    /// search is done.
    pub fn shared(pool: &Arc<WorkerPool>, weight: u32) -> Executor {
        let tenant = pool.register_tenant(weight);
        Executor {
            workers: pool.threads(),
            pool: Some(pool.clone()),
            tenant,
        }
    }

    /// The strictly sequential executor (the pre-parallel behaviour).
    pub fn serial() -> Executor {
        Executor::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers.max(1)
    }

    /// The fair-share tenant this executor submits under (0 unless
    /// built with [`Self::shared`]).
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    /// Apply `f` to every item, returning results in item order.
    ///
    /// With one worker (or at most one item) this runs inline on the
    /// caller's thread — byte-for-byte the serial evaluation path.
    /// Otherwise the batch runs on the persistent pool.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync,
    {
        self.submit(items, f).drain()
    }

    /// Data-parallel map over the row ranges of `0..n` — the
    /// primitive behind the row-sharded FE apply. Returns per-chunk
    /// results in range order; callers concatenate. Runs inline (one
    /// `f(0, n)` call) when the executor is serial, when `n` does not
    /// clear `min_chunk`, or when the calling thread is itself a pool
    /// worker (an evaluation already running on the pool — nesting a
    /// blocking batch there could deadlock, and the pool is saturated
    /// by eval-level parallelism anyway; see [`on_pool_thread`]).
    /// Otherwise the chunks run on the pool with this thread helping
    /// ([`WorkerPool::map_ranges`]). Chunking never changes the
    /// concatenated output, so every path is bit-identical.
    pub fn map_ranges<R, F>(&self, n: usize, min_chunk: usize, f: F)
        -> Vec<R>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Send + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        match &self.pool {
            Some(pool) if n > min_chunk.max(1) && !on_pool_thread() => {
                pool.map_ranges(self.tenant, n, min_chunk, &f)
            }
            _ => vec![f(0, n)],
        }
    }

    /// Start a batch **without blocking** and return a handle to join
    /// it later — the primitive behind the async pipeline depth: the
    /// caller keeps the submitting thread busy (speculative proposal
    /// of the next round) while the pool evaluates, then calls
    /// [`Submitted::drain`].
    ///
    /// With one worker (or at most one item) nothing is scheduled:
    /// the work is deferred and runs inline on the caller's thread at
    /// `drain`, *after* any overlap work — so the relative order of
    /// speculation and evaluation is the same for every worker count
    /// (speculation never sees the batch's results), and a panicking
    /// evaluation always surfaces at the join.
    ///
    /// Crate-internal (see [`WorkerPool::submit`] for why): the
    /// handle must be drained or dropped in the frame that owns the
    /// borrows, never leaked.
    pub(crate) fn submit<'env, T, R, F>(&self, items: &'env [T], f: F)
        -> Submitted<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
    {
        self.submit_cancellable(items, f, || false)
    }

    /// [`Self::submit`] with a per-item cancellation predicate:
    /// workers (or the inline path, item by item at the drain) stop
    /// starting new items once `cancel()` returns true, leaving the
    /// unstarted suffix as `None` in
    /// [`Submitted::drain_partial`]'s output. This is how a
    /// wall-clock deadline kills a super-batch mid-run instead of
    /// overshooting by the full batch.
    pub(crate) fn submit_cancellable<'env, T, R, F, C>(
        &self, items: &'env [T], f: F, cancel: C)
        -> Submitted<'env, T, R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Send + Sync + 'env,
        C: Fn() -> bool + Send + Sync + 'env,
    {
        match &self.pool {
            Some(pool) if items.len() > 1 => {
                Submitted::Pool(pool.submit_cancellable(
                    self.tenant, items, f, cancel))
            }
            _ => Submitted::Lazy {
                items,
                f: Box::new(f),
                cancel: Box::new(cancel),
            },
        }
    }
}

/// A batch issued through [`Executor::submit`]: either truly in
/// flight on the pool, or deferred for inline execution at the join
/// (serial executor / singleton batches).
pub enum Submitted<'env, T, R> {
    /// Deferred inline execution: nothing has run yet; `drain`
    /// evaluates on the caller's thread.
    Lazy {
        items: &'env [T],
        f: Box<dyn Fn(&T) -> R + Send + Sync + 'env>,
        cancel: Box<dyn Fn() -> bool + Send + Sync + 'env>,
    },
    /// In flight on the persistent pool.
    Pool(PoolBatch<'env, T, R>),
}

impl<'env, T, R> std::fmt::Debug for Submitted<'env, T, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Submitted::Lazy { items, .. } => f
                .debug_struct("Submitted::Lazy")
                .field("items", &items.len())
                .finish_non_exhaustive(),
            Submitted::Pool(batch) => {
                f.debug_tuple("Submitted::Pool").field(batch).finish()
            }
        }
    }
}

impl<'env, T, R> Submitted<'env, T, R> {
    /// Join the batch: block for (or inline-run) the evaluations and
    /// return the results in item order. Worker panics re-raise here.
    /// Only valid for non-cancellable submissions; cancellable ones
    /// join with [`drain_partial`](Self::drain_partial).
    pub fn drain(self) -> Vec<R> {
        self.drain_partial()
            .into_iter()
            .map(|r| r.expect("executor: item cancelled in a \
                               non-cancellable batch"))
            .collect()
    }

    /// Join the batch, with items never started (the cancellation
    /// predicate flipped first) as `None` — always a suffix of the
    /// output, for the pool and the inline path alike.
    pub fn drain_partial(self) -> Vec<Option<R>> {
        match self {
            Submitted::Lazy { items, f, cancel } => {
                let mut out: Vec<Option<R>> =
                    Vec::with_capacity(items.len());
                let mut dead = false;
                for t in items {
                    // once the predicate flips the rest of the batch
                    // is an unstarted suffix, same as on the pool
                    dead = dead || cancel();
                    out.push(if dead { None } else { Some(f(t)) });
                }
                out
            }
            Submitted::Pool(batch) => batch.drain_partial(),
        }
    }
}

/// Bounded model-checking surface for `rust/tests/loom_models.rs`
/// (`--features loom` only, hidden from docs): the scheduler's
/// *production* internals — [`Latch`], [`pick_task`],
/// [`enqueue_batch`], [`remove_tenant_inner`], the [`BatchState`]
/// claim cursor — re-packaged at a granularity a model checker can
/// explore exhaustively (one pick, one claim, one retire per call),
/// without spawning the full worker pool or widening the public API.
/// Every entry point here calls straight into the code above; none of
/// it is reimplemented.
#[cfg(feature = "loom")]
#[doc(hidden)]
pub mod model {
    use super::*;

    /// A tiny claimable task with per-slot claim accounting and a
    /// liveness flag: models assert both single-claim (each index
    /// handed out once) and no-use-after-join (the PR-6 UAF shape —
    /// `kill()` poisons the probe right after the handle-side join,
    /// so any pick that outlived the join trips the assert in
    /// `run_one`).
    pub struct Probe {
        n: usize,
        cursor: AtomicUsize,
        claims: Vec<AtomicUsize>,
        alive: AtomicBool,
    }

    impl Probe {
        pub fn new(n: usize) -> Arc<Probe> {
            Arc::new(Probe {
                n,
                cursor: AtomicUsize::new(0),
                claims: (0..n).map(|_| AtomicUsize::new(0)).collect(),
                alive: AtomicBool::new(true),
            })
        }

        /// Drive the claim cursor to exhaustion on the calling
        /// thread — the helper's role in [`PoolBatch::help`].
        pub fn help(&self) {
            while self.run_one() == Step::Ran {}
        }

        /// How many items have been claimed exactly once.
        pub fn claimed(&self) -> usize {
            self.claims
                .iter()
                .filter(|c| c.load(Ordering::SeqCst) == 1)
                .count()
        }

        /// Mark the batch state dead, as if the `'env` borrow behind
        /// it ended. Call only after the handle-side join; any later
        /// `run_one` is a use-after-free in the real executor and
        /// asserts here.
        pub fn kill(&self) {
            self.alive.store(false, Ordering::SeqCst);
        }
    }

    impl PoolTask for Probe {
        fn run_one(&self) -> Step {
            assert!(self.alive.load(Ordering::SeqCst),
                    "model: run_one on a dead probe — a pick \
                     outlived the handle's join (UAF)");
            let i = self.cursor.fetch_add(1, Ordering::SeqCst);
            if i >= self.n {
                return Step::Retired;
            }
            let prev = self.claims[i].fetch_add(1, Ordering::SeqCst);
            assert_eq!(prev, 0, "model: item {i} claimed twice");
            Step::Ran
        }
    }

    impl std::fmt::Debug for Probe {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
            -> std::fmt::Result {
            f.debug_struct("Probe")
                .field("n", &self.n)
                .field("claimed", &self.claimed())
                .finish_non_exhaustive()
        }
    }

    /// Shareable handle on a queued batch's completion [`Latch`].
    #[derive(Clone)]
    pub struct ModelLatch(Arc<Latch>);

    impl ModelLatch {
        pub fn retire(&self) {
            self.0.retire();
        }

        pub fn wait_done(&self) {
            self.0.wait_done();
        }

        pub fn is_retired(&self) -> bool {
            self.0.is_retired()
        }
    }

    impl std::fmt::Debug for ModelLatch {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
            -> std::fmt::Result {
            f.debug_struct("ModelLatch")
                .field("retired", &self.is_retired())
                .finish_non_exhaustive()
        }
    }

    /// One pick handed out by [`MiniSched::pick`]; [`run`] drives it
    /// through one worker-loop iteration.
    ///
    /// [`run`]: PickedModel::run
    pub struct PickedModel {
        task: Arc<dyn PoolTask>,
        latch: Arc<Latch>,
    }

    impl PickedModel {
        /// One worker-loop iteration, exactly as [`worker_loop`]
        /// performs it: run one claim, drop the task clone *before*
        /// posting, post the step on the latch.
        pub fn run(self) {
            let PickedModel { task, latch } = self;
            let step = task.run_one();
            drop(task);
            latch.post(step);
        }
    }

    impl std::fmt::Debug for PickedModel {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
            -> std::fmt::Result {
            f.debug_struct("PickedModel").finish_non_exhaustive()
        }
    }

    /// The production scheduler state behind a minimal facade:
    /// [`SchedState`] driven through the real [`pick_task`],
    /// [`enqueue_batch`] and [`remove_tenant_inner`], one transition
    /// per call so loom can permute them against each other.
    pub struct MiniSched {
        st: Mutex<SchedState>,
    }

    impl MiniSched {
        pub fn new() -> MiniSched {
            MiniSched {
                st: Mutex::new(SchedState {
                    shutdown: false,
                    vnow: 0,
                    tenants: HashMap::new(),
                }),
            }
        }

        /// Register `id` with the given weight (clamped like
        /// [`WorkerPool::register_tenant`]).
        pub fn add_tenant(&self, id: TenantId, weight: u32) {
            let mut st = lock(&self.st);
            let pass = st.vnow;
            st.tenants.insert(id, TenantState {
                weight: weight.clamp(1, MAX_TENANT_WEIGHT),
                pass,
                queue: VecDeque::new(),
            });
        }

        /// Re-weight `id` (clamped), as
        /// [`WorkerPool::set_tenant_weight`] does.
        pub fn set_weight(&self, id: TenantId, weight: u32) {
            if let Some(t) = lock(&self.st).tenants.get_mut(&id) {
                t.weight = weight.clamp(1, MAX_TENANT_WEIGHT);
            }
        }

        /// The tenant's stride virtual time, if registered.
        pub fn pass_of(&self, id: TenantId) -> Option<u64> {
            lock(&self.st).tenants.get(&id).map(|t| t.pass)
        }

        /// Queue a probe on a tenant through the production
        /// [`enqueue_batch`]; the returned latch is the handle's view
        /// of the batch.
        pub fn enqueue(&self, tenant: TenantId, probe: &Arc<Probe>)
            -> ModelLatch {
            let latch = Arc::new(Latch::new());
            let task: Arc<dyn PoolTask> = probe.clone();
            enqueue_batch(&mut lock(&self.st), tenant, QueuedBatch {
                task,
                latch: latch.clone(),
            });
            ModelLatch(latch)
        }

        /// One worker pick through the production [`pick_task`]
        /// (retired-front pruning, min-pass selection, pick counted
        /// on the latch under this one scheduler-lock hold).
        pub fn pick(&self) -> Option<PickedModel> {
            pick_task(&mut lock(&self.st))
                .map(|(task, latch, _tenant)| PickedModel {
                    task,
                    latch,
                })
        }

        /// The handle-side unlink — the tail of [`PoolBatch::join`]:
        /// after `wait_done`, drop the queue's own clone of the
        /// batch.
        pub fn unlink(&self, tenant: TenantId, latch: &ModelLatch) {
            let mut st = lock(&self.st);
            if let Some(t) = st.tenants.get_mut(&tenant) {
                t.queue
                    .retain(|b| !Arc::ptr_eq(&b.latch, &latch.0));
            }
        }

        /// [`WorkerPool::remove_tenant`], verbatim (shared helper).
        pub fn remove_tenant(&self, tenant: TenantId) -> bool {
            remove_tenant_inner(&mut lock(&self.st), tenant)
        }
    }

    impl Default for MiniSched {
        fn default() -> MiniSched {
            MiniSched::new()
        }
    }

    impl std::fmt::Debug for MiniSched {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
            -> std::fmt::Result {
            f.debug_struct("MiniSched").finish_non_exhaustive()
        }
    }

    /// Items backing [`ModelBatch`]: `'static` so the real
    /// [`BatchState`] can be driven without the lifetime transmute.
    static MB_ITEMS: [usize; 2] = [7, 9];

    /// The real [`BatchState`] — cursor, slots, poison flag — over a
    /// fixed `'static` item set, so models of helper-vs-worker claim
    /// races execute the production `run_one`/`claim_loop` code.
    pub struct ModelBatch {
        state: Arc<BatchState<'static, usize, usize>>,
    }

    impl ModelBatch {
        pub fn new() -> ModelBatch {
            ModelBatch {
                state: Arc::new(BatchState {
                    items: &MB_ITEMS[..],
                    f: Box::new(|&x| x * 2),
                    cancel: Box::new(|| false),
                    next: AtomicUsize::new(0),
                    slots: MB_ITEMS
                        .iter()
                        .map(|_| Mutex::new(None))
                        .collect(),
                    poisoned: AtomicBool::new(false),
                    panic: Mutex::new(None),
                }),
            }
        }

        /// One worker-side claim through the production
        /// [`BatchState::run_one`]; `true` while items remain.
        pub fn run_one(&self) -> bool {
            PoolTask::run_one(&*self.state) == Step::Ran
        }

        /// The helper's production claim loop
        /// ([`BatchState::claim_loop`], i.e. [`PoolBatch::help`]
        /// minus the scheduler retire).
        pub fn help(&self) {
            self.state.claim_loop();
        }

        /// Take the result slots, in item order.
        pub fn results(&self) -> Vec<Option<usize>> {
            self.state.slots.iter().map(|m| lock(m).take()).collect()
        }

        /// The expected fully-claimed [`results`](Self::results).
        pub fn expected() -> Vec<Option<usize>> {
            MB_ITEMS.iter().map(|&x| Some(x * 2)).collect()
        }
    }

    impl Clone for ModelBatch {
        fn clone(&self) -> ModelBatch {
            ModelBatch { state: self.state.clone() }
        }
    }

    impl Default for ModelBatch {
        fn default() -> ModelBatch {
            ModelBatch::new()
        }
    }

    impl std::fmt::Debug for ModelBatch {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
            -> std::fmt::Result {
            f.debug_struct("ModelBatch")
                .field("next",
                       &self.state.next.load(Ordering::SeqCst))
                .finish_non_exhaustive()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;
    use std::time::{Duration, Instant};

    #[test]
    fn results_arrive_in_item_order() {
        for workers in [1, 2, 4, 7] {
            let ex = Executor::new(workers);
            let items: Vec<usize> = (0..40).collect();
            let out = ex.run(&items, |&i| i * 3);
            assert_eq!(out, (0..40).map(|i| i * 3).collect::<Vec<_>>(),
                       "workers={workers}");
        }
    }

    #[test]
    fn serial_and_parallel_agree_bitwise() {
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.37).collect();
        let f = |x: &f64| (x.sin() * 1e6).cos() / (1.0 + x.abs());
        let a = Executor::serial().run(&items, f);
        let b = Executor::new(4).run(&items, f);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock overlap bound")]
    fn pool_actually_overlaps_work() {
        // 8 sleeps of 20ms: serial floor is 160ms; two workers should
        // land well under it even on a loaded box.
        let items: Vec<u32> = (0..8).collect();
        let ex = Executor::new(4);
        let t0 = Instant::now();
        ex.run(&items, |_| {
            std::thread::sleep(Duration::from_millis(20));
        });
        let dt = t0.elapsed();
        assert!(dt < Duration::from_millis(140),
                "no overlap observed: {dt:?}");
    }

    #[test]
    fn zero_workers_clamps_to_serial() {
        let ex = Executor::new(0);
        assert_eq!(ex.workers(), 1);
        assert_eq!(ex.run(&[1, 2, 3], |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let out: Vec<i32> = Executor::new(4).run(&[], |x: &i32| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let out = Executor::new(16).run(&[5, 6], |&x| x * x);
        assert_eq!(out, vec![25, 36]);
    }

    /// Force both pool threads to participate: each of the two items
    /// blocks until two distinct claimants have arrived, so a single
    /// thread can never clear the batch alone.
    fn both_worker_ids(ex: &Executor) -> HashSet<ThreadId> {
        let arrived = AtomicUsize::new(0);
        let ids = ex.run(&[0usize, 1usize], |_| {
            arrived.fetch_add(1, Ordering::SeqCst);
            let t0 = Instant::now();
            while arrived.load(Ordering::SeqCst) < 2 {
                assert!(t0.elapsed() < Duration::from_secs(10),
                        "second worker never arrived");
                std::hint::spin_loop();
            }
            std::thread::current().id()
        });
        ids.into_iter().collect()
    }

    #[test]
    #[cfg_attr(miri, ignore = "spin-waits on two live workers")]
    fn pool_threads_persist_across_batches() {
        // the whole point of the persistent pool: consecutive batches
        // run on the *same* threads, so per-thread caches survive
        let ex = Executor::new(2);
        let first = both_worker_ids(&ex);
        assert_eq!(first.len(), 2, "both workers claim one item each");
        assert!(!first.contains(&std::thread::current().id()),
                "work runs on pool threads, not the caller");
        for _ in 0..3 {
            let again = both_worker_ids(&ex);
            assert_eq!(first, again,
                       "batch ran on fresh threads: {again:?} vs \
                        {first:?}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "spin-waits on two live workers")]
    fn cloned_executor_shares_the_pool() {
        let ex = Executor::new(2);
        let clone = ex.clone();
        let a = both_worker_ids(&ex);
        let b = both_worker_ids(&clone);
        assert_eq!(a, b, "clone must reuse the same pool threads");
    }

    #[test]
    #[cfg_attr(miri, ignore = "timing-dependent overlap window")]
    fn submit_runs_concurrently_with_caller_work() {
        // Ordering, not wall-clock (robust on loaded CI boxes):
        // submit must return before the 30ms jobs can possibly have
        // all finished, and while the caller then works, the pool
        // must make progress on its own — both observable through
        // the completion counter without any tight timing bound.
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..4).collect();
        let hits = AtomicUsize::new(0);
        let pending = ex.submit(&items, |_| {
            std::thread::sleep(Duration::from_millis(30));
            hits.fetch_add(1, Ordering::SeqCst);
        });
        // submit did not block: a 30ms job cannot have completed in
        // the microseconds since
        assert!(hits.load(Ordering::SeqCst) < items.len(),
                "submit ran the whole batch before returning");
        // the pool works while the caller does: wait out (generously)
        // one job's length of caller-side work and expect progress
        let t0 = Instant::now();
        while hits.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10),
                    "pool made no progress during the overlap window");
            std::thread::sleep(Duration::from_millis(5));
        }
        pending.drain();
        assert_eq!(hits.load(Ordering::SeqCst), items.len());
    }

    #[test]
    fn submit_serial_defers_work_until_drain() {
        let ex = Executor::serial();
        let ran = AtomicUsize::new(0);
        let items = [1, 2, 3];
        let pending = ex.submit(&items, |&x| {
            ran.fetch_add(1, Ordering::SeqCst);
            x * 2
        });
        assert_eq!(ran.load(Ordering::SeqCst), 0,
                   "lazy submit must not evaluate before drain");
        assert_eq!(pending.drain(), vec![2, 4, 6]);
        assert_eq!(ran.load(Ordering::SeqCst), 3);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spin-waits on two live workers")]
    fn submit_panic_propagates_at_drain_and_pool_survives() {
        for workers in [1, 2] {
            let ex = Executor::new(workers);
            let before = if workers == 2 {
                Some(both_worker_ids(&ex))
            } else {
                None
            };
            let items = [0, 1, 2, 3];
            let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
                let pending = ex.submit(&items, |&i: &i32| {
                    if i == 2 {
                        panic!("boom in flight");
                    }
                    i
                });
                // overlap window: the panic must wait for the join
                let _ = std::hint::black_box(7 * 6);
                pending.drain()
            }));
            assert!(caught.is_err(),
                    "workers={workers}: panic must surface at drain");
            let out = ex.run(&[1, 2, 3], |&x| x + 1);
            assert_eq!(out, vec![2, 3, 4], "workers={workers}");
            // thread identity is pinned across the panic: the same
            // pool threads serve the post-panic batches
            if let Some(before) = before {
                assert_eq!(before, both_worker_ids(&ex),
                           "pool threads changed across the panic");
            }
        }
    }

    #[test]
    fn dropped_submission_joins_without_collecting() {
        // dropping the handle (e.g. during an unwind of the caller)
        // must wait out the in-flight jobs, then leave the pool usable
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..6).collect();
        let hits = AtomicUsize::new(0);
        {
            let _pending = ex.submit(&items, |_| {
                std::thread::sleep(Duration::from_millis(5));
                hits.fetch_add(1, Ordering::SeqCst);
            });
            // handle dropped here, joining the batch
        }
        assert_eq!(hits.load(Ordering::SeqCst), 6);
        assert_eq!(ex.run(&[9], |&x| x), vec![9]);
    }

    #[test]
    fn cancelled_batch_returns_none_suffix_and_pool_survives() {
        // a predicate that flips after k completions must leave the
        // tail unclaimed (None), never tear an in-flight item, and
        // keep the pool usable — on the pool and the inline path
        for workers in [1usize, 3] {
            let ex = Executor::new(workers);
            let items: Vec<u32> = (0..12).collect();
            let started = AtomicUsize::new(0);
            let out = ex
                .submit_cancellable(
                    &items,
                    |&x| {
                        started.fetch_add(1, Ordering::SeqCst);
                        x * 2
                    },
                    || started.load(Ordering::SeqCst) >= 4,
                )
                .drain_partial();
            assert_eq!(out.len(), 12, "workers={workers}");
            // completed prefix, cancelled suffix — no gaps
            let cut = out.iter().position(|r| r.is_none())
                .expect("cancellation must leave an unstarted tail");
            assert!(cut >= 4 && cut < 12, "workers={workers}: {cut}");
            for (i, r) in out.iter().enumerate() {
                if i < cut {
                    assert_eq!(*r, Some(items[i] * 2),
                               "workers={workers}");
                } else {
                    assert!(r.is_none(),
                            "workers={workers}: gap at {i}");
                }
            }
            // pool unaffected
            assert_eq!(ex.run(&[7, 8], |&x| x + 1), vec![8, 9]);
        }
    }

    #[test]
    fn never_cancelled_batch_fills_every_slot() {
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..9).collect();
        let out = ex
            .submit_cancellable(&items, |&x| x + 1, || false)
            .drain_partial();
        assert_eq!(out, (1..=9).map(Some).collect::<Vec<_>>());
    }

    #[test]
    fn map_ranges_concatenation_matches_serial_bitwise() {
        // per-row results spliced from chunks must equal the serial
        // single-range output byte for byte, for any worker count
        // (a shrunk n keeps this claim checkable under miri)
        let n = if cfg!(miri) { 200usize } else { 10_000usize };
        let per_row = |i: usize| ((i as f64).sin() * 1e6).cos() as f32;
        let run = |ex: &Executor, min_chunk: usize| -> Vec<f32> {
            let parts = ex.map_ranges(n, min_chunk, |lo, hi| {
                (lo..hi).map(per_row).collect::<Vec<f32>>()
            });
            parts.into_iter().flatten().collect()
        };
        let serial = run(&Executor::serial(), 1);
        assert_eq!(serial.len(), n);
        for workers in [2usize, 4, 7] {
            let ex = Executor::new(workers);
            for min_chunk in [1usize, 64, 5000, 20_000] {
                let out = run(&ex, min_chunk);
                assert_eq!(out.len(), n,
                           "workers={workers} min_chunk={min_chunk}");
                for (a, b) in serial.iter().zip(&out) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "workers={workers} \
                                min_chunk={min_chunk}");
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "asserts real multi-thread claiming")]
    fn map_ranges_actually_runs_on_the_pool() {
        // with a pool and a small min_chunk, more than one distinct
        // thread participates (the caller helps, workers claim)
        let ex = Executor::new(4);
        let ids = Mutex::new(HashSet::new());
        let parts = ex.map_ranges(64, 1, |lo, hi| {
            lock(&ids).insert(std::thread::current().id());
            // slow the chunks down so workers have time to claim
            std::thread::sleep(Duration::from_millis(5));
            hi - lo
        });
        assert_eq!(parts.iter().sum::<usize>(), 64);
        assert!(lock(&ids).len() >= 2,
                "expected pool participation, got {} thread(s)",
                lock(&ids).len());
    }

    #[test]
    fn map_ranges_from_a_pool_worker_runs_inline() {
        // a nested data-parallel map issued from inside a pool job
        // must not submit to the pool (deadlock risk): it runs inline
        // on the worker, as one chunk, and the outer batch completes
        let ex = Executor::new(2);
        let ex2 = ex.clone();
        let out = ex.run(&[10usize, 20, 30, 40], |&n| {
            assert!(on_pool_thread());
            let parts = ex2.map_ranges(n, 1, |lo, hi| hi - lo);
            assert_eq!(parts.len(), 1,
                       "nested map must run as one inline chunk");
            parts.iter().sum::<usize>()
        });
        assert_eq!(out, vec![10, 20, 30, 40]);
        // and the caller's thread is not a pool worker
        assert!(!on_pool_thread());
    }

    #[test]
    fn map_ranges_below_min_chunk_stays_inline() {
        let ex = Executor::new(4);
        let main_id = std::thread::current().id();
        let parts = ex.map_ranges(100, 512, |lo, hi| {
            assert_eq!(std::thread::current().id(), main_id);
            (lo, hi)
        });
        assert_eq!(parts, vec![(0, 100)]);
        let empty: Vec<(usize, usize)> =
            ex.map_ranges(0, 1, |lo, hi| (lo, hi));
        assert!(empty.is_empty());
    }

    #[test]
    fn map_ranges_issued_against_a_busy_pool_still_completes() {
        // a data-parallel map submitted while the workers are mid-way
        // through another batch completes correctly: the helping
        // caller churns through the chunks, and the queued batch is
        // joined once the workers free up
        let ex = Executor::new(2);
        let items: Vec<u32> = (0..4).collect();
        let pending = ex.submit(&items, |_| {
            std::thread::sleep(Duration::from_millis(30));
        });
        let parts = ex.map_ranges(1000, 1, |lo, hi| hi - lo);
        assert_eq!(parts.iter().sum::<usize>(), 1000);
        pending.drain();
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let ex = Executor::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.run(&[0, 1, 2, 3], |&i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            });
        }));
        assert!(caught.is_err(), "panic must reach the caller");
        // the pool is still usable afterwards
        let out = ex.run(&[1, 2, 3, 4], |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5]);
    }

    #[test]
    fn tenants_register_and_remove() {
        let pool = WorkerPool::new(2);
        let a = pool.register_tenant(0); // weight clamps to 1
        let b = pool.register_tenant(4);
        assert_ne!(a, b);
        assert_ne!(a, 0, "explicit tenants never collide with the \
                          implicit default");
        assert_eq!(pool.tenant_weight(a), Some(1));
        assert_eq!(pool.tenant_weight(b), Some(4));
        pool.set_tenant_weight(b, 0);
        assert_eq!(pool.tenant_weight(b), Some(1));
        assert!(pool.remove_tenant(a));
        assert!(!pool.remove_tenant(a), "double remove must refuse");
        assert_eq!(pool.tenant_weight(a), None);
        assert!(pool.remove_tenant(b));
    }

    #[test]
    fn oversized_weights_clamp_and_stride_never_zeroes() {
        // STRIDE_ONE / weight truncates to 0 past 2^20; a zero stride
        // would pin the tenant at min-pass forever and starve every
        // co-tenant, so both entry points clamp and stride() floors
        assert_eq!(stride(0), STRIDE_ONE);
        assert_eq!(stride(MAX_TENANT_WEIGHT), 1);
        assert!(stride(u32::MAX) >= 1);
        let pool = WorkerPool::new(1);
        let t = pool.register_tenant(u32::MAX);
        assert_eq!(pool.tenant_weight(t), Some(MAX_TENANT_WEIGHT));
        pool.set_tenant_weight(t, 0);
        assert_eq!(pool.tenant_weight(t), Some(1));
        pool.set_tenant_weight(t, u32::MAX);
        assert_eq!(pool.tenant_weight(t), Some(MAX_TENANT_WEIGHT));
        assert!(pool.remove_tenant(t));
    }

    #[test]
    fn helper_retirement_races_worker_picks_safely() {
        // regression for the pick/retire TOCTOU: `help()` retires the
        // batch from the submitting thread the instant the cursor is
        // exhausted, racing workers mid-pick. Tiny fast batches in a
        // tight loop maximise the window in which a stale pick could
        // outlive the drain; the pick count and the retired check now
        // share one latch-lock hold, so every iteration must join
        // cleanly with all slots accounted for.
        let ex = Executor::new(4);
        let rounds = if cfg!(miri) { 20 } else { 300 };
        for round in 0..rounds {
            let parts = ex.map_ranges(8, 1, |lo, hi| hi - lo);
            assert_eq!(parts.iter().sum::<usize>(), 8,
                       "round {round}");
        }
    }

    #[test]
    fn shared_executors_serve_concurrent_tenants_in_order() {
        let pool = Arc::new(WorkerPool::new(2));
        let a = Executor::shared(&pool, 1);
        let b = Executor::shared(&pool, 3);
        assert_eq!(a.workers(), 2, "shared executor reports the \
                                    pool's thread count");
        assert_ne!(a.tenant(), b.tenant());
        std::thread::scope(|s| {
            let ra = s.spawn(|| a.run(&[1, 2, 3, 4], |&x| x * 2));
            let rb = s.spawn(|| b.run(&[5, 6, 7], |&x| x + 1));
            assert_eq!(ra.join().unwrap(), vec![2, 4, 6, 8]);
            assert_eq!(rb.join().unwrap(), vec![6, 7, 8]);
        });
        // drained tenants can be reclaimed
        assert!(pool.remove_tenant(a.tenant()));
        assert!(pool.remove_tenant(b.tenant()));
        // ...and the pool still serves the default tenant
        assert_eq!(pool.run(&[1, 2], |&x: &i32| x * 10),
                   vec![10, 20]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "100k-item deadline-death race")]
    fn a_dying_tenants_unclaimed_slots_go_to_co_tenants() {
        // tenant A's batch is cancelled mid-run (the deadline-death
        // shape); tenant B's batch must still complete fully, and the
        // pool must be reusable — A's unclaimed slots never wedge the
        // scheduler
        let pool = Arc::new(WorkerPool::new(2));
        let a = Executor::shared(&pool, 1);
        let b = Executor::shared(&pool, 1);
        let stop = AtomicBool::new(false);
        let a_items: Vec<u32> = (0..100_000).collect();
        let b_items: Vec<u32> = (0..64).collect();
        std::thread::scope(|s| {
            let ha = s.spawn(|| {
                a.submit_cancellable(
                    &a_items,
                    |&x| {
                        std::thread::sleep(Duration::from_millis(1));
                        x
                    },
                    || stop.load(Ordering::SeqCst),
                )
                .drain_partial()
            });
            // let A get going, then kill it mid-batch
            std::thread::sleep(Duration::from_millis(10));
            stop.store(true, Ordering::SeqCst);
            let rb = s.spawn(|| b.run(&b_items, |&x| x + 1));
            assert_eq!(rb.join().unwrap(),
                       (1..=64).collect::<Vec<u32>>());
            let ra = ha.join().unwrap();
            let claimed = ra.iter().filter(|r| r.is_some()).count();
            assert!(claimed < a_items.len(),
                    "cancellation never bit: {claimed} claims");
        });
        assert!(pool.remove_tenant(a.tenant()));
        assert!(pool.remove_tenant(b.tenant()));
    }

    #[test]
    #[cfg_attr(miri, ignore = "spin-gated 1200-claim window")]
    fn weighted_tenants_split_claims_proportionally() {
        // two saturating tenants with weights 1 and 3 on one worker:
        // with a single worker the pick sequence is strictly
        // sequential, so the stride ratio inside any window is exact
        // up to rounding. Items gate on `go` so both queues are
        // populated before the first claim can count.
        let pool = Arc::new(WorkerPool::new(1));
        let a = Executor::shared(&pool, 1);
        let b = Executor::shared(&pool, 3);
        let counts = [AtomicUsize::new(0), AtomicUsize::new(0)];
        let total = AtomicUsize::new(0);
        let go = AtomicBool::new(false);
        const WINDOW: usize = 400;
        let items: Vec<usize> = (0..600).collect();
        let tick = |idx: usize| {
            while !go.load(Ordering::Acquire) {
                std::hint::spin_loop();
            }
            let t = total.fetch_add(1, Ordering::SeqCst);
            if t < WINDOW {
                counts[idx].fetch_add(1, Ordering::SeqCst);
            }
        };
        let sa = a.submit(&items, |_| tick(0));
        let sb = b.submit(&items, |_| tick(1));
        go.store(true, Ordering::Release);
        sa.drain();
        sb.drain();
        let ca = counts[0].load(Ordering::SeqCst) as f64;
        let cb = counts[1].load(Ordering::SeqCst) as f64;
        // expected 100 : 300, exact up to the single item the worker
        // may have claimed before `go`
        assert!(ca > 0.0 && cb > 0.0, "both tenants must progress");
        let ratio = cb / ca;
        assert!(ratio > 2.0 && ratio < 4.5,
                "weight-3 tenant should claim ~3x in the window: \
                 {cb} vs {ca}");
    }
}
