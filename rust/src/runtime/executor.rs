//! Persistent worker-pool executor for batched candidate evaluation.
//!
//! The Volcano-style `do_next!` pull proposes a *batch* of candidate
//! configurations per pull (and, with cross-leaf super-batching, a
//! whole elimination round of pulls); this executor fans each batch
//! out across a pool of **long-lived** worker threads and returns the
//! results in request order. Determinism contract: the executor never
//! reorders results — `workers = 1` and `workers = N` produce
//! identical output for the same input batch, so worker count is
//! purely a performance knob (the *batch size* is what changes search
//! semantics).
//!
//! The pool is spawned once (per search, via
//! `PipelineEvaluator::with_workers`) and its threads are reused
//! across every batch, so per-thread state — notably the PJRT
//! executable caches in `runtime::mod`, which live in thread-locals —
//! is amortised over the whole search instead of being rebuilt for
//! every batch as the previous `std::thread::scope`-per-batch design
//! did. Work is claimed through an atomic cursor so uneven
//! per-candidate costs balance across the pool, and a panic inside
//! the work closure propagates to the submitting thread once the
//! batch joins, exactly like the serial path.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// A fixed-size pool of persistent worker threads fed over a shared
/// channel. Threads are spawned at construction and live until the
/// pool is dropped; every [`WorkerPool::run`] reuses them.
pub struct WorkerPool {
    injector: Mutex<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
                std::thread::Builder::new()
                    .name(format!("volcano-worker-{i}"))
                    .spawn(move || loop {
                        // hold the lock only while dequeuing, never
                        // while running a job
                        let job = lock(&rx).recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("executor: failed to spawn worker thread")
            })
            .collect();
        WorkerPool { injector: Mutex::new(tx), handles }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Apply `f` to every item on the pool, blocking until the batch
    /// completes; results come back in item order. At most
    /// `min(threads, items)` workers claim items via an atomic cursor.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        if items.is_empty() {
            return Vec::new();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> =
            items.iter().map(|_| Mutex::new(None)).collect();
        let (done_tx, done_rx) = channel::<std::thread::Result<()>>();
        let n_jobs = self.handles.len().min(items.len());
        {
            let next = &next;
            let slots = &slots;
            let f = &f;
            for _ in 0..n_jobs {
                let done_tx = done_tx.clone();
                let job: Box<dyn FnOnce() + Send + '_> =
                    Box::new(move || {
                        let r = catch_unwind(AssertUnwindSafe(|| loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= items.len() {
                                break;
                            }
                            let out = f(&items[i]);
                            *lock(&slots[i]) = Some(out);
                        }));
                        // the batch joins on this send, not the return
                        let _ = done_tx.send(r);
                    });
                // SAFETY: the job borrows `items`, `f`, `next` and
                // `slots` from this stack frame. We erase the lifetime
                // to ship it through the 'static channel, and block
                // below until every submitted job has signalled
                // completion (or panicked) before returning — the
                // borrows therefore strictly outlive all use. The
                // completion signal is sent after the closure finishes
                // (panic included, via catch_unwind), so no worker can
                // still touch the frame once recv() has yielded
                // `n_jobs` results.
                let job: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>,
                                          Job>(job)
                };
                lock(&self.injector)
                    .send(job)
                    .expect("executor: worker pool shut down");
            }
        }
        drop(done_tx);
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n_jobs {
            match done_rx.recv()
                .expect("executor: worker exited without signalling") {
                Ok(()) => {}
                Err(p) => panic = Some(p),
            }
        }
        if let Some(p) = panic {
            resume_unwind(p);
        }
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(|p| p.into_inner())
                    .expect("executor: worker left a slot empty")
            })
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // replace the injector with a dangling sender so the original
        // is dropped and every worker's recv() errors out
        let (tx, _) = channel::<Job>();
        *lock(&self.injector) = tx;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Executor facade used by the evaluator: serial inline execution for
/// one worker (or one item), a shared persistent [`WorkerPool`]
/// otherwise. Cloning shares the pool (and its threads).
#[derive(Clone, Default)]
pub struct Executor {
    workers: usize,
    pool: Option<Arc<WorkerPool>>,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("workers", &self.workers.max(1))
            .field("persistent", &self.pool.is_some())
            .finish()
    }
}

impl Executor {
    /// Pool with `workers` persistent threads; 0 is clamped to 1
    /// (serial, no threads spawned).
    pub fn new(workers: usize) -> Executor {
        let workers = workers.max(1);
        let pool = if workers > 1 {
            Some(Arc::new(WorkerPool::new(workers)))
        } else {
            None
        };
        Executor { workers, pool }
    }

    /// The strictly sequential executor (the pre-parallel behaviour).
    pub fn serial() -> Executor {
        Executor::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers.max(1)
    }

    /// Apply `f` to every item, returning results in item order.
    ///
    /// With one worker (or at most one item) this runs inline on the
    /// caller's thread — byte-for-byte the serial evaluation path.
    /// Otherwise the batch runs on the persistent pool.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        match &self.pool {
            Some(pool) if items.len() > 1 => pool.run(items, f),
            _ => items.iter().map(&f).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;
    use std::time::{Duration, Instant};

    #[test]
    fn results_arrive_in_item_order() {
        for workers in [1, 2, 4, 7] {
            let ex = Executor::new(workers);
            let items: Vec<usize> = (0..40).collect();
            let out = ex.run(&items, |&i| i * 3);
            assert_eq!(out, (0..40).map(|i| i * 3).collect::<Vec<_>>(),
                       "workers={workers}");
        }
    }

    #[test]
    fn serial_and_parallel_agree_bitwise() {
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.37).collect();
        let f = |x: &f64| (x.sin() * 1e6).cos() / (1.0 + x.abs());
        let a = Executor::serial().run(&items, f);
        let b = Executor::new(4).run(&items, f);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn pool_actually_overlaps_work() {
        // 8 sleeps of 20ms: serial floor is 160ms; two workers should
        // land well under it even on a loaded box.
        let items: Vec<u32> = (0..8).collect();
        let ex = Executor::new(4);
        let t0 = Instant::now();
        ex.run(&items, |_| {
            std::thread::sleep(Duration::from_millis(20));
        });
        let dt = t0.elapsed();
        assert!(dt < Duration::from_millis(140),
                "no overlap observed: {dt:?}");
    }

    #[test]
    fn zero_workers_clamps_to_serial() {
        let ex = Executor::new(0);
        assert_eq!(ex.workers(), 1);
        assert_eq!(ex.run(&[1, 2, 3], |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let out: Vec<i32> = Executor::new(4).run(&[], |x: &i32| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let out = Executor::new(16).run(&[5, 6], |&x| x * x);
        assert_eq!(out, vec![25, 36]);
    }

    /// Force both pool threads to participate: each of the two items
    /// blocks until two distinct claimants have arrived, so a single
    /// thread can never clear the batch alone.
    fn both_worker_ids(ex: &Executor) -> HashSet<ThreadId> {
        let arrived = AtomicUsize::new(0);
        let ids = ex.run(&[0usize, 1usize], |_| {
            arrived.fetch_add(1, Ordering::SeqCst);
            let t0 = Instant::now();
            while arrived.load(Ordering::SeqCst) < 2 {
                assert!(t0.elapsed() < Duration::from_secs(10),
                        "second worker never arrived");
                std::hint::spin_loop();
            }
            std::thread::current().id()
        });
        ids.into_iter().collect()
    }

    #[test]
    fn pool_threads_persist_across_batches() {
        // the whole point of the persistent pool: consecutive batches
        // run on the *same* threads, so per-thread caches survive
        let ex = Executor::new(2);
        let first = both_worker_ids(&ex);
        assert_eq!(first.len(), 2, "both workers claim one item each");
        assert!(!first.contains(&std::thread::current().id()),
                "work runs on pool threads, not the caller");
        for _ in 0..3 {
            let again = both_worker_ids(&ex);
            assert_eq!(first, again,
                       "batch ran on fresh threads: {again:?} vs \
                        {first:?}");
        }
    }

    #[test]
    fn cloned_executor_shares_the_pool() {
        let ex = Executor::new(2);
        let clone = ex.clone();
        let a = both_worker_ids(&ex);
        let b = both_worker_ids(&clone);
        assert_eq!(a, b, "clone must reuse the same pool threads");
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let ex = Executor::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.run(&[0, 1, 2, 3], |&i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            });
        }));
        assert!(caught.is_err(), "panic must reach the caller");
        // the pool is still usable afterwards
        let out = ex.run(&[1, 2, 3, 4], |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5]);
    }
}
