//! Worker-pool executor for batched candidate evaluation.
//!
//! The Volcano-style `do_next!` pull proposes a *batch* of candidate
//! configurations per leaf block; this executor fans the batch out
//! across a pool of scoped worker threads and returns the results in
//! request order. Determinism contract: the executor never reorders
//! results — `workers = 1` and `workers = N` produce identical output
//! for the same input batch, so worker count is purely a performance
//! knob (the *batch size* is what changes search semantics).
//!
//! Built on `std::thread::scope`: no queue handoff of owned data, no
//! extra dependencies, and worker closures may borrow the evaluator
//! immutably (`F: Sync`). Work is claimed through an atomic cursor so
//! uneven per-candidate costs balance across the pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, Debug)]
pub struct Executor {
    workers: usize,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::serial()
    }
}

impl Executor {
    /// Pool with `workers` threads; 0 is clamped to 1 (serial).
    pub fn new(workers: usize) -> Executor {
        Executor { workers: workers.max(1) }
    }

    /// The strictly sequential executor (the pre-parallel behaviour).
    pub fn serial() -> Executor {
        Executor::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to every item, returning results in item order.
    ///
    /// With one worker (or at most one item) this runs inline on the
    /// caller's thread — byte-for-byte the serial evaluation path.
    /// Otherwise `min(workers, items)` scoped threads claim items via
    /// an atomic cursor. A panic inside `f` propagates to the caller
    /// once the scope joins, exactly like the serial path.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        if self.workers <= 1 || items.len() <= 1 {
            return items.iter().map(&f).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> =
            items.iter().map(|_| Mutex::new(None)).collect();
        let n_threads = self.workers.min(items.len());
        std::thread::scope(|s| {
            for _ in 0..n_threads {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let r = f(&items[i]);
                    match slots[i].lock() {
                        Ok(mut g) => *g = Some(r),
                        Err(p) => *p.into_inner() = Some(r),
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(|p| p.into_inner())
                    .expect("executor: worker left a slot empty")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn results_arrive_in_item_order() {
        for workers in [1, 2, 4, 7] {
            let ex = Executor::new(workers);
            let items: Vec<usize> = (0..40).collect();
            let out = ex.run(&items, |&i| i * 3);
            assert_eq!(out, (0..40).map(|i| i * 3).collect::<Vec<_>>(),
                       "workers={workers}");
        }
    }

    #[test]
    fn serial_and_parallel_agree_bitwise() {
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.37).collect();
        let f = |x: &f64| (x.sin() * 1e6).cos() / (1.0 + x.abs());
        let a = Executor::serial().run(&items, f);
        let b = Executor::new(4).run(&items, f);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn pool_actually_overlaps_work() {
        // 8 sleeps of 20ms: serial floor is 160ms; two workers should
        // land well under it even on a loaded box.
        let items: Vec<u32> = (0..8).collect();
        let t0 = Instant::now();
        Executor::new(4).run(&items, |_| {
            std::thread::sleep(Duration::from_millis(20));
        });
        let dt = t0.elapsed();
        assert!(dt < Duration::from_millis(140),
                "no overlap observed: {dt:?}");
    }

    #[test]
    fn zero_workers_clamps_to_serial() {
        let ex = Executor::new(0);
        assert_eq!(ex.workers(), 1);
        assert_eq!(ex.run(&[1, 2, 3], |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let out: Vec<i32> = Executor::new(4).run(&[], |x: &i32| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let out = Executor::new(16).run(&[5, 6], |&x| x * x);
        assert_eq!(out, vec![25, 36]);
    }
}
