//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange is HLO *text* (not serialized HloModuleProto): jax >=
//! 0.5 emits 64-bit instruction ids which xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Thread-safety: the runtime is shared immutably across the
//! `runtime::executor` worker pool, so it holds no interior `Rc`s —
//! execution telemetry sits behind a `Mutex`, and compiled
//! `PjRtLoadedExecutable`s (which are not `Sync`) live in *per-thread*
//! caches: each worker compiles an artifact once on first use and
//! reuses its own instance for the lifetime of the thread. Python
//! never runs at search time.
//!
//! The `xla` crate (and its native XLA libraries) is only present in
//! artifact-enabled deployments, so everything touching it is gated
//! behind the `pjrt` cargo feature. Without the feature,
//! [`Runtime::new`] returns an error and every caller degrades to the
//! native algorithm roster — the documented PJRT-skip path.

pub mod executor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};

/// Canonical shape constants exported by the AOT manifest. Mirrors
/// `python/compile/shapes.py`.
#[derive(Clone, Debug)]
pub struct Constants {
    pub n_train: usize,
    pub n_val: usize,
    pub d: usize,
    pub c: usize,
    pub c_reg: usize,
    pub t_steps: usize,
    pub k_max: usize,
    pub mlp_hidden: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    pub family: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub input_dtypes: Vec<String>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// A host-side tensor to feed an artifact.
pub enum Input {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Input {
    #[cfg(feature = "pjrt")]
    fn shape(&self) -> &[usize] {
        match self {
            Input::F32(_, s) | Input::I32(_, s) => s,
        }
    }
}

/// A host-side output tensor (always converted to f32 for callers).
#[derive(Clone, Debug)]
pub struct Output {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    constants: Constants,
    artifacts: HashMap<String, ArtifactInfo>,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    art_dir: PathBuf,
    /// Telemetry: (#executions, total execute seconds) per artifact.
    stats: Mutex<HashMap<String, (u64, f64)>>,
}

#[cfg(feature = "pjrt")]
thread_local! {
    /// Per-thread compiled-executable cache, keyed by
    /// `<artifact dir>::<artifact name>`. PJRT loaded executables are
    /// not `Sync`; one compilation per (thread, artifact) keeps the
    /// hot path lock-free.
    static EXECS: std::cell::RefCell<
        HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>
        = std::cell::RefCell::new(HashMap::new());
}

impl Runtime {
    #[cfg(not(feature = "pjrt"))]
    pub fn new(art_dir: &Path) -> Result<Runtime> {
        bail!(
            "PJRT runtime support is not compiled in (artifact dir: \
             {}): rebuild with `--features pjrt` and supply the `xla` \
             crate (see rust/README.md); falling back to the native \
             algorithm roster",
            art_dir.display()
        );
    }

    #[cfg(feature = "pjrt")]
    pub fn new(art_dir: &Path) -> Result<Runtime> {
        use crate::util::json::Json;

        let manifest_path = art_dir.join("manifest.json");
        let man = Json::parse_file(&manifest_path).with_context(|| {
            format!("reading {} (run `make artifacts` first)",
                    manifest_path.display())
        })?;
        let consts = man
            .get("constants")
            .ok_or_else(|| anyhow!("manifest missing constants"))?;
        let need = |k: &str| -> Result<usize> {
            consts
                .get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest constant {k} missing"))
        };
        let constants = Constants {
            n_train: need("n_train")?,
            n_val: need("n_val")?,
            d: need("d")?,
            c: need("c")?,
            c_reg: need("c_reg")?,
            t_steps: need("t_steps")?,
            k_max: need("k_max")?,
            mlp_hidden: consts
                .get("mlp_hidden")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
        };
        let mut artifacts = HashMap::new();
        let arts = man
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for (name, entry) in arts {
            let shapes_of = |key: &str| -> Vec<Vec<usize>> {
                entry
                    .get(key)
                    .and_then(|v| v.as_arr())
                    .map(|items| {
                        items
                            .iter()
                            .map(|it| {
                                it.get("shape")
                                    .and_then(|s| s.as_arr())
                                    .map(|dims| dims.iter()
                                        .filter_map(|d| d.as_usize())
                                        .collect())
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            let dtypes: Vec<String> = entry
                .get("inputs")
                .and_then(|v| v.as_arr())
                .map(|items| {
                    items
                        .iter()
                        .map(|it| it.get("dtype").and_then(|d| d.as_str())
                            .unwrap_or("float32").to_string())
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(name.clone(), ArtifactInfo {
                file: entry.get("file").and_then(|f| f.as_str())
                    .unwrap_or("").to_string(),
                family: entry.get("family").and_then(|f| f.as_str())
                    .unwrap_or("").to_string(),
                input_shapes: shapes_of("inputs"),
                input_dtypes: dtypes,
                output_shapes: shapes_of("output_shapes"),
            });
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            constants,
            artifacts,
            art_dir: art_dir.to_path_buf(),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// Locate the artifacts directory next to the current executable /
    /// working directory (used by binaries and tests).
    pub fn default_dir() -> PathBuf {
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    pub fn constants(&self) -> &Constants {
        &self.constants
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.get(name)
    }

    #[cfg(feature = "pjrt")]
    fn executable(&self, name: &str)
        -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let cache_key = format!("{}::{name}", self.art_dir.display());
        let hit = EXECS.with(|c| c.borrow().get(&cache_key).cloned());
        if let Some(e) = hit {
            return Ok(e);
        }
        let info = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.art_dir.join(&info.file);
        let text_path = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(text_path)
            .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let rc = std::rc::Rc::new(exe);
        EXECS.with(|c| {
            c.borrow_mut().insert(cache_key, rc.clone());
        });
        Ok(rc)
    }

    /// Execute an artifact; returns the decomposed output tuple.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute(&self, name: &str, _inputs: &[Input])
        -> Result<Vec<Output>> {
        bail!("cannot execute artifact {name}: built without the \
               `pjrt` feature")
    }

    /// Execute an artifact; returns the decomposed output tuple.
    #[cfg(feature = "pjrt")]
    pub fn execute(&self, name: &str, inputs: &[Input])
        -> Result<Vec<Output>> {
        let info = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        if inputs.len() != info.input_shapes.len() {
            bail!("{name}: expected {} inputs, got {}",
                  info.input_shapes.len(), inputs.len());
        }
        for (i, (inp, want)) in
            inputs.iter().zip(&info.input_shapes).enumerate() {
            if inp.shape() != want.as_slice() {
                bail!("{name}: input {i} shape {:?} != expected {:?}",
                      inp.shape(), want);
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                let lit = match inp {
                    Input::F32(data, shape) => {
                        let dims: Vec<i64> =
                            shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(data)
                            .reshape(&dims)
                            .map_err(|e| anyhow!("reshape: {e:?}"))?
                    }
                    Input::I32(data, shape) => {
                        let dims: Vec<i64> =
                            shape.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(data)
                            .reshape(&dims)
                            .map_err(|e| anyhow!("reshape: {e:?}"))?
                    }
                };
                Ok(lit)
            })
            .collect::<Result<_>>()?;

        // DETLINT: allow(wall-clock): telemetry only — feeds the
        // device-time gauge, never a search decision.
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let buf = &result[0][0];
        let tuple = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut st = match self.stats.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            let e = st.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }

        let outputs = tuple
            .into_iter()
            .zip(info.output_shapes.iter())
            .map(|(lit, shape)| -> Result<Output> {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output to_vec: {e:?}"))?;
                Ok(Output { data, shape: shape.clone() })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(outputs)
    }

    /// (#executions, total seconds) per artifact, for §Perf telemetry.
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        let st = match self.stats.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let mut v: Vec<(String, u64, f64)> = st
            .iter()
            .map(|(k, (n, s))| (k.clone(), *n, *s))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        // built without the pjrt feature this errors; skip then too
        Runtime::new(&dir).ok()
    }

    #[test]
    fn runtime_is_send_and_sync() {
        #[allow(dead_code)]
        fn assert_send_sync<T: Send + Sync>() {}
        // with the pjrt feature the bound depends on the xla client;
        // the stub build must always be shareable across workers
        #[cfg(not(feature = "pjrt"))]
        assert_send_sync::<Runtime>();
    }

    #[test]
    fn missing_artifacts_error_gracefully() {
        // the PJRT-skip path: construction must return Err (so callers
        // degrade to the native roster), never panic
        let bad = std::env::temp_dir().join("volcano-no-artifacts");
        let _ = std::fs::create_dir_all(&bad);
        assert!(Runtime::new(&bad).is_err());
        assert!(Runtime::new(Path::new("/nonexistent/nowhere")).is_err());
    }

    #[test]
    fn manifest_loads_with_expected_artifacts() {
        let Some(rt) = runtime() else { return };
        let names = rt.artifact_names();
        for want in ["glm_softmax", "glm_hinge", "glm_identity",
                     "glm_huber", "knn_cls", "knn_reg"] {
            assert!(names.iter().any(|n| n == want), "{want} missing");
        }
        assert_eq!(rt.constants().d, 32);
        assert!(rt.constants().n_train >= 256);
    }

    #[test]
    fn glm_softmax_trains_on_blobs_via_pjrt() {
        let Some(rt) = runtime() else { return };
        let c = rt.constants().clone();
        let mut rng = crate::util::rng::Rng::new(0);
        // 3-class blobs in the first 2 dims, padded
        let m = 400.min(c.n_train);
        let mut x = vec![0.0f32; c.n_train * c.d];
        let mut y = vec![0.0f32; c.n_train * c.c];
        let mut labels = vec![0usize; m];
        let centers = [(0.0, 0.0), (3.0, 0.0), (0.0, 3.0)];
        for i in 0..m {
            let cls = rng.below(3);
            labels[i] = cls;
            x[i * c.d] = (centers[cls].0 + rng.normal() * 0.5) as f32;
            x[i * c.d + 1] = (centers[cls].1 + rng.normal() * 0.5) as f32;
            y[i * c.c + cls] = 1.0;
        }
        let mut mask = vec![0.0f32; c.n_train];
        for v in mask.iter_mut().take(m) {
            *v = 1.0;
        }
        let mut cmask = vec![0.0f32; c.c];
        cmask[..3].fill(1.0);
        let xv: Vec<f32> = x[..c.n_val * c.d].to_vec();
        let sched = vec![1.0f32; c.t_steps];
        let hypers = vec![0.5f32, 1e-4, 0.0, 1.0];

        let out = rt
            .execute("glm_softmax", &[
                Input::F32(x, vec![c.n_train, c.d]),
                Input::F32(y, vec![c.n_train, c.c]),
                Input::F32(mask, vec![c.n_train, 1]),
                Input::F32(cmask, vec![1, c.c]),
                Input::F32(xv, vec![c.n_val, c.d]),
                Input::F32(sched, vec![c.t_steps]),
                Input::F32(hypers, vec![1, 4]),
            ])
            .expect("execute");
        assert_eq!(out.len(), 3);
        let scores = &out[0];
        assert_eq!(scores.shape, vec![c.n_val, c.c]);
        let mut hits = 0;
        for i in 0..c.n_val.min(m) {
            let row = &scores.data[i * c.c..i * c.c + 3];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == labels[i] {
                hits += 1;
            }
        }
        let acc = hits as f64 / c.n_val.min(m) as f64;
        assert!(acc > 0.9, "pjrt-trained GLM acc = {acc}");
        // telemetry recorded
        let stats = rt.exec_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].1, 1);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let Some(rt) = runtime() else { return };
        let bad = rt.execute("glm_softmax",
                             &[Input::F32(vec![0.0], vec![1])]);
        assert!(bad.is_err());
    }

    #[test]
    fn unknown_artifact_is_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("nope", &[]).is_err());
    }
}
