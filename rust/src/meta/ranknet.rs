//! RankNet arm-ranking for conditioning blocks (§5.1, Eq. 11).
//!
//! A small MLP scores (dataset meta-features, arm one-hot) pairs;
//! training minimises the pairwise hinge objective
//! `l+(σ(r_j - r_k)) + l-(σ(r_k - r_j))` over triples
//! (A_j better-than A_k on D_i). Inference ranks the arms for a new
//! dataset; the top-`k` subset prunes the conditioning block's arms.
//!
//! Implemented natively (manual backprop) — it runs at planning time,
//! not on the evaluation hot path.

use crate::util::rng::Rng;

/// A preference triple: on dataset with meta-features `d`, arm
/// `better` outperformed arm `worse`.
#[derive(Clone, Debug)]
pub struct Triple {
    pub d: Vec<f64>,
    pub better: usize,
    pub worse: usize,
}

pub struct RankNet {
    pub n_arms: usize,
    d_in: usize,
    h: usize,
    w1: Vec<f64>, // d_in x h
    b1: Vec<f64>,
    w2: Vec<f64>, // h
    b2: f64,
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

impl RankNet {
    pub fn new(meta_dim: usize, n_arms: usize, hidden: usize,
               rng: &mut Rng) -> RankNet {
        let d_in = meta_dim + n_arms;
        let scale = (2.0 / d_in as f64).sqrt();
        RankNet {
            n_arms,
            d_in,
            h: hidden,
            w1: (0..d_in * hidden).map(|_| rng.normal() * scale)
                .collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden).map(|_| rng.normal() * (1.0
                / hidden as f64).sqrt()).collect(),
            b2: 0.0,
        }
    }

    fn input(&self, d: &[f64], arm: usize) -> Vec<f64> {
        let mut x = d.to_vec();
        let mut onehot = vec![0.0; self.n_arms];
        onehot[arm.min(self.n_arms - 1)] = 1.0;
        x.extend(onehot);
        x
    }

    /// Forward pass returning (score, hidden activations).
    fn forward(&self, x: &[f64]) -> (f64, Vec<f64>) {
        let mut hid = vec![0.0; self.h];
        for j in 0..self.h {
            let mut z = self.b1[j];
            for (i, &xi) in x.iter().enumerate() {
                z += xi * self.w1[i * self.h + j];
            }
            hid[j] = z.max(0.0);
        }
        let mut out = self.b2;
        for j in 0..self.h {
            out += hid[j] * self.w2[j];
        }
        (out, hid)
    }

    pub fn score(&self, d: &[f64], arm: usize) -> f64 {
        self.forward(&self.input(d, arm)).0
    }

    /// Rank all arms for a dataset (best first).
    pub fn rank_arms(&self, d: &[f64]) -> Vec<usize> {
        let scores: Vec<f64> =
            (0..self.n_arms).map(|a| self.score(d, a)).collect();
        let mut idx: Vec<usize> = (0..self.n_arms).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal));
        idx
    }

    pub fn top_k(&self, d: &[f64], k: usize) -> Vec<usize> {
        let mut r = self.rank_arms(d);
        r.truncate(k.max(1));
        r
    }

    /// One SGD pass over the triples; returns the mean pairwise loss.
    pub fn train_epoch(&mut self, triples: &[Triple], lr: f64,
                       rng: &mut Rng) -> f64 {
        let mut order: Vec<usize> = (0..triples.len()).collect();
        rng.shuffle(&mut order);
        let mut total_loss = 0.0;
        for &ti in &order {
            let t = &triples[ti];
            let xj = self.input(&t.d, t.better);
            let xk = self.input(&t.d, t.worse);
            let (rj, hj) = self.forward(&xj);
            let (rk, hk) = self.forward(&xk);
            // pairwise logistic (RankNet) loss on the margin rj - rk
            let p = sigmoid(rj - rk);
            total_loss += -(p.max(1e-12)).ln();
            // dL/d(rj - rk) = p - 1
            let g = p - 1.0;
            // backprop through both branches (shared weights):
            // d rj/d w2 = hj ; d rk/d w2 = hk
            for j in 0..self.h {
                let gw2 = g * (hj[j] - hk[j]);
                // hidden grads
                let gh_j = g * self.w2[j];
                self.w2[j] -= lr * gw2;
                if hj[j] > 0.0 {
                    for (i, &xi) in xj.iter().enumerate() {
                        self.w1[i * self.h + j] -= lr * gh_j * xi;
                    }
                    self.b1[j] -= lr * gh_j;
                }
                if hk[j] > 0.0 {
                    for (i, &xi) in xk.iter().enumerate() {
                        self.w1[i * self.h + j] += lr * gh_j * xi;
                    }
                    self.b1[j] += lr * gh_j;
                }
            }
        }
        total_loss / triples.len().max(1) as f64
    }

    /// Full training loop with a step-decayed learning rate.
    pub fn train(&mut self, triples: &[Triple], epochs: usize,
                 rng: &mut Rng) -> f64 {
        let mut loss = f64::INFINITY;
        for e in 0..epochs {
            let lr = 0.02 * 0.97f64.powi(e as i32);
            loss = self.train_epoch(triples, lr, rng);
        }
        loss
    }
}

/// Turn per-dataset arm utilities into preference triples (all ordered
/// pairs with a margin).
pub fn triples_from_scores(d: &[f64], arm_scores: &[(usize, f64)],
                           margin: f64) -> Vec<Triple> {
    let mut out = Vec::new();
    for i in 0..arm_scores.len() {
        for j in 0..arm_scores.len() {
            if arm_scores[i].1 > arm_scores[j].1 + margin {
                out.push(Triple {
                    d: d.to_vec(),
                    better: arm_scores[i].0,
                    worse: arm_scores[j].0,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic meta-world: arm 0 wins when d[0] > 0, arm 1 wins when
    /// d[0] < 0; arm 2 always mediocre.
    fn world(rng: &mut Rng, n_tasks: usize) -> Vec<Triple> {
        let mut triples = Vec::new();
        for _ in 0..n_tasks {
            let f0 = rng.uniform(-1.0, 1.0);
            let d = vec![f0, rng.normal() * 0.1, 1.0];
            let scores = if f0 > 0.0 {
                vec![(0usize, 0.9), (1usize, 0.3), (2usize, 0.6)]
            } else {
                vec![(0, 0.3), (1, 0.9), (2, 0.6)]
            };
            triples.extend(triples_from_scores(&d, &scores, 0.05));
        }
        triples
    }

    #[test]
    fn learns_context_dependent_ranking() {
        let mut rng = Rng::new(0);
        let triples = world(&mut rng, 120);
        let mut net = RankNet::new(3, 3, 16, &mut rng);
        let loss0 = net.train_epoch(&triples, 0.0, &mut rng); // probe
        let loss = net.train(&triples, 40, &mut rng);
        assert!(loss < loss0 * 0.8, "loss {loss0} -> {loss}");
        // rankings flip with the context feature
        let pos = net.rank_arms(&[0.8, 0.0, 1.0]);
        let neg = net.rank_arms(&[-0.8, 0.0, 1.0]);
        assert_eq!(pos[0], 0, "pos context ranks {pos:?}");
        assert_eq!(neg[0], 1, "neg context ranks {neg:?}");
    }

    #[test]
    fn top_k_subset_contains_winner() {
        let mut rng = Rng::new(1);
        let triples = world(&mut rng, 120);
        let mut net = RankNet::new(3, 3, 16, &mut rng);
        net.train(&triples, 40, &mut rng);
        let top2 = net.top_k(&[0.9, 0.0, 1.0], 2);
        assert!(top2.contains(&0));
        assert_eq!(top2.len(), 2);
    }

    #[test]
    fn triples_respect_margin() {
        let scores = vec![(0usize, 0.5), (1usize, 0.5001), (2usize, 0.9)];
        let t = triples_from_scores(&[1.0], &scores, 0.05);
        // only arm 2 dominates the others beyond the margin
        assert_eq!(t.len(), 2);
        assert!(t.iter().all(|x| x.better == 2));
    }
}
