//! Meta-learning accelerations (§5): dataset meta-features, RankNet
//! arm pruning for conditioning blocks, RGPE surrogate transfer for
//! joint blocks, and the persisted meta-corpus with the paper's
//! leave-one-out protocol.

pub mod corpus;
pub mod features;
pub mod ranknet;
pub mod rgpe;

pub use corpus::{MetaCorpus, TaskRecord};
pub use features::{meta_features, META_DIM};
pub use ranknet::RankNet;
pub use rgpe::Rgpe;
