//! RGPE meta-surrogate for joint blocks (§5.2, Eqs. 12–13):
//! a ranking-weighted ensemble of Gaussian processes fitted to BO
//! histories from prior tasks plus the target task. The weight of each
//! base surrogate is the (bootstrap-estimated) probability that it has
//! the smallest pairwise ranking loss on the target observations.

use crate::surrogate::gp::Gp;
use crate::surrogate::Surrogate;
use crate::util::rng::Rng;

pub struct Rgpe {
    /// Base GPs fitted on prior-task histories (frozen).
    base: Vec<Gp>,
    /// Target-task GP, refitted on every `fit` call.
    target: Gp,
    weights: Vec<f64>,
    /// Bootstrap samples for the argmin probability (Eq. 13).
    pub n_bootstrap: usize,
    rng: Rng,
    target_x: Vec<Vec<f64>>,
    target_y: Vec<f64>,
}

impl Rgpe {
    /// `histories`: per prior task, the (features, utility) history.
    pub fn new(histories: &[(Vec<Vec<f64>>, Vec<f64>)], seed: u64)
        -> Rgpe {
        let base = histories
            .iter()
            .filter(|(x, _)| x.len() >= 3)
            .map(|(x, y)| {
                let mut gp = Gp::new();
                gp.fit(x, y);
                gp
            })
            .collect::<Vec<_>>();
        let n = base.len();
        Rgpe {
            base,
            target: Gp::new(),
            weights: vec![1.0 / (n + 1) as f64; n + 1],
            n_bootstrap: 50,
            rng: Rng::new(seed ^ 0x46504752),
            target_x: Vec::new(),
            target_y: Vec::new(),
        }
    }

    pub fn n_base(&self) -> usize {
        self.base.len()
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Pairwise ranking loss of a predictor against target
    /// observations restricted to index set `idx` (Eq. 13). The target
    /// GP uses leave-one-out predictions to avoid trivially winning.
    fn ranking_loss(predict: &dyn Fn(usize) -> f64, ys: &[f64],
                    idx: &[usize]) -> f64 {
        let mut loss = 0.0;
        for (a, &i) in idx.iter().enumerate() {
            for &j in idx.iter().skip(a + 1) {
                let pi = predict(i);
                let pj = predict(j);
                if (pi < pj) != (ys[i] < ys[j]) {
                    loss += 1.0;
                }
            }
        }
        loss
    }

    fn reweight(&mut self) {
        let n = self.target_y.len();
        let k = self.base.len();
        if n < 3 {
            self.weights = vec![1.0 / (k + 1) as f64; k + 1];
            return;
        }
        // cache predictions of each base GP on the target points
        let base_preds: Vec<Vec<f64>> = self
            .base
            .iter()
            .map(|gp| {
                self.target_x.iter().map(|x| gp.predict(x).0).collect()
            })
            .collect();
        // leave-one-out target predictions: refit is too costly, so use
        // the standard approximation — predict each point from a GP
        // trained on all points (optimistic) but add the predictive
        // noise; with few points this is close enough for weighting.
        let tgt_preds: Vec<f64> = self
            .target_x
            .iter()
            .enumerate()
            .map(|(i, x)| {
                // jackknife-lite: perturb by removing the point's own
                // residual influence via noise-scaled shrinkage
                let (m, v) = self.target.predict(x);
                let shrink = v / (v + self.target.noise + 1e-9);
                m * shrink + self.target_y[i] * 0.0
                    + (1.0 - shrink) * crate::util::stats::mean(
                        &self.target_y)
            })
            .collect();
        let mut wins = vec![0.0f64; k + 1];
        let all_idx: Vec<usize> = (0..n).collect();
        for _ in 0..self.n_bootstrap {
            let idx: Vec<usize> = (0..n)
                .map(|_| all_idx[self.rng.below(n)])
                .collect();
            let mut best = (f64::INFINITY, 0usize);
            for (b, preds) in base_preds.iter().enumerate() {
                let l = Self::ranking_loss(&|i| preds[i],
                                           &self.target_y, &idx);
                if l < best.0 {
                    best = (l, b);
                }
            }
            let lt = Self::ranking_loss(&|i| tgt_preds[i],
                                        &self.target_y, &idx);
            if lt <= best.0 {
                wins[k] += 1.0;
            } else {
                wins[best.1] += 1.0;
            }
        }
        let total: f64 = wins.iter().sum();
        self.weights = wins.iter().map(|w| w / total.max(1.0)).collect();
    }
}

impl Surrogate for Rgpe {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        self.target_x = x.to_vec();
        self.target_y = y.to_vec();
        self.target.fit(x, y);
        self.reweight();
    }

    fn predict(&self, x: &[f64]) -> (f64, f64) {
        let k = self.base.len();
        let mut mean = 0.0;
        let mut var = 0.0;
        for (b, gp) in self.base.iter().enumerate() {
            let w = self.weights[b];
            if w > 1e-9 {
                let (m, v) = gp.predict(x);
                mean += w * m;
                var += w * v;
            }
        }
        let wt = self.weights[k];
        if wt > 1e-9 || k == 0 {
            let (m, v) = self.target.predict(x);
            let w = if k == 0 { 1.0 } else { wt };
            mean += w * m;
            var += w * v;
        }
        (mean, var.max(1e-10))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Prior task objectives share the target's structure; an
    /// unrelated prior should be down-weighted.
    fn samples(f: impl Fn(f64) -> f64, n: usize, seed: u64)
        -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.f64()]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| f(x[0])).collect();
        (xs, ys)
    }

    #[test]
    fn related_prior_gets_more_weight_than_adversarial() {
        let related = samples(|x| -(x - 0.7).powi(2), 25, 0);
        let adversarial = samples(|x| (x - 0.7).powi(2), 25, 1);
        let mut rgpe = Rgpe::new(&[related, adversarial], 2);
        // a few target observations of the same related function
        let (tx, ty) = samples(|x| -(x - 0.7).powi(2) + 0.01, 8, 3);
        rgpe.fit(&tx, &ty);
        let w = rgpe.weights();
        assert_eq!(w.len(), 3);
        assert!(w[0] > w[1],
                "related {:.3} should outweigh adversarial {:.3}",
                w[0], w[1]);
    }

    #[test]
    fn few_observations_fall_back_to_uniform() {
        let prior = samples(|x| x, 20, 4);
        let mut rgpe = Rgpe::new(&[prior], 5);
        rgpe.fit(&[vec![0.5]], &[0.5]);
        let w = rgpe.weights();
        assert!((w[0] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn warm_start_prediction_matches_prior_structure() {
        // with NO target observations, prediction is driven by priors
        let prior = samples(|x| -(x - 0.3).powi(2), 30, 6);
        let mut rgpe = Rgpe::new(&[prior], 7);
        rgpe.fit(&[], &[]);
        let (m_peak, _) = rgpe.predict(&[0.3]);
        let (m_far, _) = rgpe.predict(&[0.95]);
        assert!(m_peak > m_far,
                "prior knowledge should rank 0.3 above 0.95 \
                 ({m_peak} vs {m_far})");
    }

    #[test]
    fn implements_surrogate_for_smac_injection() {
        let prior = samples(|x| -(x - 0.6).powi(2), 20, 8);
        let rgpe: Box<dyn Surrogate> =
            Box::new(Rgpe::new(&[prior], 9));
        let (m, v) = rgpe.predict(&[0.6]);
        assert!(m.is_finite() && v > 0.0);
    }
}
