//! Persistent meta-knowledge (§5 + §6.1 "Training Data for
//! Meta-learning"): per prior task we store its meta-features, the
//! best utility each algorithm arm achieved, and the BO histories of
//! each leaf block (feature-encoded in that leaf's subspace). The
//! corpus feeds RankNet arm pruning and RGPE surrogate transfer with
//! the paper's leave-one-out protocol.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::ranknet::{triples_from_scores, RankNet, Triple};
use super::rgpe::Rgpe;

#[derive(Clone, Debug, Default)]
pub struct TaskRecord {
    pub name: String,
    pub metric: String,
    pub meta_features: Vec<f64>,
    /// best utility per algorithm arm on this task.
    pub arm_scores: BTreeMap<String, f64>,
    /// per-leaf BO history: label -> (encoded configs, utilities).
    pub leaf_histories: BTreeMap<String, (Vec<Vec<f64>>, Vec<f64>)>,
}

#[derive(Clone, Debug, Default)]
pub struct MetaCorpus {
    pub records: Vec<TaskRecord>,
}

impl MetaCorpus {
    pub fn push(&mut self, rec: TaskRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    // ---- persistence ----------------------------------------------
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    let arms = Json::Obj(
                        r.arm_scores
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::Num(*v)))
                            .collect(),
                    );
                    let hists = Json::Obj(
                        r.leaf_histories
                            .iter()
                            .map(|(k, (xs, ys))| {
                                (k.clone(), Json::obj(vec![
                                    ("x", Json::Arr(xs.iter()
                                        .map(|row| Json::arr_f64(row))
                                        .collect())),
                                    ("y", Json::arr_f64(ys)),
                                ]))
                            })
                            .collect(),
                    );
                    Json::obj(vec![
                        ("name", Json::Str(r.name.clone())),
                        ("metric", Json::Str(r.metric.clone())),
                        ("meta_features",
                         Json::arr_f64(&r.meta_features)),
                        ("arm_scores", arms),
                        ("leaf_histories", hists),
                    ])
                })
                .collect(),
        )
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn from_json(v: &Json) -> Result<MetaCorpus> {
        let arr = v.as_arr().ok_or_else(|| anyhow!("corpus not array"))?;
        let mut out = MetaCorpus::default();
        for item in arr {
            let mut rec = TaskRecord {
                name: item.get("name").and_then(|s| s.as_str())
                    .unwrap_or("").to_string(),
                metric: item.get("metric").and_then(|s| s.as_str())
                    .unwrap_or("").to_string(),
                meta_features: item
                    .get("meta_features")
                    .and_then(|a| a.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_f64())
                        .collect())
                    .unwrap_or_default(),
                ..Default::default()
            };
            if let Some(arms) =
                item.get("arm_scores").and_then(|o| o.as_obj()) {
                for (k, v) in arms {
                    if let Some(x) = v.as_f64() {
                        rec.arm_scores.insert(k.clone(), x);
                    }
                }
            }
            if let Some(h) =
                item.get("leaf_histories").and_then(|o| o.as_obj()) {
                for (k, v) in h {
                    let xs: Vec<Vec<f64>> = v
                        .get("x")
                        .and_then(|a| a.as_arr())
                        .map(|rows| rows.iter()
                            .map(|r| r.as_arr().map(|c| c.iter()
                                .filter_map(|x| x.as_f64()).collect())
                                .unwrap_or_default())
                            .collect())
                        .unwrap_or_default();
                    let ys: Vec<f64> = v
                        .get("y")
                        .and_then(|a| a.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_f64())
                            .collect())
                        .unwrap_or_default();
                    rec.leaf_histories.insert(k.clone(), (xs, ys));
                }
            }
            out.records.push(rec);
        }
        Ok(out)
    }

    pub fn load(path: &Path) -> Result<MetaCorpus> {
        let v = Json::parse_file(path)?;
        Self::from_json(&v)
    }

    // ---- meta-learning consumers ------------------------------------
    /// Records usable for a task (same metric, leave-one-out by name).
    fn relevant<'a>(&'a self, metric: &str, exclude: &str)
        -> impl Iterator<Item = &'a TaskRecord> {
        let metric = metric.to_string();
        let exclude = exclude.to_string();
        self.records
            .iter()
            .filter(move |r| r.metric == metric && r.name != exclude)
    }

    /// Train a RankNet over the corpus (leave-one-out) for the given
    /// arm universe; returns None with too little data.
    pub fn train_ranknet(&self, arms: &[String], metric: &str,
                         exclude: &str, rng: &mut Rng)
        -> Option<RankNet> {
        let mut triples: Vec<Triple> = Vec::new();
        let mut meta_dim = 0;
        for rec in self.relevant(metric, exclude) {
            if rec.meta_features.is_empty() {
                continue;
            }
            meta_dim = rec.meta_features.len();
            let scores: Vec<(usize, f64)> = arms
                .iter()
                .enumerate()
                .filter_map(|(i, a)| {
                    rec.arm_scores.get(a).map(|&s| (i, s))
                })
                .collect();
            triples.extend(triples_from_scores(
                &rec.meta_features, &scores, 1e-4));
        }
        if triples.len() < 3 || meta_dim == 0 {
            return None;
        }
        let mut net = RankNet::new(meta_dim, arms.len(), 24, rng);
        net.train(&triples, 30, rng);
        Some(net)
    }

    /// Build an RGPE surrogate for one leaf label from prior
    /// histories with matching feature dimension.
    pub fn rgpe_for_leaf(&self, leaf: &str, metric: &str, exclude: &str,
                         dim: usize, seed: u64) -> Option<Rgpe> {
        let hists: Vec<(Vec<Vec<f64>>, Vec<f64>)> = self
            .relevant(metric, exclude)
            .filter_map(|r| r.leaf_histories.get(leaf))
            .filter(|(xs, _)| !xs.is_empty() && xs[0].len() == dim)
            .map(|(xs, ys)| {
                // cap per-task history so GP fits stay cheap
                let cap = 40.min(xs.len());
                (xs[..cap].to_vec(), ys[..cap].to_vec())
            })
            .collect();
        if hists.is_empty() {
            return None;
        }
        Some(Rgpe::new(&hists, seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(name: &str, f0: f64) -> TaskRecord {
        let mut arm_scores = BTreeMap::new();
        // arm "a" wins when f0 > 0
        arm_scores.insert("a".into(), if f0 > 0.0 { 0.9 } else { 0.2 });
        arm_scores.insert("b".into(), 0.5);
        let mut leaf_histories = BTreeMap::new();
        leaf_histories.insert(
            "hp|a".into(),
            (vec![vec![0.1], vec![0.5], vec![0.9]],
             vec![0.2, 0.6, 0.4]),
        );
        TaskRecord {
            name: name.into(),
            metric: "balanced_accuracy".into(),
            meta_features: vec![f0, 1.0],
            arm_scores,
            leaf_histories,
        }
    }

    fn corpus(n: usize) -> MetaCorpus {
        let mut c = MetaCorpus::default();
        for i in 0..n {
            let f0 = if i % 2 == 0 { 0.8 } else { -0.8 };
            c.push(record(&format!("t{i}"), f0));
        }
        c
    }

    #[test]
    fn json_roundtrip() {
        let c = corpus(4);
        let j = c.to_json();
        let c2 = MetaCorpus::from_json(&j).unwrap();
        assert_eq!(c2.len(), 4);
        assert_eq!(c2.records[0].arm_scores["a"], 0.9);
        assert_eq!(c2.records[0].leaf_histories["hp|a"].0.len(), 3);
        assert_eq!(c2.records[1].meta_features, vec![-0.8, 1.0]);
    }

    #[test]
    fn save_and_load(){
        let dir = std::env::temp_dir().join("volcano_corpus_test.json");
        let c = corpus(3);
        c.save(&dir).unwrap();
        let c2 = MetaCorpus::load(&dir).unwrap();
        assert_eq!(c2.len(), 3);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn ranknet_trains_and_discriminates() {
        let c = corpus(40);
        let arms = vec!["a".to_string(), "b".to_string()];
        let mut rng = Rng::new(0);
        let net = c
            .train_ranknet(&arms, "balanced_accuracy", "none", &mut rng)
            .expect("enough data");
        assert_eq!(net.top_k(&[0.8, 1.0], 1), vec![0]);
        assert_eq!(net.top_k(&[-0.8, 1.0], 1), vec![1]);
    }

    #[test]
    fn leave_one_out_excludes_target() {
        let mut c = corpus(2);
        // poison record for the excluded task: if used, ranking flips
        let mut bad = record("target", 0.8);
        bad.arm_scores.insert("a".into(), -10.0);
        c.push(bad);
        let arms = vec!["a".to_string(), "b".to_string()];
        let mut rng = Rng::new(1);
        // with only 2 clean records there are few triples: accept None
        // or a net; if a net exists it must not have learned a == bad
        if let Some(net) =
            c.train_ranknet(&arms, "balanced_accuracy", "target",
                            &mut rng)
        {
            let top = net.top_k(&[0.8, 1.0], 1);
            assert_eq!(top, vec![0]);
        }
    }

    #[test]
    fn rgpe_for_leaf_checks_dim_and_metric() {
        let c = corpus(5);
        assert!(c.rgpe_for_leaf("hp|a", "balanced_accuracy", "x", 1, 0)
            .is_some());
        assert!(c.rgpe_for_leaf("hp|a", "mse", "x", 1, 0).is_none());
        assert!(c.rgpe_for_leaf("hp|a", "balanced_accuracy", "x", 7, 0)
            .is_none());
        assert!(c.rgpe_for_leaf("nope", "balanced_accuracy", "x", 1, 0)
            .is_none());
    }
}
