//! Dataset meta-features (the `h_D` extractor of §5.1): a fixed-length
//! numeric description of a dataset used by RankNet arm pruning and
//! for matching prior tasks. Kept cheap — a single pass plus one
//! covariance probe on a subsample.

use crate::data::dataset::{Dataset, Task};

pub const META_DIM: usize = 12;

/// Extract the 12-dim meta-feature vector.
pub fn meta_features(ds: &Dataset) -> Vec<f64> {
    let n = ds.n.max(1);
    let d = ds.d.max(1);
    let rows: Vec<usize> = (0..n.min(512)).collect();
    let (mean, std) = ds.col_stats(&rows);

    // label statistics
    let (class_entropy, imbalance, n_classes) = match ds.task {
        Task::Classification { n_classes } => {
            let counts = ds.class_counts();
            let total: usize = counts.iter().sum();
            let mut h = 0.0;
            let mut max_c = 0usize;
            let mut min_c = usize::MAX;
            for &c in &counts {
                if c > 0 {
                    let p = c as f64 / total.max(1) as f64;
                    h -= p * p.ln();
                    max_c = max_c.max(c);
                    min_c = min_c.min(c);
                }
            }
            let imb = if min_c == 0 || min_c == usize::MAX {
                1.0
            } else {
                max_c as f64 / min_c as f64
            };
            (h, imb, n_classes as f64)
        }
        Task::Regression => {
            let ys: Vec<f64> =
                rows.iter().map(|&i| ds.y[i] as f64).collect();
            let v = crate::util::stats::variance(&ys);
            (v.ln_1p(), 1.0, 0.0)
        }
    };

    // feature statistics
    let mean_abs_mean = mean.iter().map(|m| m.abs()).sum::<f64>()
        / d as f64;
    let std_spread = {
        let max = std.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
        let min = std.iter().cloned().fold(f64::INFINITY, f64::min)
            .max(1e-12);
        (max / min).ln()
    };
    // mean |corr(feature, label)| — signal strength probe
    let mut corr_sum = 0.0;
    let ys: Vec<f64> = rows.iter().map(|&i| ds.y[i] as f64).collect();
    let y_mean = crate::util::stats::mean(&ys);
    let y_var: f64 = ys.iter().map(|y| (y - y_mean).powi(2)).sum();
    for j in 0..d {
        let mut num = 0.0;
        let mut xv = 0.0;
        let c = ds.col(j);
        for (&i, y) in rows.iter().zip(&ys) {
            let x = c[i] as f64 - mean[j];
            num += x * (y - y_mean);
            xv += x * x;
        }
        if xv > 0.0 && y_var > 0.0 {
            corr_sum += (num / (xv.sqrt() * y_var.sqrt())).abs();
        }
    }
    let mean_abs_corr = corr_sum / d as f64;

    // skewness proxy: mean |(mean - median)| / std over a few columns
    let mut skew = 0.0;
    let probe_cols = d.min(8);
    for j in 0..probe_cols {
        let c = ds.col(j);
        let xs: Vec<f64> =
            rows.iter().map(|&i| c[i] as f64).collect();
        let med = crate::util::stats::median(&xs);
        skew += (mean[j] - med).abs() / std[j].max(1e-9);
    }
    skew /= probe_cols.max(1) as f64;

    vec![
        (n as f64).ln(),
        (d as f64).ln(),
        n_classes,
        class_entropy,
        imbalance.ln(),
        if ds.task.is_classification() { 1.0 } else { 0.0 },
        (n as f64 / d as f64).ln(),
        mean_abs_mean.ln_1p(),
        std_spread,
        mean_abs_corr,
        skew,
        1.0, // bias term
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn mk(gen: GenKind, task: Task, imb: f64, wild: bool) -> Dataset {
        generate(&Profile {
            name: "mf".into(),
            task,
            gen,
            n: 300,
            d: 8,
            noise: 0.05,
            imbalance: imb,
            redundant: 1,
            wild_scales: wild,
            seed: 4,
        })
    }

    #[test]
    fn dimension_is_stable() {
        let ds = mk(GenKind::Blobs { sep: 1.0 },
                    Task::Classification { n_classes: 3 }, 1.0, false);
        assert_eq!(meta_features(&ds).len(), META_DIM);
        let dr = mk(GenKind::Friedman1, Task::Regression, 1.0, false);
        assert_eq!(meta_features(&dr).len(), META_DIM);
    }

    #[test]
    fn imbalance_is_reflected() {
        let bal = mk(GenKind::Blobs { sep: 2.0 },
                     Task::Classification { n_classes: 2 }, 1.0, false);
        let imb = mk(GenKind::Blobs { sep: 2.0 },
                     Task::Classification { n_classes: 2 }, 20.0, false);
        assert!(meta_features(&imb)[4] > meta_features(&bal)[4] + 0.5);
    }

    #[test]
    fn scale_spread_is_reflected() {
        let uni = mk(GenKind::Blobs { sep: 2.0 },
                     Task::Classification { n_classes: 2 }, 1.0, false);
        let wild = mk(GenKind::Blobs { sep: 2.0 },
                      Task::Classification { n_classes: 2 }, 1.0, true);
        assert!(meta_features(&wild)[8] > meta_features(&uni)[8]);
    }

    #[test]
    fn all_features_finite() {
        for gen in [GenKind::Rings, GenKind::Texture,
                    GenKind::NonlinearCls] {
            let ds = mk(gen, Task::Classification { n_classes: 2 },
                        3.0, true);
            assert!(meta_features(&ds).iter().all(|v| v.is_finite()));
        }
    }
}
