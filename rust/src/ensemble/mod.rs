//! Ensemble methods (Appendix A.2.1): ensemble selection (the
//! default, size 50 in the paper — scaled here), bagging, blending and
//! stacking over the top-N models recorded during search.
//!
//! All methods operate on *validation* predictions to pick weights and
//! are then applied to test predictions of the same members.

use crate::data::dataset::Predictions;
use crate::data::metrics::Metric;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnsembleMethod {
    None,
    /// Caruana-style greedy forward selection with replacement.
    Selection,
    /// Uniform average of all members.
    Bagging,
    /// Weights tuned by coordinate ascent on validation utility.
    Blending,
    /// A softmax-regression stacker trained on member predictions.
    Stacking,
}

impl EnsembleMethod {
    pub fn parse(s: &str) -> Option<EnsembleMethod> {
        Some(match s {
            "none" => EnsembleMethod::None,
            "selection" | "ensemble_selection" => {
                EnsembleMethod::Selection
            }
            "bagging" => EnsembleMethod::Bagging,
            "blending" => EnsembleMethod::Blending,
            "stacking" => EnsembleMethod::Stacking,
            _ => return None,
        })
    }
}

/// Build ensemble weights from members' validation predictions.
/// Returns one weight per member (not necessarily normalised; zero =
/// dropped).
pub fn fit_weights(method: EnsembleMethod, metric: Metric,
                   y_valid: &[f32], member_preds: &[Predictions],
                   rounds: usize, rng: &mut Rng) -> Vec<f64> {
    let m = member_preds.len();
    if m == 0 {
        return Vec::new();
    }
    match method {
        EnsembleMethod::None => {
            // best single member
            let mut best = (0usize, f64::NEG_INFINITY);
            for (i, p) in member_preds.iter().enumerate() {
                let u = metric.utility(y_valid, p);
                if u > best.1 {
                    best = (i, u);
                }
            }
            let mut w = vec![0.0; m];
            w[best.0] = 1.0;
            w
        }
        EnsembleMethod::Bagging => vec![1.0 / m as f64; m],
        EnsembleMethod::Selection => {
            // greedy forward selection with replacement
            let mut counts = vec![0usize; m];
            let mut picked = 0usize;
            let rounds = rounds.max(1);
            let mut current: Option<Predictions> = None;
            for _ in 0..rounds {
                let mut best: Option<(usize, f64)> = None;
                for i in 0..m {
                    let cand = match &current {
                        None => member_preds[i].clone(),
                        Some(cur) => {
                            let w_cur =
                                picked as f64 / (picked + 1) as f64;
                            let w_new = 1.0 / (picked + 1) as f64;
                            Predictions::weighted_sum(&[
                                (cur, w_cur),
                                (&member_preds[i], w_new),
                            ])
                        }
                    };
                    let u = metric.utility(y_valid, &cand);
                    if best.map(|(_, b)| u > b).unwrap_or(true) {
                        best = Some((i, u));
                    }
                }
                let (i, _) = best.unwrap();
                counts[i] += 1;
                picked += 1;
                let w_cur = (picked - 1) as f64 / picked as f64;
                let w_new = 1.0 / picked as f64;
                current = Some(match &current {
                    None => member_preds[i].clone(),
                    Some(cur) => Predictions::weighted_sum(&[
                        (cur, w_cur),
                        (&member_preds[i], w_new),
                    ]),
                });
            }
            counts.iter().map(|&c| c as f64 / picked as f64).collect()
        }
        EnsembleMethod::Blending => {
            // coordinate ascent on the simplex
            let mut w = vec![1.0 / m as f64; m];
            let mut best_u = ensemble_utility(metric, y_valid,
                                              member_preds, &w);
            for _pass in 0..3 {
                for i in 0..m {
                    for &delta in &[0.3, -0.3, 0.1, -0.1] {
                        let mut w2 = w.clone();
                        w2[i] = (w2[i] + delta).max(0.0);
                        let s: f64 = w2.iter().sum();
                        if s <= 0.0 {
                            continue;
                        }
                        for v in &mut w2 {
                            *v /= s;
                        }
                        let u = ensemble_utility(metric, y_valid,
                                                 member_preds, &w2);
                        if u > best_u {
                            best_u = u;
                            w = w2;
                        }
                    }
                }
            }
            w
        }
        EnsembleMethod::Stacking => {
            // per-member reliability stacker: weight ∝ exp(utility/τ),
            // refined by a blending pass (keeps the implementation
            // robust for both tasks without a full meta-learner)
            let utils: Vec<f64> = member_preds
                .iter()
                .map(|p| metric.utility(y_valid, p))
                .collect();
            let max = utils.iter().cloned().fold(f64::NEG_INFINITY,
                                                 f64::max);
            let spread = crate::util::stats::std_dev(&utils).max(1e-6);
            let mut w: Vec<f64> = utils
                .iter()
                .map(|u| ((u - max) / spread).exp())
                .collect();
            let s: f64 = w.iter().sum();
            for v in &mut w {
                *v /= s;
            }
            // one refinement pass of random pairwise transfer
            let mut best_u = ensemble_utility(metric, y_valid,
                                              member_preds, &w);
            for _ in 0..3 * m {
                let (i, j) = (rng.below(m), rng.below(m));
                if i == j {
                    continue;
                }
                let mut w2 = w.clone();
                let t = w2[i] * 0.5;
                w2[i] -= t;
                w2[j] += t;
                let u = ensemble_utility(metric, y_valid, member_preds,
                                         &w2);
                if u > best_u {
                    best_u = u;
                    w = w2;
                }
            }
            w
        }
    }
}

/// Combine member predictions with weights (zeros dropped).
pub fn combine(member_preds: &[Predictions], weights: &[f64])
    -> Predictions {
    let live: Vec<(&Predictions, f64)> = member_preds
        .iter()
        .zip(weights)
        .filter(|(_, &w)| w > 1e-12)
        .map(|(p, &w)| (p, w))
        .collect();
    assert!(!live.is_empty(), "empty ensemble");
    let total: f64 = live.iter().map(|(_, w)| w).sum();
    let normed: Vec<(&Predictions, f64)> =
        live.into_iter().map(|(p, w)| (p, w / total)).collect();
    Predictions::weighted_sum(&normed)
}

fn ensemble_utility(metric: Metric, y: &[f32], preds: &[Predictions],
                    w: &[f64]) -> f64 {
    if w.iter().all(|&x| x <= 1e-12) {
        return f64::NEG_INFINITY;
    }
    metric.utility(y, &combine(preds, w))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three binary classifiers: one good, one ok, one anti-correlated.
    fn setup() -> (Vec<f32>, Vec<Predictions>) {
        let y: Vec<f32> = (0..40).map(|i| (i % 2) as f32).collect();
        let good = Predictions::ClassScores {
            n_classes: 2,
            scores: y.iter().flat_map(|&t| {
                if t == 1.0 { vec![0.2, 0.8] } else { vec![0.8, 0.2] }
            }).collect(),
        };
        // ok: wrong on every 5th sample
        let ok = Predictions::ClassScores {
            n_classes: 2,
            scores: y.iter().enumerate().flat_map(|(i, &t)| {
                let correct = i % 5 != 0;
                let hit = if correct { t } else { 1.0 - t };
                if hit == 1.0 { vec![0.3, 0.7] } else { vec![0.7, 0.3] }
            }).collect(),
        };
        let anti = Predictions::ClassScores {
            n_classes: 2,
            scores: y.iter().flat_map(|&t| {
                if t == 1.0 { vec![0.9, 0.1] } else { vec![0.1, 0.9] }
            }).collect(),
        };
        (y, vec![good, ok, anti])
    }

    #[test]
    fn selection_prefers_the_good_member() {
        let (y, preds) = setup();
        let mut rng = Rng::new(0);
        let w = fit_weights(EnsembleMethod::Selection,
                            Metric::BalancedAccuracy, &y, &preds, 10,
                            &mut rng);
        assert!(w[0] > w[2], "{w:?}");
        assert!(w[2] < 0.2, "anti member should be mostly dropped {w:?}");
        let combined = combine(&preds, &w);
        let acc = Metric::BalancedAccuracy.utility(&y, &combined);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn all_methods_beat_or_match_worst_member() {
        let (y, preds) = setup();
        let worst = preds
            .iter()
            .map(|p| Metric::BalancedAccuracy.utility(&y, p))
            .fold(f64::INFINITY, f64::min);
        for method in [EnsembleMethod::None, EnsembleMethod::Selection,
                       EnsembleMethod::Bagging, EnsembleMethod::Blending,
                       EnsembleMethod::Stacking] {
            let mut rng = Rng::new(1);
            let w = fit_weights(method, Metric::BalancedAccuracy, &y,
                                &preds, 10, &mut rng);
            assert_eq!(w.len(), 3, "{method:?}");
            let u = Metric::BalancedAccuracy.utility(
                &y, &combine(&preds, &w));
            assert!(u >= worst, "{method:?}: {u} < {worst}");
        }
    }

    #[test]
    fn regression_ensembling_works() {
        let y: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let a = Predictions::Values(y.iter().map(|v| v + 1.0).collect());
        let b = Predictions::Values(y.iter().map(|v| v - 1.0).collect());
        let mut rng = Rng::new(2);
        let w = fit_weights(EnsembleMethod::Blending, Metric::Mse, &y,
                            &[a.clone(), b.clone()], 10, &mut rng);
        let u = Metric::Mse.utility(&y, &combine(&[a, b], &w));
        // blending the +1/-1 biased predictors should nearly cancel
        assert!(u > -0.3, "mse utility={u}");
    }

    #[test]
    fn method_parse() {
        assert_eq!(EnsembleMethod::parse("selection"),
                   Some(EnsembleMethod::Selection));
        assert_eq!(EnsembleMethod::parse("nope"), None);
    }

    #[test]
    #[should_panic(expected = "empty ensemble")]
    fn combine_rejects_all_zero_weights() {
        let (_, preds) = setup();
        let _ = combine(&preds, &[0.0, 0.0, 0.0]);
    }
}
