//! Bagged tree ensembles: random forest and extra-trees, for both
//! tasks. These are two of the strongest arms in the conditioning
//! block, mirroring their role in auto-sklearn's roster.

use crate::data::dataset::{Dataset, Predictions, Task};
use crate::util::rng::Rng;

use super::tree::{Criterion, Tree, TreeParams};
use super::PREDICT_BLOCK_ROWS;

#[derive(Clone, Debug)]
pub struct ForestParams {
    pub n_estimators: usize,
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    pub max_features: f64,
    pub bootstrap: bool,
    pub criterion: Criterion,
    /// true => extra-trees (random thresholds, no bootstrap default)
    pub extra: bool,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_estimators: 32,
            max_depth: 12,
            min_samples_split: 2,
            min_samples_leaf: 1,
            max_features: 0.7,
            bootstrap: true,
            criterion: Criterion::Gini,
            extra: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Forest {
    trees: Vec<Tree>,
    task: Task,
}

impl Forest {
    pub fn fit(ds: &Dataset, train: &[usize], p: &ForestParams,
               rng: &mut Rng) -> Forest {
        let cls = ds.task.is_classification();
        let k = ds.task.n_classes();
        let y: Vec<f64> = ds.y.iter().map(|&v| v as f64).collect();
        let tp = TreeParams {
            max_depth: p.max_depth,
            min_samples_split: p.min_samples_split,
            min_samples_leaf: p.min_samples_leaf,
            max_features: p.max_features,
            criterion: if cls { p.criterion } else { Criterion::Mse },
            random_thresholds: p.extra,
            n_classes: if cls { k } else { 0 },
        };
        let trees = (0..p.n_estimators.max(1))
            .map(|t| {
                let mut trng = rng.fork(t as u64);
                let rows: Vec<usize> = if p.bootstrap && !p.extra {
                    (0..train.len())
                        .map(|_| train[trng.below(train.len())])
                        .collect()
                } else {
                    train.to_vec()
                };
                Tree::fit_with(|i, j| ds.at(i, j), ds.d, &y, &rows,
                               &tp, &mut trng)
            })
            .collect();
        Forest { trees, task: ds.task }
    }

    pub fn predict(&self, ds: &Dataset, rows: &[usize]) -> Predictions {
        // blocked gather: bounded row-major buffer, each source
        // column streamed once per block (util::kernels)
        let mut block = Vec::new();
        match self.task {
            Task::Classification { n_classes } => {
                let mut scores = vec![0.0f32; rows.len() * n_classes];
                for blo in (0..rows.len()).step_by(PREDICT_BLOCK_ROWS) {
                    let bhi = (blo + PREDICT_BLOCK_ROWS).min(rows.len());
                    ds.gather_rows_rowmajor(&rows[blo..bhi], &mut block);
                    for r in blo..bhi {
                        let buf = &block[(r - blo) * ds.d
                                         ..(r - blo + 1) * ds.d];
                        for t in &self.trees {
                            let dist = t.predict_row(buf);
                            for c in 0..n_classes.min(dist.len()) {
                                scores[r * n_classes + c] += dist[c] as f32;
                            }
                        }
                        let inv = 1.0 / self.trees.len().max(1) as f32;
                        for c in 0..n_classes {
                            scores[r * n_classes + c] *= inv;
                        }
                    }
                }
                Predictions::ClassScores { n_classes, scores }
            }
            Task::Regression => {
                let mut vals = vec![0.0f32; rows.len()];
                for blo in (0..rows.len()).step_by(PREDICT_BLOCK_ROWS) {
                    let bhi = (blo + PREDICT_BLOCK_ROWS).min(rows.len());
                    ds.gather_rows_rowmajor(&rows[blo..bhi], &mut block);
                    for r in blo..bhi {
                        let buf = &block[(r - blo) * ds.d
                                         ..(r - blo + 1) * ds.d];
                        let s: f64 = self
                            .trees
                            .iter()
                            .map(|t| t.predict_row(buf)[0])
                            .sum();
                        vals[r] = (s / self.trees.len().max(1) as f64)
                            as f32;
                    }
                }
                Predictions::Values(vals)
            }
        }
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metrics::{balanced_accuracy, mse};
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn cls_profile(gen: GenKind, k: usize) -> Profile {
        Profile {
            name: "f".into(),
            task: Task::Classification { n_classes: k },
            gen,
            n: 500,
            d: 8,
            noise: 0.02,
            imbalance: 1.0,
            redundant: 2,
            wild_scales: false,
            seed: 21,
        }
    }

    #[test]
    fn forest_beats_chance_on_rings() {
        let ds = generate(&cls_profile(GenKind::Rings, 2));
        let train: Vec<usize> = (0..400).collect();
        let test: Vec<usize> = (400..500).collect();
        let mut rng = Rng::new(0);
        let f = Forest::fit(&ds, &train, &ForestParams::default(),
                            &mut rng);
        let preds = f.predict(&ds, &test);
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let acc = balanced_accuracy(&yt, &preds.argmax_labels());
        assert!(acc > 0.85, "acc={acc}");
    }

    #[test]
    fn extra_trees_work_and_differ() {
        let ds = generate(&cls_profile(GenKind::Checker { cells: 3 }, 2));
        let train: Vec<usize> = (0..400).collect();
        let test: Vec<usize> = (400..500).collect();
        let mut rng = Rng::new(1);
        let p = ForestParams { extra: true, ..Default::default() };
        let f = Forest::fit(&ds, &train, &p, &mut rng);
        let preds = f.predict(&ds, &test);
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        assert!(balanced_accuracy(&yt, &preds.argmax_labels()) > 0.8);
    }

    #[test]
    fn regression_forest_fits_friedman() {
        let p = Profile {
            name: "fr".into(),
            task: Task::Regression,
            gen: GenKind::Friedman1,
            n: 600,
            d: 8,
            noise: 0.2,
            imbalance: 1.0,
            redundant: 0,
            wild_scales: false,
            seed: 3,
        };
        let ds = generate(&p);
        let train: Vec<usize> = (0..480).collect();
        let test: Vec<usize> = (480..600).collect();
        let mut rng = Rng::new(2);
        let f = Forest::fit(&ds, &train, &ForestParams {
            n_estimators: 48,
            ..Default::default()
        }, &mut rng);
        let preds = f.predict(&ds, &test);
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let err = mse(&yt, preds.values());
        // friedman1 var ~ 24; a fitted forest should be well below it
        assert!(err < 12.0, "mse={err}");
    }

    #[test]
    fn single_tree_forest_is_deterministic_per_seed() {
        let ds = generate(&cls_profile(GenKind::Blobs { sep: 2.0 }, 3));
        let train: Vec<usize> = (0..300).collect();
        let p = ForestParams { n_estimators: 1, ..Default::default() };
        let f1 = Forest::fit(&ds, &train, &p, &mut Rng::new(9));
        let f2 = Forest::fit(&ds, &train, &p, &mut Rng::new(9));
        let rows: Vec<usize> = (300..350).collect();
        let (a, b) = (f1.predict(&ds, &rows), f2.predict(&ds, &rows));
        assert_eq!(a.argmax_labels(), b.argmax_labels());
    }
}
