//! PJRT-backed algorithm arms.
//!
//! These arms' training loops are the AOT-compiled JAX programs whose
//! inner step is the L1 Pallas kernel (see python/compile/): logistic
//! regression and linear SVM (glm_softmax / glm_hinge), MLPs
//! (mlp_*_h{16,64}), ridge / lasso / linear SVR (glm_identity /
//! glm_huber) and KNN (knn_cls / knn_reg).
//!
//! Marshaling protocol (one artifact serves the whole subspace):
//! * datasets are column-truncated/padded to the canonical D and
//!   row-subsampled/padded to N_TRAIN with a row mask;
//! * features (and regression targets) are standardised on the
//!   training subsample for GD conditioning — the fitted model stores
//!   the canonicalisation and applies it natively at predict time;
//! * hyper-parameters travel as runtime inputs (hypers tensor + the
//!   per-step lr schedule, which also encodes cosine annealing and the
//!   multi-fidelity step budget).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::data::dataset::{Dataset, Predictions, Task};
use crate::runtime::{Constants, Input, Runtime};
use crate::space::{Config, ConfigSpace};
use crate::util::rng::Rng;

use super::{fidelity_rows, Algorithm, EvalContext, FittedModel};

// ====================================================================
// Canonicalisation
// ====================================================================

/// Fitted feature canonicalisation: column selection + standardisation
/// + (regression) target standardisation.
#[derive(Clone, Debug)]
struct Canon {
    cols: Vec<usize>,
    mean: Vec<f32>,
    inv_std: Vec<f32>,
    y_mean: f32,
    y_std: f32,
}

impl Canon {
    fn fit(ds: &Dataset, rows: &[usize], d_canon: usize,
           standardize_y: bool) -> Canon {
        let cols: Vec<usize> = (0..ds.d.min(d_canon)).collect();
        let (mean64, std64) = ds.col_stats(rows);
        let mean: Vec<f32> = cols.iter().map(|&j| mean64[j] as f32)
            .collect();
        let inv_std: Vec<f32> = cols
            .iter()
            .map(|&j| 1.0f32 / (std64[j] as f32).max(1e-6))
            .collect();
        let (y_mean, y_std) = if standardize_y {
            let ys: Vec<f64> = rows.iter().map(|&i| ds.y[i] as f64)
                .collect();
            let m = crate::util::stats::mean(&ys);
            let s = crate::util::stats::std_dev(&ys).max(1e-6);
            (m as f32, s as f32)
        } else {
            (0.0, 1.0)
        };
        Canon { cols, mean, inv_std, y_mean, y_std }
    }

    /// Write the canonicalised row into `out` (length d_canon, padded
    /// with zeros).
    fn row_into(&self, row: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        for (k, &j) in self.cols.iter().enumerate() {
            out[k] = (row[j] - self.mean[k]) * self.inv_std[k];
        }
    }
}

/// Build the (x, y, mask, cls_mask) canonical training tensors.
struct TrainTensors {
    x: Vec<f32>,
    y: Vec<f32>,
    mask: Vec<f32>,
    cmask: Vec<f32>,
    c: usize,
}

fn train_tensors(ds: &Dataset, rows: &[usize], canon: &Canon,
                 consts: &Constants, classification: bool)
    -> TrainTensors {
    let n = consts.n_train;
    let d = consts.d;
    let c = if classification { consts.c } else { consts.c_reg };
    let m = rows.len().min(n);
    let mut x = vec![0.0f32; n * d];
    let mut y = vec![0.0f32; n * c];
    let mut mask = vec![0.0f32; n];
    let mut rbuf = Vec::with_capacity(ds.d);
    for (r, &i) in rows.iter().take(m).enumerate() {
        ds.gather_row(i, &mut rbuf);
        canon.row_into(&rbuf, &mut x[r * d..(r + 1) * d]);
        if classification {
            let cls = (ds.y[i] as usize).min(c - 1);
            y[r * c + cls] = 1.0;
        } else {
            y[r * c] = (ds.y[i] - canon.y_mean) / canon.y_std;
        }
        mask[r] = 1.0;
    }
    let mut cmask = vec![0.0f32; c];
    if classification {
        let k = ds.task.n_classes().min(c);
        cmask[..k].fill(1.0);
    } else {
        cmask.fill(1.0);
    }
    TrainTensors { x, y, mask, cmask, c }
}

/// Per-step learning-rate schedule; also encodes the multi-fidelity
/// step budget (zeros beyond the active prefix).
fn lr_schedule(kind: &str, t: usize, fidelity: f64) -> Vec<f32> {
    let active = ((t as f64) * fidelity.clamp(0.05, 1.0)).ceil() as usize;
    let active = active.clamp(1, t);
    (0..t)
        .map(|i| {
            if i >= active {
                return 0.0;
            }
            match kind {
                "cosine" => {
                    // cosine annealing — the paper's motivating
                    // "unsupported scheduler" example
                    0.5 * (1.0
                        + (std::f64::consts::PI * i as f64
                            / active as f64).cos()) as f32
                }
                "step" => if i < active / 2 { 1.0 } else { 0.1 },
                _ => 1.0,
            }
        })
        .collect()
}

fn require_rt<'a>(ctx: &EvalContext<'a>) -> Result<&'a Runtime> {
    ctx.runtime.ok_or_else(|| {
        anyhow!("PJRT runtime unavailable (run `make artifacts`)")
    })
}

// ====================================================================
// GLM family (logistic / linear SVC / ridge / lasso / linear SVR)
// ====================================================================

struct GlmSpec {
    name: &'static str,
    artifact: &'static str,
    classification: bool,
    /// (uses_l2, uses_l1, uses_delta)
    reg_knobs: (bool, bool, bool),
    cost: f64,
}

pub struct GlmAlgo {
    spec: GlmSpec,
}

struct FittedGlm {
    w: Vec<f32>, // d x c
    b: Vec<f32>, // c
    d: usize,
    c: usize,
    canon: Canon,
    task: Task,
}

impl FittedModel for FittedGlm {
    fn predict(&self, ds: &Dataset, rows: &[usize],
               _ctx: &mut EvalContext) -> Predictions {
        let mut xrow = vec![0.0f32; self.d];
        let mut rbuf = Vec::with_capacity(ds.d);
        match self.task {
            Task::Classification { n_classes } => {
                let mut scores = vec![0.0f32; rows.len() * n_classes];
                for (r, &i) in rows.iter().enumerate() {
                    ds.gather_row(i, &mut rbuf);
                    self.canon.row_into(&rbuf, &mut xrow);
                    for cc in 0..n_classes.min(self.c) {
                        let mut s = self.b[cc];
                        for j in 0..self.d {
                            s += xrow[j] * self.w[j * self.c + cc];
                        }
                        scores[r * n_classes + cc] = s;
                    }
                }
                Predictions::ClassScores { n_classes, scores }
            }
            Task::Regression => {
                let vals = rows
                    .iter()
                    .map(|&i| {
                        ds.gather_row(i, &mut rbuf);
                        self.canon.row_into(&rbuf, &mut xrow);
                        let mut s = self.b[0];
                        for j in 0..self.d {
                            s += xrow[j] * self.w[j * self.c];
                        }
                        s * self.canon.y_std + self.canon.y_mean
                    })
                    .collect();
                Predictions::Values(vals)
            }
        }
    }
}

impl Algorithm for GlmAlgo {
    fn name(&self) -> &str {
        self.spec.name
    }
    fn space(&self) -> ConfigSpace {
        let mut cs = ConfigSpace::new()
            .log_float("lr", 1e-3, 1.5, 0.3)
            .cat("schedule", &["constant", "cosine", "step"], "constant");
        let (l2, l1, delta) = self.spec.reg_knobs;
        if l2 {
            cs = cs.log_float("l2", 1e-7, 1.0, 1e-4);
        }
        if l1 {
            cs = cs.log_float("l1", 1e-7, 0.3, 1e-4);
        }
        if delta {
            cs = cs.float("epsilon", 0.05, 2.0, 0.5);
        }
        cs
    }
    fn supports(&self, task: Task) -> bool {
        match task {
            Task::Classification { n_classes } => {
                self.spec.classification && n_classes <= 8
            }
            Task::Regression => !self.spec.classification,
        }
    }
    fn cost_hint(&self) -> f64 {
        self.spec.cost
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rt = require_rt(ctx)?;
        let consts = rt.constants().clone();
        let mut rows = train.to_vec();
        if rows.len() > consts.n_train {
            rows = fidelity_rows(&rows,
                                 consts.n_train as f64 / rows.len() as f64,
                                 &mut ctx.rng)
                .into_owned();
        }
        let cls = self.spec.classification;
        let canon = Canon::fit(ds, &rows, consts.d, !cls);
        let t = train_tensors(ds, &rows, &canon, &consts, cls);
        let sched = lr_schedule(cfg.str_or("schedule", "constant"),
                                consts.t_steps, ctx.fidelity);
        let hypers = vec![
            cfg.f64_or("lr", 0.3) as f32,
            cfg.f64_or("l2", 0.0) as f32,
            cfg.f64_or("l1", 0.0) as f32,
            cfg.f64_or("epsilon", 0.5) as f32,
        ];
        let xv = vec![0.0f32; consts.n_val * consts.d];
        let out = rt.execute(self.spec.artifact, &[
            Input::F32(t.x, vec![consts.n_train, consts.d]),
            Input::F32(t.y, vec![consts.n_train, t.c]),
            Input::F32(t.mask, vec![consts.n_train, 1]),
            Input::F32(t.cmask, vec![1, t.c]),
            Input::F32(xv, vec![consts.n_val, consts.d]),
            Input::F32(sched, vec![consts.t_steps]),
            Input::F32(hypers, vec![1, 4]),
        ])?;
        if out.len() != 3 {
            bail!("{}: expected 3 outputs", self.spec.artifact);
        }
        let w = out[1].data.clone();
        let b = out[2].data.clone();
        Ok(Box::new(FittedGlm {
            w,
            b,
            d: consts.d,
            c: t.c,
            canon,
            task: ds.task,
        }))
    }
}

// ====================================================================
// MLP family
// ====================================================================

pub struct MlpAlgo {
    classification: bool,
}

struct FittedMlp {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    d: usize,
    h: usize,
    c: usize,
    canon: Canon,
    task: Task,
}

impl FittedModel for FittedMlp {
    fn predict(&self, ds: &Dataset, rows: &[usize],
               _ctx: &mut EvalContext) -> Predictions {
        let mut xrow = vec![0.0f32; self.d];
        let mut rbuf = Vec::with_capacity(ds.d);
        let mut hid = vec![0.0f32; self.h];
        let mut score_of = |row: &[f32], out: &mut [f32]| {
            for (j, o) in out.iter_mut().enumerate() {
                *o = self.b2[j];
            }
            for hidx in 0..self.h {
                let mut z = self.b1[hidx];
                for j in 0..self.d {
                    z += row[j] * self.w1[j * self.h + hidx];
                }
                hid[hidx] = z.max(0.0);
            }
            for (j, o) in out.iter_mut().enumerate() {
                for hidx in 0..self.h {
                    *o += hid[hidx] * self.w2[hidx * self.c + j];
                }
            }
        };
        match self.task {
            Task::Classification { n_classes } => {
                let mut scores = vec![0.0f32; rows.len() * n_classes];
                let mut full = vec![0.0f32; self.c];
                for (r, &i) in rows.iter().enumerate() {
                    ds.gather_row(i, &mut rbuf);
                    self.canon.row_into(&rbuf, &mut xrow);
                    score_of(&xrow, &mut full);
                    scores[r * n_classes..(r + 1) * n_classes]
                        .copy_from_slice(&full[..n_classes]);
                }
                Predictions::ClassScores { n_classes, scores }
            }
            Task::Regression => {
                let mut out1 = vec![0.0f32; 1];
                let vals = rows
                    .iter()
                    .map(|&i| {
                        ds.gather_row(i, &mut rbuf);
                        self.canon.row_into(&rbuf, &mut xrow);
                        score_of(&xrow, &mut out1);
                        out1[0] * self.canon.y_std + self.canon.y_mean
                    })
                    .collect();
                Predictions::Values(vals)
            }
        }
    }
}

impl Algorithm for MlpAlgo {
    fn name(&self) -> &str {
        if self.classification { "mlp" } else { "mlp_regressor" }
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new()
            .cat("hidden", &["16", "64"], "16")
            .log_float("lr", 1e-3, 1.0, 0.1)
            .log_float("l2", 1e-7, 1e-2, 1e-5)
            .float("momentum", 0.3, 0.99, 0.9)
            .cat("schedule", &["constant", "cosine", "step"], "constant")
    }
    fn supports(&self, task: Task) -> bool {
        match task {
            Task::Classification { n_classes } => {
                self.classification && n_classes <= 8
            }
            Task::Regression => !self.classification,
        }
    }
    fn cost_hint(&self) -> f64 {
        2.5
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rt = require_rt(ctx)?;
        let consts = rt.constants().clone();
        let h: usize = cfg.str_or("hidden", "16").parse().unwrap_or(16);
        if !consts.mlp_hidden.contains(&h) {
            bail!("no MLP artifact with hidden={h}");
        }
        let artifact = if self.classification {
            format!("mlp_softmax_h{h}")
        } else {
            format!("mlp_identity_h{h}")
        };
        let mut rows = train.to_vec();
        if rows.len() > consts.n_train {
            rows = fidelity_rows(&rows,
                                 consts.n_train as f64 / rows.len() as f64,
                                 &mut ctx.rng)
                .into_owned();
        }
        let canon = Canon::fit(ds, &rows, consts.d, !self.classification);
        let t = train_tensors(ds, &rows, &canon, &consts,
                              self.classification);
        let sched = lr_schedule(cfg.str_or("schedule", "constant"),
                                consts.t_steps, ctx.fidelity);
        let hypers = vec![
            cfg.f64_or("lr", 0.1) as f32,
            cfg.f64_or("l2", 1e-5) as f32,
            cfg.f64_or("momentum", 0.9) as f32,
            0.0f32,
        ];
        let seed = vec![ctx.rng.next_u64() as i32];
        let xv = vec![0.0f32; consts.n_val * consts.d];
        let out = rt.execute(&artifact, &[
            Input::F32(t.x, vec![consts.n_train, consts.d]),
            Input::F32(t.y, vec![consts.n_train, t.c]),
            Input::F32(t.mask, vec![consts.n_train, 1]),
            Input::F32(t.cmask, vec![1, t.c]),
            Input::F32(xv, vec![consts.n_val, consts.d]),
            Input::F32(sched, vec![consts.t_steps]),
            Input::F32(hypers, vec![1, 4]),
            Input::I32(seed, vec![1]),
        ])?;
        if out.len() != 5 {
            bail!("{artifact}: expected 5 outputs");
        }
        Ok(Box::new(FittedMlp {
            w1: out[1].data.clone(),
            b1: out[2].data.clone(),
            w2: out[3].data.clone(),
            b2: out[4].data.clone(),
            d: consts.d,
            h,
            c: t.c,
            canon,
            task: ds.task,
        }))
    }
}

// ====================================================================
// KNN
// ====================================================================

pub struct KnnAlgo {
    classification: bool,
}

struct FittedKnn {
    /// Canonicalised padded train tensors kept for query-time calls.
    x: Vec<f32>,
    y: Vec<f32>,
    mask: Vec<f32>,
    c: usize,
    k: usize,
    distance_weighted: bool,
    canon: Canon,
    task: Task,
    artifact: &'static str,
}

impl FittedModel for FittedKnn {
    fn predict(&self, ds: &Dataset, rows: &[usize],
               ctx: &mut EvalContext) -> Predictions {
        let rt = match ctx.runtime {
            Some(rt) => rt,
            None => panic!("KNN predict requires the PJRT runtime"),
        };
        let consts = rt.constants();
        let (nq, d, kmax) = (consts.n_val, consts.d, consts.k_max);
        let mut xrow = vec![0.0f32; d];
        let mut rbuf = Vec::with_capacity(ds.d);
        let mut all_scores: Vec<f32> = Vec::new();
        let k_live = match self.task {
            Task::Classification { n_classes } => n_classes,
            Task::Regression => 1,
        };
        for chunk in rows.chunks(nq) {
            let mut xq = vec![0.0f32; nq * d];
            for (r, &i) in chunk.iter().enumerate() {
                ds.gather_row(i, &mut rbuf);
                self.canon.row_into(&rbuf, &mut xrow);
                xq[r * d..(r + 1) * d].copy_from_slice(&xrow);
            }
            let out = rt
                .execute(self.artifact, &[
                    Input::F32(self.x.clone(),
                               vec![consts.n_train, d]),
                    Input::F32(self.y.clone(),
                               vec![consts.n_train, self.c]),
                    Input::F32(self.mask.clone(),
                               vec![consts.n_train, 1]),
                    Input::F32(xq, vec![nq, d]),
                ])
                .expect("knn execute");
            let dists = &out[0].data; // (nq, kmax)
            let neigh = &out[1].data; // (nq, kmax, c)
            for (r, _) in chunk.iter().enumerate() {
                let mut acc = vec![0.0f64; k_live];
                let mut wsum = 0.0f64;
                for kk in 0..self.k.min(kmax) {
                    let w = if self.distance_weighted {
                        1.0 / (dists[r * kmax + kk] as f64).max(1e-6)
                    } else {
                        1.0
                    };
                    wsum += w;
                    for cc in 0..k_live.min(self.c) {
                        acc[cc] += w
                            * neigh[(r * kmax + kk) * self.c + cc] as f64;
                    }
                }
                for a in &mut acc {
                    *a /= wsum.max(1e-12);
                }
                all_scores.extend(acc.iter().map(|&v| v as f32));
            }
        }
        match self.task {
            Task::Classification { n_classes } => {
                Predictions::ClassScores { n_classes, scores: all_scores }
            }
            Task::Regression => Predictions::Values(
                all_scores
                    .iter()
                    .map(|&v| v * self.canon.y_std + self.canon.y_mean)
                    .collect(),
            ),
        }
    }
}

impl Algorithm for KnnAlgo {
    fn name(&self) -> &str {
        if self.classification { "knn" } else { "knn_regressor" }
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new()
            .int("k", 1, 25, 5)
            .cat("weights", &["uniform", "distance"], "uniform")
    }
    fn supports(&self, task: Task) -> bool {
        match task {
            Task::Classification { n_classes } => {
                self.classification && n_classes <= 8
            }
            Task::Regression => !self.classification,
        }
    }
    fn cost_hint(&self) -> f64 {
        1.5
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rt = require_rt(ctx)?;
        let consts = rt.constants().clone();
        let mut rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        if rows.len() > consts.n_train {
            rows.to_mut().truncate(consts.n_train);
        }
        let canon = Canon::fit(ds, &rows, consts.d, !self.classification);
        let t = train_tensors(ds, &rows, &canon, &consts,
                              self.classification);
        // regression targets standardised like GLM for consistency
        Ok(Box::new(FittedKnn {
            x: t.x,
            y: t.y,
            mask: t.mask,
            c: t.c,
            k: cfg.usize_or("k", 5).clamp(1, consts.k_max),
            distance_weighted: cfg.str_or("weights", "uniform")
                == "distance",
            canon,
            task: ds.task,
            artifact: if self.classification { "knn_cls" }
                      else { "knn_reg" },
        }))
    }
}

// ====================================================================
// Roster
// ====================================================================

pub fn pjrt_roster(task: Task) -> Vec<Arc<dyn Algorithm>> {
    if task.is_classification() {
        vec![
            Arc::new(GlmAlgo {
                spec: GlmSpec {
                    name: "logistic_regression",
                    artifact: "glm_softmax",
                    classification: true,
                    reg_knobs: (true, true, false),
                    cost: 1.0,
                },
            }),
            Arc::new(GlmAlgo {
                spec: GlmSpec {
                    name: "linear_svc",
                    artifact: "glm_hinge",
                    classification: true,
                    reg_knobs: (true, false, false),
                    cost: 1.0,
                },
            }),
            Arc::new(MlpAlgo { classification: true }),
            Arc::new(KnnAlgo { classification: true }),
        ]
    } else {
        vec![
            Arc::new(GlmAlgo {
                spec: GlmSpec {
                    name: "ridge",
                    artifact: "glm_identity",
                    classification: false,
                    reg_knobs: (true, false, false),
                    cost: 1.0,
                },
            }),
            Arc::new(GlmAlgo {
                spec: GlmSpec {
                    name: "lasso",
                    artifact: "glm_identity",
                    classification: false,
                    reg_knobs: (false, true, false),
                    cost: 1.0,
                },
            }),
            Arc::new(GlmAlgo {
                spec: GlmSpec {
                    name: "linear_svr",
                    artifact: "glm_huber",
                    classification: false,
                    reg_knobs: (true, false, true),
                    cost: 1.0,
                },
            }),
            Arc::new(MlpAlgo { classification: false }),
            Arc::new(KnnAlgo { classification: false }),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metrics::{balanced_accuracy, mse, Metric};
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        // also skips when built without the `pjrt` feature
        Runtime::new(&dir).ok()
    }

    fn cls_ds() -> Dataset {
        generate(&Profile {
            name: "pj".into(),
            task: Task::Classification { n_classes: 3 },
            gen: GenKind::Blobs { sep: 2.0 },
            n: 400,
            d: 10,
            noise: 0.02,
            imbalance: 1.0,
            redundant: 1,
            wild_scales: true, // canonicalisation must handle this
            seed: 13,
        })
    }

    fn reg_ds() -> Dataset {
        generate(&Profile {
            name: "pjr".into(),
            task: Task::Regression,
            gen: GenKind::LinearReg { informative: 5 },
            n: 400,
            d: 10,
            noise: 0.3,
            imbalance: 1.0,
            redundant: 0,
            wild_scales: true,
            seed: 14,
        })
    }

    #[test]
    fn all_pjrt_cls_arms_learn_blobs() {
        let Some(rt) = runtime() else { return };
        let ds = cls_ds();
        let train: Vec<usize> = (0..320).collect();
        let test: Vec<usize> = (320..400).collect();
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        for algo in pjrt_roster(ds.task) {
            let mut ctx = EvalContext::new(Some(&rt), 5);
            let cfg = algo.space().default_config();
            let m = algo.fit(&ds, &train, &cfg, &mut ctx)
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
            let p = m.predict(&ds, &test, &mut ctx);
            let acc = balanced_accuracy(&yt, &p.argmax_labels());
            assert!(acc > 0.8, "{} acc={acc}", algo.name());
        }
    }

    #[test]
    fn all_pjrt_reg_arms_beat_mean_predictor() {
        let Some(rt) = runtime() else { return };
        let ds = reg_ds();
        let train: Vec<usize> = (0..320).collect();
        let test: Vec<usize> = (320..400).collect();
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let mean: f32 = yt.iter().sum::<f32>() / yt.len() as f32;
        let base = mse(&yt, &vec![mean; yt.len()]);
        for algo in pjrt_roster(ds.task) {
            let mut ctx = EvalContext::new(Some(&rt), 6);
            let cfg = algo.space().default_config();
            let m = algo.fit(&ds, &train, &cfg, &mut ctx)
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
            let p = m.predict(&ds, &test, &mut ctx);
            let err = mse(&yt, p.values());
            assert!(err < base, "{}: {err} !< {base}", algo.name());
        }
    }

    #[test]
    fn hyperparameters_change_outcomes() {
        let Some(rt) = runtime() else { return };
        let ds = cls_ds();
        let train: Vec<usize> = (0..320).collect();
        let test: Vec<usize> = (320..400).collect();
        let algo = &pjrt_roster(ds.task)[0]; // logistic
        let mut ctx = EvalContext::new(Some(&rt), 7);
        let good = algo.space().default_config();
        let crippled = good.clone().merged(
            &Config::new().with("l1", crate::space::Value::F(0.3))
                .with("lr", crate::space::Value::F(0.001)));
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let m1 = algo.fit(&ds, &train, &good, &mut ctx).unwrap();
        let m2 = algo.fit(&ds, &train, &crippled, &mut ctx).unwrap();
        let a1 = Metric::BalancedAccuracy
            .utility(&yt, &m1.predict(&ds, &test, &mut ctx));
        let a2 = Metric::BalancedAccuracy
            .utility(&yt, &m2.predict(&ds, &test, &mut ctx));
        assert!(a1 > a2, "regularised-to-death model should be worse \
                          ({a1} vs {a2})");
    }

    #[test]
    fn fidelity_changes_glm_training() {
        let Some(rt) = runtime() else { return };
        let ds = cls_ds();
        let train: Vec<usize> = (0..320).collect();
        let algo = &pjrt_roster(ds.task)[0];
        let cfg = algo.space().default_config();
        let mut ctx_full = EvalContext::new(Some(&rt), 8);
        let mut ctx_low = EvalContext::new(Some(&rt), 8);
        ctx_low.fidelity = 0.1;
        let rows: Vec<usize> = (320..400).collect();
        let p_full = algo.fit(&ds, &train, &cfg, &mut ctx_full).unwrap()
            .predict(&ds, &rows, &mut ctx_full);
        let p_low = algo.fit(&ds, &train, &cfg, &mut ctx_low).unwrap()
            .predict(&ds, &rows, &mut ctx_low);
        // 10% of the GD steps => different (typically worse) scores
        assert_ne!(p_full.score_row(0), p_low.score_row(0));
    }

    #[test]
    fn knn_distance_weighting_differs_from_uniform() {
        let Some(rt) = runtime() else { return };
        let ds = cls_ds();
        let train: Vec<usize> = (0..320).collect();
        let rows: Vec<usize> = (320..360).collect();
        let algo = KnnAlgo { classification: true };
        let mut ctx = EvalContext::new(Some(&rt), 9);
        let u = algo.space().default_config();
        let w = u.clone().merged(&Config::new()
            .with("weights", crate::space::Value::C("distance".into())));
        let pu = algo.fit(&ds, &train, &u, &mut ctx).unwrap()
            .predict(&ds, &rows, &mut ctx);
        let pw = algo.fit(&ds, &train, &w, &mut ctx).unwrap()
            .predict(&ds, &rows, &mut ctx);
        let du: Vec<f32> = (0..rows.len())
            .flat_map(|r| pu.score_row(r).to_vec()).collect();
        let dw: Vec<f32> = (0..rows.len())
            .flat_map(|r| pw.score_row(r).to_vec()).collect();
        assert_ne!(du, dw);
    }

    #[test]
    fn lr_schedule_shapes() {
        let c = lr_schedule("constant", 10, 1.0);
        assert_eq!(c, vec![1.0; 10]);
        let cos = lr_schedule("cosine", 10, 1.0);
        assert!(cos[0] > 0.99 && cos[9] < cos[0]);
        let half = lr_schedule("constant", 10, 0.5);
        assert_eq!(&half[..5], &[1.0; 5]);
        assert_eq!(&half[5..], &[0.0; 5]);
        let step = lr_schedule("step", 10, 1.0);
        assert_eq!(step[0], 1.0);
        assert!((step[9] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn unsupported_class_count_is_declared() {
        let algo = GlmAlgo {
            spec: GlmSpec {
                name: "logistic_regression",
                artifact: "glm_softmax",
                classification: true,
                reg_knobs: (true, true, false),
                cost: 1.0,
            },
        };
        assert!(!algo.supports(Task::Classification { n_classes: 12 }));
        assert!(algo.supports(Task::Classification { n_classes: 8 }));
    }
}
