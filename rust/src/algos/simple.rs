//! Closed-form probabilistic arms: Gaussian naive Bayes, LDA and QDA —
//! the fast, low-variance members of the conditioning block's roster.

use crate::data::dataset::{Dataset, Predictions, Task};
use crate::util::linalg::{cho_solve, Mat};

// ====================================================================
// Gaussian naive Bayes
// ====================================================================

#[derive(Clone, Debug)]
pub struct GaussianNb {
    priors: Vec<f64>,
    means: Vec<Vec<f64>>,
    vars: Vec<Vec<f64>>,
    n_classes: usize,
}

impl GaussianNb {
    pub fn fit(ds: &Dataset, train: &[usize], var_smoothing: f64)
        -> GaussianNb {
        assert!(ds.task.is_classification());
        let k = ds.task.n_classes();
        let d = ds.d;
        let mut counts = vec![0usize; k];
        let mut means = vec![vec![0.0f64; d]; k];
        let mut buf = Vec::with_capacity(d);
        for &i in train {
            let c = ds.label(i).min(k - 1);
            counts[c] += 1;
            ds.gather_row(i, &mut buf);
            for (j, &v) in buf.iter().enumerate() {
                means[c][j] += v as f64;
            }
        }
        for c in 0..k {
            for j in 0..d {
                means[c][j] /= counts[c].max(1) as f64;
            }
        }
        let mut vars = vec![vec![0.0f64; d]; k];
        let mut max_var: f64 = 1e-12;
        for &i in train {
            let c = ds.label(i).min(k - 1);
            ds.gather_row(i, &mut buf);
            for (j, &v) in buf.iter().enumerate() {
                let dlt = v as f64 - means[c][j];
                vars[c][j] += dlt * dlt;
            }
        }
        for c in 0..k {
            for j in 0..d {
                vars[c][j] /= counts[c].max(1) as f64;
                max_var = max_var.max(vars[c][j]);
            }
        }
        let eps = var_smoothing.max(1e-12) * max_var;
        for c in 0..k {
            for j in 0..d {
                vars[c][j] += eps;
            }
        }
        let n: f64 = counts.iter().sum::<usize>().max(1) as f64;
        let priors = counts.iter().map(|&c| (c as f64 + 1e-9) / n)
            .collect();
        GaussianNb { priors, means, vars, n_classes: k }
    }

    pub fn predict(&self, ds: &Dataset, rows: &[usize]) -> Predictions {
        let k = self.n_classes;
        let mut scores = vec![0.0f32; rows.len() * k];
        let mut buf = Vec::with_capacity(ds.d);
        for (r, &i) in rows.iter().enumerate() {
            ds.gather_row(i, &mut buf);
            let mut lls = vec![0.0f64; k];
            for c in 0..k {
                let mut ll = self.priors[c].ln();
                for (j, &v) in buf.iter().enumerate() {
                    let var = self.vars[c][j];
                    let dlt = v as f64 - self.means[c][j];
                    ll += -0.5 * (2.0 * std::f64::consts::PI * var).ln()
                        - 0.5 * dlt * dlt / var;
                }
                lls[c] = ll;
            }
            // softmax the log-likelihoods into calibrated-ish scores
            let m = lls.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let s: f64 = lls.iter().map(|&l| (l - m).exp()).sum();
            for c in 0..k {
                scores[r * k + c] = ((lls[c] - m).exp() / s) as f32;
            }
        }
        Predictions::ClassScores { n_classes: k, scores }
    }
}

// ====================================================================
// LDA / QDA
// ====================================================================

#[derive(Clone, Debug)]
pub struct Discriminant {
    priors: Vec<f64>,
    means: Vec<Vec<f64>>,
    /// One inverse-covariance application per class (QDA) or a single
    /// shared one (LDA). Stored as the covariance matrix; solves are
    /// done per prediction batch via Cholesky.
    covs: Vec<Mat>,
    log_dets: Vec<f64>,
    shared: bool,
    n_classes: usize,
}

impl Discriminant {
    /// `shrinkage`/`reg_param` shrinks covariance towards a scaled
    /// identity (LDA: shrinkage; QDA: reg_param — same mechanics).
    pub fn fit(ds: &Dataset, train: &[usize], shared: bool, reg: f64)
        -> Option<Discriminant> {
        assert!(ds.task.is_classification());
        let k = ds.task.n_classes();
        let d = ds.d;
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); k];
        for &i in train {
            by_class[ds.label(i).min(k - 1)].push(i);
        }
        let n: f64 = train.len() as f64;
        let priors: Vec<f64> = by_class
            .iter()
            .map(|m| (m.len() as f64 + 1e-9) / n)
            .collect();
        let means: Vec<Vec<f64>> = by_class
            .iter()
            .map(|m| {
                if m.is_empty() {
                    vec![0.0; d]
                } else {
                    ds.col_stats(m).0
                }
            })
            .collect();

        let cov_of = |members: &[&Vec<usize>], means_of: &dyn Fn(usize) -> usize| -> Mat {
            let mut cov = Mat::zeros(d, d);
            let mut count = 0.0f64;
            let mut row = Vec::with_capacity(d);
            for (ci, rows) in members.iter().enumerate() {
                for &i in rows.iter() {
                    let mu = &means[means_of(ci)];
                    ds.gather_row(i, &mut row);
                    for a in 0..d {
                        let da = row[a] as f64 - mu[a];
                        for b in a..d {
                            let v = da * (row[b] as f64 - mu[b]);
                            cov[(a, b)] += v;
                        }
                    }
                    count += 1.0;
                }
            }
            for a in 0..d {
                for b in 0..a {
                    cov[(a, b)] = cov[(b, a)];
                }
            }
            for a in 0..d {
                for b in a + 1..d {
                    cov[(b, a)] = cov[(a, b)];
                }
            }
            cov.scale(1.0 / count.max(1.0));
            cov
        };

        let regularise = |mut cov: Mat| -> Mat {
            let trace: f64 = (0..d).map(|i| cov[(i, i)]).sum::<f64>()
                .max(1e-9);
            let avg = trace / d as f64;
            for a in 0..d {
                for b in 0..d {
                    cov[(a, b)] *= 1.0 - reg;
                }
                cov[(a, a)] += reg * avg + 1e-9;
            }
            cov
        };

        let (covs, log_dets): (Vec<Mat>, Vec<f64>) = if shared {
            let refs: Vec<&Vec<usize>> = by_class.iter().collect();
            let cov = regularise(cov_of(&refs, &|ci| ci));
            let ld = log_det(&cov)?;
            (vec![cov], vec![ld])
        } else {
            let mut cs = Vec::with_capacity(k);
            let mut lds = Vec::with_capacity(k);
            for c in 0..k {
                let refs: Vec<&Vec<usize>> = vec![&by_class[c]];
                let cov = regularise(cov_of(&refs, &move |_| c));
                lds.push(log_det(&cov)?);
                cs.push(cov);
            }
            (cs, lds)
        };
        Some(Discriminant { priors, means, covs, log_dets, shared,
                            n_classes: k })
    }

    pub fn predict(&self, ds: &Dataset, rows: &[usize]) -> Predictions {
        let k = self.n_classes;
        let d = ds.d;
        let mut scores = vec![0.0f32; rows.len() * k];
        let mut row = Vec::with_capacity(d);
        for (r, &i) in rows.iter().enumerate() {
            ds.gather_row(i, &mut row);
            let mut lls = vec![f64::NEG_INFINITY; k];
            for c in 0..k {
                let cov = if self.shared { &self.covs[0] }
                          else { &self.covs[c] };
                let ld = if self.shared { self.log_dets[0] }
                         else { self.log_dets[c] };
                let diff: Vec<f64> = (0..d)
                    .map(|j| row[j] as f64 - self.means[c][j])
                    .collect();
                if let Some(sol) = cho_solve(cov, &diff) {
                    let maha = crate::util::linalg::dot(&diff, &sol);
                    lls[c] = self.priors[c].ln() - 0.5 * maha - 0.5 * ld;
                }
            }
            let m = lls.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let s: f64 = lls.iter().map(|&l| (l - m).exp()).sum();
            for c in 0..k {
                scores[r * k + c] = ((lls[c] - m).exp() / s.max(1e-300))
                    as f32;
            }
        }
        Predictions::ClassScores { n_classes: k, scores }
    }
}

fn log_det(cov: &Mat) -> Option<f64> {
    let l = crate::util::linalg::cholesky(cov)?;
    Some(2.0 * (0..cov.rows).map(|i| l[(i, i)].ln()).sum::<f64>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metrics::balanced_accuracy;
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn blob_ds(k: usize, sep: f64) -> Dataset {
        generate(&Profile {
            name: "s".into(),
            task: Task::Classification { n_classes: k },
            gen: GenKind::Blobs { sep },
            n: 500,
            d: 6,
            noise: 0.02,
            imbalance: 1.5,
            redundant: 1,
            wild_scales: false,
            seed: 77,
        })
    }

    fn acc_of(preds: &Predictions, ds: &Dataset, rows: &[usize]) -> f64 {
        let yt: Vec<f32> = rows.iter().map(|&i| ds.y[i]).collect();
        balanced_accuracy(&yt, &preds.argmax_labels())
    }

    #[test]
    fn nb_separates_blobs() {
        let ds = blob_ds(3, 2.5);
        let train: Vec<usize> = (0..400).collect();
        let test: Vec<usize> = (400..500).collect();
        let nb = GaussianNb::fit(&ds, &train, 1e-9);
        assert!(acc_of(&nb.predict(&ds, &test), &ds, &test) > 0.9);
    }

    #[test]
    fn nb_scores_are_probabilities() {
        let ds = blob_ds(2, 1.0);
        let train: Vec<usize> = (0..400).collect();
        let nb = GaussianNb::fit(&ds, &train, 1e-9);
        let rows: Vec<usize> = (400..450).collect();
        let p = nb.predict(&ds, &rows);
        for r in 0..rows.len() {
            let s: f32 = p.score_row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn lda_beats_qda_on_shared_covariance_blobs() {
        let ds = blob_ds(3, 1.2);
        let train: Vec<usize> = (0..150).collect(); // few samples
        let test: Vec<usize> = (400..500).collect();
        let lda = Discriminant::fit(&ds, &train, true, 0.1).unwrap();
        let qda = Discriminant::fit(&ds, &train, false, 0.1).unwrap();
        let a_lda = acc_of(&lda.predict(&ds, &test), &ds, &test);
        let a_qda = acc_of(&qda.predict(&ds, &test), &ds, &test);
        assert!(a_lda > 0.75, "lda={a_lda}");
        assert!(a_qda > 0.6, "qda={a_qda}");
    }

    #[test]
    fn qda_handles_class_specific_scales() {
        // class 0 tight, class 1 spread: QDA should classify well
        let mut ds = Dataset::new("q", Task::Classification { n_classes: 2 }, 2);
        let mut rng = crate::util::rng::Rng::new(3);
        for i in 0..400 {
            if i % 2 == 0 {
                ds.push_row(&[(rng.normal() * 0.3) as f32,
                              (rng.normal() * 0.3) as f32], 0.0);
            } else {
                ds.push_row(&[(rng.normal() * 3.0) as f32,
                              (rng.normal() * 3.0) as f32], 1.0);
            }
        }
        let train: Vec<usize> = (0..300).collect();
        let test: Vec<usize> = (300..400).collect();
        let qda = Discriminant::fit(&ds, &train, false, 0.05).unwrap();
        assert!(acc_of(&qda.predict(&ds, &test), &ds, &test) > 0.8);
    }

    #[test]
    fn degenerate_features_do_not_crash() {
        // constant feature => singular covariance; jitter must save us
        let mut ds = Dataset::new("c", Task::Classification { n_classes: 2 }, 2);
        for i in 0..100 {
            ds.push_row(&[1.0, i as f32 % 2.0], (i % 2) as f32);
        }
        let train: Vec<usize> = (0..100).collect();
        let lda = Discriminant::fit(&ds, &train, true, 0.0);
        assert!(lda.is_some());
        let p = lda.unwrap().predict(&ds, &[0, 1]);
        assert_eq!(p.argmax_labels().len(), 2);
    }
}
