//! CART decision trees — the shared substrate for the tree-family
//! algorithm arms (decision tree, random forest, extra-trees, gradient
//! boosting, AdaBoost, histogram-GBM).
//!
//! Works on raw row-major f32 features with f64 targets so boosting can
//! fit trees on residuals without copying datasets. Classification
//! leaves store class distributions; regression leaves store means.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criterion {
    Gini,
    Entropy,
    Mse,
}

#[derive(Clone, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    /// Fraction of features examined per split (0, 1].
    pub max_features: f64,
    pub criterion: Criterion,
    /// Extra-trees style: one random threshold per feature instead of
    /// an exhaustive scan.
    pub random_thresholds: bool,
    /// 0 for regression.
    pub n_classes: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 10,
            min_samples_split: 2,
            min_samples_leaf: 1,
            max_features: 1.0,
            criterion: Criterion::Gini,
            random_thresholds: false,
            n_classes: 2,
        }
    }
}

#[derive(Clone, Debug)]
enum Node {
    Split { feature: usize, thresh: f32, left: usize, right: usize },
    /// Class distribution (classification) or single mean (regression).
    Leaf(Vec<f64>),
}

#[derive(Clone, Debug)]
pub struct Tree {
    nodes: Vec<Node>,
    pub n_classes: usize,
}

struct Stats {
    counts: Vec<f64>, // class counts, or [sum, sumsq] for regression
    n: f64,
}

impl Stats {
    fn new(k: usize) -> Stats {
        Stats { counts: vec![0.0; k.max(2)], n: 0.0 }
    }
    fn add(&mut self, y: f64, cls: bool) {
        self.n += 1.0;
        if cls {
            self.counts[y as usize] += 1.0;
        } else {
            self.counts[0] += y;
            self.counts[1] += y * y;
        }
    }
    fn sub(&mut self, y: f64, cls: bool) {
        self.n -= 1.0;
        if cls {
            self.counts[y as usize] -= 1.0;
        } else {
            self.counts[0] -= y;
            self.counts[1] -= y * y;
        }
    }
    fn impurity(&self, crit: Criterion) -> f64 {
        if self.n <= 0.0 {
            return 0.0;
        }
        match crit {
            Criterion::Gini => {
                let mut g = 1.0;
                for &c in &self.counts {
                    let p = c / self.n;
                    g -= p * p;
                }
                g
            }
            Criterion::Entropy => {
                let mut h = 0.0;
                for &c in &self.counts {
                    if c > 0.0 {
                        let p = c / self.n;
                        h -= p * p.log2();
                    }
                }
                h
            }
            Criterion::Mse => {
                let mean = self.counts[0] / self.n;
                (self.counts[1] / self.n - mean * mean).max(0.0)
            }
        }
    }
}

impl Tree {
    /// Fit on rows of `x` (row-major, `d` columns) with targets `y`
    /// (class index as f64 for classification).
    pub fn fit(x: &[f32], d: usize, y: &[f64], rows: &[usize],
               p: &TreeParams, rng: &mut Rng) -> Tree {
        Self::fit_with(|i, j| x[i * d + j], d, y, rows, p, rng)
    }

    /// Fit through a feature accessor `at(row, col)`. Columnar
    /// datasets pass `|i, j| ds.at(i, j)` and avoid materialising a
    /// row-major copy; the closure is monomorphised so the inner scan
    /// loops compile to the same direct loads as the slice version.
    /// Split search order is identical regardless of accessor, so the
    /// fitted tree is bit-identical to the row-major path on the same
    /// values.
    pub fn fit_with<F>(at: F, d: usize, y: &[f64], rows: &[usize],
                       p: &TreeParams, rng: &mut Rng) -> Tree
    where
        F: Fn(usize, usize) -> f32,
    {
        assert!(d > 0, "empty feature matrix");
        let mut t = Tree { nodes: Vec::new(), n_classes: p.n_classes };
        let mut rows = rows.to_vec();
        t.grow(&at, d, y, &mut rows, p, rng, 0);
        t
    }

    fn leaf_value(&self, y: &[f64], rows: &[usize], p: &TreeParams)
        -> Vec<f64> {
        if p.n_classes > 0 {
            let mut dist = vec![0.0; p.n_classes];
            for &i in rows {
                dist[(y[i] as usize).min(p.n_classes - 1)] += 1.0;
            }
            let n = rows.len().max(1) as f64;
            for v in &mut dist {
                *v /= n;
            }
            dist
        } else {
            let mean = rows.iter().map(|&i| y[i]).sum::<f64>()
                / rows.len().max(1) as f64;
            vec![mean]
        }
    }

    /// Recursively grow; returns the node index. `rows` is reordered
    /// in-place (partitioning) to avoid allocation per node.
    fn grow<F>(&mut self, at: &F, d: usize, y: &[f64],
               rows: &mut [usize], p: &TreeParams, rng: &mut Rng,
               depth: usize) -> usize
    where
        F: Fn(usize, usize) -> f32,
    {
        let make_leaf = |t: &mut Tree, rows: &[usize]| {
            let v = t.leaf_value(y, rows, p);
            t.nodes.push(Node::Leaf(v));
            t.nodes.len() - 1
        };
        if depth >= p.max_depth
            || rows.len() < p.min_samples_split
            || rows.len() < 2 * p.min_samples_leaf
        {
            return make_leaf(self, rows);
        }
        // pure node?
        let cls = p.n_classes > 0;
        if cls {
            let first = y[rows[0]];
            if rows.iter().all(|&i| y[i] == first) {
                return make_leaf(self, rows);
            }
        }

        let n_feat = ((d as f64 * p.max_features).ceil() as usize)
            .clamp(1, d);
        let feats = rng.sample_indices(d, n_feat);

        let mut best: Option<(f64, usize, f32)> = None; // (gain, feat, thr)
        let mut scratch: Vec<(f32, f64)> = Vec::with_capacity(rows.len());

        let mut parent = Stats::new(p.n_classes);
        for &i in rows.iter() {
            parent.add(y[i], cls);
        }
        let parent_imp = parent.impurity(p.criterion);
        if parent_imp <= 1e-12 {
            return make_leaf(self, rows);
        }

        for &f in &feats {
            scratch.clear();
            for &i in rows.iter() {
                scratch.push((at(i, f), y[i]));
            }
            if p.random_thresholds {
                let lo = scratch.iter().map(|s| s.0).fold(f32::INFINITY,
                                                          f32::min);
                let hi = scratch.iter().map(|s| s.0)
                    .fold(f32::NEG_INFINITY, f32::max);
                if hi <= lo {
                    continue;
                }
                let thr = rng.uniform(lo as f64, hi as f64) as f32;
                let mut left = Stats::new(p.n_classes);
                let mut right = Stats::new(p.n_classes);
                for &(v, yy) in &scratch {
                    if v <= thr {
                        left.add(yy, cls);
                    } else {
                        right.add(yy, cls);
                    }
                }
                if left.n < p.min_samples_leaf as f64
                    || right.n < p.min_samples_leaf as f64 {
                    continue;
                }
                let gain = parent_imp
                    - (left.n * left.impurity(p.criterion)
                        + right.n * right.impurity(p.criterion))
                        / parent.n;
                if gain > best.map(|b| b.0).unwrap_or(1e-9) {
                    best = Some((gain, f, thr));
                }
            } else {
                // hot loop: total_cmp + unstable sort is measurably
                // faster than partial_cmp with an Ordering fallback
                scratch.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
                let mut left = Stats::new(p.n_classes);
                let mut right = Stats::new(p.n_classes);
                for &(_, yy) in &scratch {
                    right.add(yy, cls);
                }
                for w in 0..scratch.len() - 1 {
                    let (v, yy) = scratch[w];
                    left.add(yy, cls);
                    right.sub(yy, cls);
                    let next_v = scratch[w + 1].0;
                    if v == next_v {
                        continue;
                    }
                    if left.n < p.min_samples_leaf as f64
                        || right.n < p.min_samples_leaf as f64 {
                        continue;
                    }
                    let gain = parent_imp
                        - (left.n * left.impurity(p.criterion)
                            + right.n * right.impurity(p.criterion))
                            / parent.n;
                    if gain > best.map(|b| b.0).unwrap_or(1e-9) {
                        best = Some((gain, f, (v + next_v) / 2.0));
                    }
                }
            }
        }

        let (gain, feat, thr) = match best {
            Some(b) if b.0 > 1e-9 => b,
            _ => return make_leaf(self, rows),
        };
        let _ = gain;

        // partition rows in place
        let mut lo = 0usize;
        let mut hi = rows.len();
        while lo < hi {
            if at(rows[lo], feat) <= thr {
                lo += 1;
            } else {
                hi -= 1;
                rows.swap(lo, hi);
            }
        }
        if lo == 0 || lo == rows.len() {
            return make_leaf(self, rows);
        }

        let node_idx = self.nodes.len();
        self.nodes.push(Node::Split { feature: feat, thresh: thr,
                                      left: 0, right: 0 });
        let (lrows, rrows) = rows.split_at_mut(lo);
        let li = self.grow(at, d, y, lrows, p, rng, depth + 1);
        let ri = self.grow(at, d, y, rrows, p, rng, depth + 1);
        if let Node::Split { left, right, .. } = &mut self.nodes[node_idx] {
            *left = li;
            *right = ri;
        }
        node_idx
    }

    /// Leaf payload for one row (class distribution or [mean]).
    pub fn predict_row<'a>(&'a self, row: &[f32]) -> &'a [f64] {
        // the root is the first node pushed *after* its subtrees when
        // the tree has splits; track via explicit root search: the root
        // is node 0 only for leaf-only trees. We store root implicitly:
        // grow() pushes the root split before children, so node with
        // index `self.root()` is fine.
        let mut idx = self.root();
        loop {
            match &self.nodes[idx] {
                Node::Leaf(v) => return v,
                Node::Split { feature, thresh, left, right } => {
                    idx = if row.get(*feature).copied().unwrap_or(0.0)
                        <= *thresh { *left } else { *right };
                }
            }
        }
    }

    fn root(&self) -> usize {
        0
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn rec(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf(_) => 1,
                Node::Split { left, right, .. } => {
                    1 + rec(nodes, *left).max(rec(nodes, *right))
                }
            }
        }
        if self.nodes.is_empty() { 0 } else { rec(&self.nodes, 0) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_data(n: usize, seed: u64) -> (Vec<f32>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.uniform(-1.0, 1.0);
            let b = rng.uniform(-1.0, 1.0);
            x.push(a as f32);
            x.push(b as f32);
            y.push(if a * b > 0.0 { 1.0 } else { 0.0 });
        }
        (x, y)
    }

    #[test]
    fn learns_xor_exactly() {
        let (x, y) = xor_data(400, 0);
        let rows: Vec<usize> = (0..400).collect();
        let p = TreeParams { max_depth: 6, ..Default::default() };
        let mut rng = Rng::new(1);
        let t = Tree::fit(&x, 2, &y, &rows, &p, &mut rng);
        let mut hits = 0;
        for i in 0..400 {
            let dist = t.predict_row(&x[i * 2..i * 2 + 2]);
            let pred = if dist[1] > dist[0] { 1.0 } else { 0.0 };
            if pred == y[i] {
                hits += 1;
            }
        }
        assert!(hits >= 392, "hits={hits}");
    }

    #[test]
    fn depth_limit_respected() {
        let (x, y) = xor_data(300, 2);
        let rows: Vec<usize> = (0..300).collect();
        let p = TreeParams { max_depth: 3, ..Default::default() };
        let mut rng = Rng::new(3);
        let t = Tree::fit(&x, 2, &y, &rows, &p, &mut rng);
        assert!(t.depth() <= 4); // split nodes + leaf level
    }

    #[test]
    fn min_samples_leaf_respected() {
        let (x, y) = xor_data(100, 4);
        let rows: Vec<usize> = (0..100).collect();
        let p = TreeParams {
            min_samples_leaf: 40,
            max_depth: 8,
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let t = Tree::fit(&x, 2, &y, &rows, &p, &mut rng);
        // with leaves >= 40 of 100 samples, at most 1 split chain
        assert!(t.n_nodes() <= 5, "nodes={}", t.n_nodes());
    }

    #[test]
    fn regression_tree_fits_step_function() {
        let mut rng = Rng::new(6);
        let n = 300;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let v = rng.uniform(-1.0, 1.0);
            x.push(v as f32);
            y.push(if v > 0.25 { 3.0 } else { -1.0 });
        }
        let rows: Vec<usize> = (0..n).collect();
        let p = TreeParams {
            criterion: Criterion::Mse,
            n_classes: 0,
            max_depth: 3,
            ..Default::default()
        };
        let t = Tree::fit(&x, 1, &y, &rows, &p, &mut rng);
        assert!((t.predict_row(&[0.5])[0] - 3.0).abs() < 0.1);
        assert!((t.predict_row(&[-0.5])[0] + 1.0).abs() < 0.1);
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let x = vec![0.0f32; 10];
        let y = vec![1.0f64; 10];
        let rows: Vec<usize> = (0..10).collect();
        let p = TreeParams::default();
        let mut rng = Rng::new(7);
        let t = Tree::fit(&x, 1, &y, &rows, &p, &mut rng);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict_row(&[0.0])[1], 1.0);
    }

    #[test]
    fn random_thresholds_still_learn() {
        let (x, y) = xor_data(500, 8);
        let rows: Vec<usize> = (0..500).collect();
        let p = TreeParams {
            random_thresholds: true,
            max_depth: 10,
            ..Default::default()
        };
        let mut rng = Rng::new(9);
        let t = Tree::fit(&x, 2, &y, &rows, &p, &mut rng);
        let mut hits = 0;
        for i in 0..500 {
            let dist = t.predict_row(&x[i * 2..i * 2 + 2]);
            if (dist[1] > dist[0]) == (y[i] == 1.0) {
                hits += 1;
            }
        }
        assert!(hits > 440, "hits={hits}");
    }

    #[test]
    fn accessor_path_is_bit_identical_to_row_major() {
        let (x, y) = xor_data(250, 21);
        let d = 2;
        // column-major copy accessed through the closure, as a
        // columnar Dataset would be
        let mut cols = vec![Vec::with_capacity(250); d];
        for i in 0..250 {
            for (j, c) in cols.iter_mut().enumerate() {
                c.push(x[i * d + j]);
            }
        }
        let rows: Vec<usize> = (0..250).collect();
        let p = TreeParams { max_depth: 6, max_features: 0.5,
                             ..Default::default() };
        let a = Tree::fit(&x, d, &y, &rows, &p, &mut Rng::new(33));
        let b = Tree::fit_with(|i, j| cols[j][i], d, &y, &rows, &p,
                               &mut Rng::new(33));
        assert_eq!(a.n_nodes(), b.n_nodes());
        assert_eq!(a.depth(), b.depth());
        for i in 0..250 {
            let ra = a.predict_row(&x[i * d..(i + 1) * d]);
            let rb = b.predict_row(&x[i * d..(i + 1) * d]);
            assert_eq!(ra.len(), rb.len());
            for (va, vb) in ra.iter().zip(rb) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn entropy_criterion_works() {
        let (x, y) = xor_data(300, 10);
        let rows: Vec<usize> = (0..300).collect();
        let p = TreeParams {
            criterion: Criterion::Entropy,
            max_depth: 6,
            ..Default::default()
        };
        let mut rng = Rng::new(11);
        let t = Tree::fit(&x, 2, &y, &rows, &p, &mut rng);
        let mut hits = 0;
        for i in 0..300 {
            let dist = t.predict_row(&x[i * 2..i * 2 + 2]);
            if (dist[1] > dist[0]) == (y[i] == 1.0) {
                hits += 1;
            }
        }
        assert!(hits > 285);
    }
}
