//! Boosted ensembles: gradient boosting (softmax/squared loss on
//! shallow CART trees), a histogram-binned variant standing in for the
//! paper's LightGBM arm, and AdaBoost (SAMME via weighted resampling).

use crate::data::dataset::{Dataset, Predictions, Task};
use crate::util::rng::Rng;

use super::tree::{Criterion, Tree, TreeParams};
use super::PREDICT_BLOCK_ROWS;

// ====================================================================
// Gradient boosting
// ====================================================================

#[derive(Clone, Debug)]
pub struct GbmParams {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub subsample: f64,
    pub min_samples_leaf: usize,
    /// Histogram mode: bin features into `n_bins` quantile bins first
    /// (the LightGBM-style arm); 0 disables binning.
    pub n_bins: usize,
}

impl Default for GbmParams {
    fn default() -> Self {
        GbmParams {
            n_estimators: 60,
            learning_rate: 0.1,
            max_depth: 3,
            subsample: 0.9,
            min_samples_leaf: 3,
            n_bins: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Gbm {
    /// trees[round][class] (regression: one "class").
    trees: Vec<Vec<Tree>>,
    lr: f64,
    task: Task,
    base: Vec<f64>,
    /// Per-feature bin edges when histogram mode is on.
    bins: Option<Vec<Vec<f32>>>,
}

fn softmax_rows(z: &mut [f64], k: usize) {
    for row in z.chunks_mut(k) {
        let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
}

fn quantile_edges(ds: &Dataset, train: &[usize], n_bins: usize)
    -> Vec<Vec<f32>> {
    (0..ds.d)
        .map(|j| {
            let c = ds.col(j);
            let mut xs: Vec<f32> =
                train.iter().map(|&i| c[i]).collect();
            xs.sort_by(|a, b| a.partial_cmp(b)
                .unwrap_or(std::cmp::Ordering::Equal));
            let mut edges: Vec<f32> = (1..n_bins)
                .map(|b| xs[(b * xs.len() / n_bins)
                    .min(xs.len().saturating_sub(1))])
                .collect();
            edges.dedup();
            edges
        })
        .collect()
}

fn bin_row(row: &[f32], bins: &[Vec<f32>]) -> Vec<f32> {
    row.iter()
        .enumerate()
        .map(|(j, &v)| {
            let edges = &bins[j];
            let idx = match edges.binary_search_by(|e| e
                .partial_cmp(&v).unwrap_or(std::cmp::Ordering::Less)) {
                Ok(i) => i + 1,
                Err(i) => i,
            };
            idx as f32
        })
        .collect()
}

impl Gbm {
    pub fn fit(ds: &Dataset, train: &[usize], p: &GbmParams,
               rng: &mut Rng) -> Gbm {
        let cls = ds.task.is_classification();
        let k = if cls { ds.task.n_classes() } else { 1 };
        let n = train.len();

        // optional histogram binning (LightGBM-style arm)
        let bins = if p.n_bins > 1 {
            Some(quantile_edges(ds, train, p.n_bins))
        } else {
            None
        };
        // boosting re-reads every row once per (round, class); one
        // row-major gather here beats columnar strided access inside
        // the tree loop, and the copy dies with the fit
        let (x_local, d): (Vec<f32>, usize) = match &bins {
            Some(b) => {
                // blocked column-streaming gather, then bin each
                // contiguous row slice (the raw copy dies here)
                let raw = ds.to_row_major();
                let mut x = Vec::with_capacity(ds.n * ds.d);
                for i in 0..ds.n {
                    x.extend(bin_row(&raw[i * ds.d..(i + 1) * ds.d],
                                     b));
                }
                (x, ds.d)
            }
            None => (ds.to_row_major(), ds.d),
        };

        // base score: log priors (cls) or mean (reg)
        let base: Vec<f64> = if cls {
            let mut counts = vec![1e-9f64; k];
            for &i in train {
                counts[ds.label(i).min(k - 1)] += 1.0;
            }
            let total: f64 = counts.iter().sum();
            counts.iter().map(|c| (c / total).ln()).collect()
        } else {
            let m = train.iter().map(|&i| ds.y[i] as f64).sum::<f64>()
                / n.max(1) as f64;
            vec![m]
        };

        // current raw scores per train row
        let mut f: Vec<f64> = (0..n).flat_map(|_| base.clone()).collect();
        let tp = TreeParams {
            max_depth: p.max_depth,
            min_samples_split: 2 * p.min_samples_leaf,
            min_samples_leaf: p.min_samples_leaf,
            max_features: 1.0,
            criterion: Criterion::Mse,
            random_thresholds: false,
            n_classes: 0,
        };

        let mut rounds = Vec::with_capacity(p.n_estimators);
        let mut residual = vec![0.0f64; n];
        for _round in 0..p.n_estimators {
            // row subsample for this round
            let m_rows = ((n as f64 * p.subsample) as usize).clamp(2, n);
            let pick: Vec<usize> = if m_rows < n {
                rng.sample_indices(n, m_rows)
            } else {
                (0..n).collect()
            };
            let mut class_trees = Vec::with_capacity(k);
            let mut probs = f.clone();
            if cls {
                softmax_rows(&mut probs, k);
            }
            for c in 0..k {
                for (t, &row) in train.iter().enumerate() {
                    residual[t] = if cls {
                        let y = if ds.label(row).min(k - 1) == c { 1.0 }
                                else { 0.0 };
                        y - probs[t * k + c]
                    } else {
                        ds.y[row] as f64 - f[t]
                    };
                }
                // fit tree on (global-row x, residual indexed by local t)
                // => remap: build target vec aligned to global rows
                let mut y_global = vec![0.0f64; ds.n];
                for (t, &row) in train.iter().enumerate() {
                    y_global[row] = residual[t];
                }
                let rows_global: Vec<usize> =
                    pick.iter().map(|&t| train[t]).collect();
                let tree = Tree::fit(&x_local, d, &y_global, &rows_global,
                                     &tp, rng);
                // update scores
                for (t, &row) in train.iter().enumerate() {
                    let pred = tree.predict_row(
                        &x_local[row * d..(row + 1) * d])[0];
                    f[t * k + c] += p.learning_rate * pred;
                }
                class_trees.push(tree);
            }
            rounds.push(class_trees);
        }
        Gbm { trees: rounds, lr: p.learning_rate, task: ds.task, base,
              bins }
    }

    pub fn predict(&self, ds: &Dataset, rows: &[usize]) -> Predictions {
        let k = self.base.len();
        let mut scores = vec![0.0f64; rows.len() * k];
        // blocked gather: bounded row-major buffer, each source
        // column streamed once per block (util::kernels)
        let mut block = Vec::new();
        for blo in (0..rows.len()).step_by(PREDICT_BLOCK_ROWS) {
            let bhi = (blo + PREDICT_BLOCK_ROWS).min(rows.len());
            ds.gather_rows_rowmajor(&rows[blo..bhi], &mut block);
            for r in blo..bhi {
                let buf = &block[(r - blo) * ds.d..(r - blo + 1) * ds.d];
                let binned;
                let row: &[f32] = match &self.bins {
                    Some(b) => {
                        binned = bin_row(buf, b);
                        &binned
                    }
                    None => buf,
                };
                for c in 0..k {
                    let mut s = self.base[c];
                    for round in &self.trees {
                        s += self.lr * round[c].predict_row(row)[0];
                    }
                    scores[r * k + c] = s;
                }
            }
        }
        match self.task {
            Task::Classification { n_classes } => {
                softmax_rows(&mut scores, k);
                Predictions::ClassScores {
                    n_classes,
                    scores: scores.iter().map(|&v| v as f32).collect(),
                }
            }
            Task::Regression => Predictions::Values(
                scores.iter().map(|&v| v as f32).collect()),
        }
    }
}

// ====================================================================
// AdaBoost (SAMME, weighted resampling)
// ====================================================================

#[derive(Clone, Debug)]
pub struct AdaParams {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
}

impl Default for AdaParams {
    fn default() -> Self {
        AdaParams { n_estimators: 40, learning_rate: 1.0, max_depth: 2 }
    }
}

#[derive(Clone, Debug)]
pub struct AdaBoost {
    stumps: Vec<(Tree, f64)>,
    task: Task,
}

impl AdaBoost {
    pub fn fit(ds: &Dataset, train: &[usize], p: &AdaParams,
               rng: &mut Rng) -> AdaBoost {
        let cls = ds.task.is_classification();
        let k = if cls { ds.task.n_classes() } else { 0 };
        let y: Vec<f64> = ds.y.iter().map(|&v| v as f64).collect();
        let n = train.len();
        let mut w = vec![1.0 / n as f64; n];
        let tp = TreeParams {
            max_depth: p.max_depth,
            criterion: if cls { Criterion::Gini } else { Criterion::Mse },
            n_classes: k,
            ..Default::default()
        };
        let mut stumps = Vec::new();
        let mut buf = Vec::with_capacity(ds.d);
        for round in 0..p.n_estimators {
            let mut trng = rng.fork(round as u64);
            // weighted resample
            let rows: Vec<usize> = (0..n)
                .map(|_| train[trng.weighted(&w)])
                .collect();
            let tree = Tree::fit_with(|i, j| ds.at(i, j), ds.d, &y,
                                      &rows, &tp, &mut trng);
            if cls {
                // SAMME error on weighted train
                let mut err = 0.0;
                let mut preds = Vec::with_capacity(n);
                for (t, &i) in train.iter().enumerate() {
                    ds.gather_row(i, &mut buf);
                    let dist = tree.predict_row(&buf);
                    let pred = dist
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(c, _)| c)
                        .unwrap_or(0);
                    preds.push(pred);
                    if pred != ds.label(i) {
                        err += w[t];
                    }
                }
                let err = err.clamp(1e-10, 1.0 - 1e-10);
                if err >= 1.0 - 1.0 / k as f64 {
                    continue; // worse than chance: skip round
                }
                let alpha = p.learning_rate
                    * (((1.0 - err) / err).ln() + (k as f64 - 1.0).ln());
                for (t, &i) in train.iter().enumerate() {
                    if preds[t] != ds.label(i) {
                        w[t] *= alpha.exp();
                    }
                }
                let s: f64 = w.iter().sum();
                for v in &mut w {
                    *v /= s;
                }
                stumps.push((tree, alpha));
                if err < 1e-9 {
                    break;
                }
            } else {
                // AdaBoost.R2-flavoured: weight by absolute error
                let mut errs = Vec::with_capacity(n);
                let mut max_e: f64 = 1e-12;
                for &i in train {
                    ds.gather_row(i, &mut buf);
                    let e = (tree.predict_row(&buf)[0]
                        - ds.y[i] as f64).abs();
                    max_e = max_e.max(e);
                    errs.push(e);
                }
                let avg_loss: f64 = errs
                    .iter()
                    .zip(&w)
                    .map(|(e, wi)| (e / max_e) * wi)
                    .sum();
                let avg_loss = avg_loss.clamp(1e-10, 0.999);
                let beta = avg_loss / (1.0 - avg_loss);
                let alpha = p.learning_rate * (1.0 / beta).ln();
                for (t, e) in errs.iter().enumerate() {
                    w[t] *= beta.powf(1.0 - e / max_e);
                }
                let s: f64 = w.iter().sum();
                for v in &mut w {
                    *v /= s;
                }
                stumps.push((tree, alpha));
            }
        }
        if stumps.is_empty() {
            // degenerate data: keep one unweighted tree
            let mut trng = rng.fork(999);
            let rows: Vec<usize> = train.to_vec();
            let tree = Tree::fit_with(|i, j| ds.at(i, j), ds.d, &y,
                                      &rows, &tp, &mut trng);
            stumps.push((tree, 1.0));
        }
        AdaBoost { stumps, task: ds.task }
    }

    pub fn predict(&self, ds: &Dataset, rows: &[usize]) -> Predictions {
        // blocked gather: bounded row-major buffer, each source
        // column streamed once per block (util::kernels)
        let mut block = Vec::new();
        match self.task {
            Task::Classification { n_classes } => {
                let mut scores = vec![0.0f32; rows.len() * n_classes];
                for blo in (0..rows.len()).step_by(PREDICT_BLOCK_ROWS) {
                    let bhi = (blo + PREDICT_BLOCK_ROWS).min(rows.len());
                    ds.gather_rows_rowmajor(&rows[blo..bhi], &mut block);
                    for r in blo..bhi {
                        let buf = &block[(r - blo) * ds.d
                                         ..(r - blo + 1) * ds.d];
                        for (tree, alpha) in &self.stumps {
                            let dist = tree.predict_row(buf);
                            let pred = dist
                                .iter()
                                .enumerate()
                                .max_by(|a, b| a.1.partial_cmp(b.1)
                                    .unwrap())
                                .map(|(c, _)| c)
                                .unwrap_or(0);
                            scores[r * n_classes
                                   + pred.min(n_classes - 1)] +=
                                *alpha as f32;
                        }
                    }
                }
                Predictions::ClassScores { n_classes, scores }
            }
            Task::Regression => {
                let total: f64 =
                    self.stumps.iter().map(|(_, a)| *a).sum::<f64>()
                        .max(1e-12);
                let mut vals = Vec::with_capacity(rows.len());
                for blo in (0..rows.len()).step_by(PREDICT_BLOCK_ROWS) {
                    let bhi = (blo + PREDICT_BLOCK_ROWS).min(rows.len());
                    ds.gather_rows_rowmajor(&rows[blo..bhi], &mut block);
                    for r in blo..bhi {
                        let buf = &block[(r - blo) * ds.d
                                         ..(r - blo + 1) * ds.d];
                        let s: f64 = self
                            .stumps
                            .iter()
                            .map(|(t, a)| a * t.predict_row(buf)[0])
                            .sum();
                        vals.push((s / total) as f32);
                    }
                }
                Predictions::Values(vals)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metrics::{balanced_accuracy, mse};
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn gen(task: Task, gk: GenKind, n: usize) -> Dataset {
        generate(&Profile {
            name: "b".into(),
            task,
            gen: gk,
            n,
            d: 8,
            noise: 0.03,
            imbalance: 1.0,
            redundant: 1,
            wild_scales: false,
            seed: 31,
        })
    }

    #[test]
    fn gbm_classifies_checker() {
        let ds = gen(Task::Classification { n_classes: 2 },
                     GenKind::Checker { cells: 3 }, 600);
        let train: Vec<usize> = (0..480).collect();
        let test: Vec<usize> = (480..600).collect();
        let mut rng = Rng::new(0);
        let g = Gbm::fit(&ds, &train, &GbmParams::default(), &mut rng);
        let preds = g.predict(&ds, &test);
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let acc = balanced_accuracy(&yt, &preds.argmax_labels());
        assert!(acc > 0.8, "acc={acc}");
    }

    #[test]
    fn gbm_multiclass_probabilities_sum_to_one() {
        let ds = gen(Task::Classification { n_classes: 4 },
                     GenKind::Blobs { sep: 2.0 }, 400);
        let train: Vec<usize> = (0..300).collect();
        let mut rng = Rng::new(1);
        let g = Gbm::fit(&ds, &train, &GbmParams {
            n_estimators: 20,
            ..Default::default()
        }, &mut rng);
        let rows: Vec<usize> = (300..340).collect();
        let preds = g.predict(&ds, &rows);
        for r in 0..rows.len() {
            let s: f32 = preds.score_row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
        }
    }

    #[test]
    fn gbm_regression_beats_mean_predictor() {
        let ds = gen(Task::Regression, GenKind::Friedman1, 600);
        let train: Vec<usize> = (0..480).collect();
        let test: Vec<usize> = (480..600).collect();
        let mut rng = Rng::new(2);
        let g = Gbm::fit(&ds, &train, &GbmParams::default(), &mut rng);
        let preds = g.predict(&ds, &test);
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let mean: f32 = yt.iter().sum::<f32>() / yt.len() as f32;
        let mean_mse = mse(&yt, &vec![mean; yt.len()]);
        let got = mse(&yt, preds.values());
        assert!(got < mean_mse * 0.5, "mse {got} vs mean {mean_mse}");
    }

    #[test]
    fn hist_mode_bins_and_still_learns() {
        let ds = gen(Task::Classification { n_classes: 2 },
                     GenKind::Blobs { sep: 1.5 }, 500);
        let train: Vec<usize> = (0..400).collect();
        let test: Vec<usize> = (400..500).collect();
        let mut rng = Rng::new(3);
        let g = Gbm::fit(&ds, &train, &GbmParams {
            n_bins: 16,
            n_estimators: 30,
            ..Default::default()
        }, &mut rng);
        assert!(g.bins.is_some());
        let preds = g.predict(&ds, &test);
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        assert!(balanced_accuracy(&yt, &preds.argmax_labels()) > 0.85);
    }

    #[test]
    fn adaboost_improves_over_single_stump() {
        let ds = gen(Task::Classification { n_classes: 2 },
                     GenKind::Checker { cells: 2 }, 600);
        let train: Vec<usize> = (0..480).collect();
        let test: Vec<usize> = (480..600).collect();
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let mut rng = Rng::new(4);
        let weak = AdaBoost::fit(&ds, &train, &AdaParams {
            n_estimators: 1, max_depth: 1, ..Default::default()
        }, &mut rng);
        let strong = AdaBoost::fit(&ds, &train, &AdaParams {
            n_estimators: 60, max_depth: 2, ..Default::default()
        }, &mut rng);
        let acc_weak = balanced_accuracy(
            &yt, &weak.predict(&ds, &test).argmax_labels());
        let acc_strong = balanced_accuracy(
            &yt, &strong.predict(&ds, &test).argmax_labels());
        assert!(acc_strong > acc_weak, "{acc_strong} <= {acc_weak}");
        assert!(acc_strong > 0.8, "{acc_strong}");
    }

    #[test]
    fn adaboost_regression_runs() {
        let ds = gen(Task::Regression, GenKind::PiecewiseReg { steps: 4 },
                     400);
        let train: Vec<usize> = (0..320).collect();
        let test: Vec<usize> = (320..400).collect();
        let mut rng = Rng::new(5);
        let a = AdaBoost::fit(&ds, &train, &AdaParams::default(), &mut rng);
        let preds = a.predict(&ds, &test);
        let yt: Vec<f32> = test.iter().map(|&i| ds.y[i]).collect();
        let mean: f32 = yt.iter().sum::<f32>() / yt.len() as f32;
        assert!(mse(&yt, preds.values())
            < mse(&yt, &vec![mean; yt.len()]));
    }
}
