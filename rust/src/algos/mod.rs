//! Algorithm arms (the paper's Table 12 analogue): native Rust tree /
//! probabilistic models plus PJRT-backed trainable models whose
//! training loop is the AOT-compiled JAX/Pallas artifact.
//!
//! Each arm exposes its own hyper-parameter [`ConfigSpace`]; the
//! conditioning block builds one child per arm, exactly like the
//! paper's per-algorithm decomposition.

pub mod boosting;
pub mod forest;
pub mod pjrt;
pub mod simple;
pub mod tree;

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::Result;

use crate::data::dataset::{Dataset, Predictions, Task};
use crate::runtime::Runtime;
use crate::space::{Config, ConfigSpace};
use crate::util::rng::Rng;

/// Per-evaluation context: the PJRT runtime (if artifacts are built),
/// a forked RNG stream and the multi-fidelity knob used by the
/// Hyperband-family optimizers (fraction of train subsample / GD
/// steps).
pub struct EvalContext<'a> {
    pub rng: Rng,
    pub runtime: Option<&'a Runtime>,
    pub fidelity: f64,
}

impl<'a> EvalContext<'a> {
    pub fn new(runtime: Option<&'a Runtime>, seed: u64) -> Self {
        EvalContext { rng: Rng::new(seed), runtime, fidelity: 1.0 }
    }
}

/// Row-block height for batched predict-path gathers. Blocks keep the
/// row-major staging buffer L1/L2-resident (1024 rows x d<=64 f32 =
/// 256 KiB worst case) while amortizing the per-column pointer walk in
/// [`Dataset::gather_rows_rowmajor`] across the whole block.
pub(crate) const PREDICT_BLOCK_ROWS: usize = 1024;

pub trait FittedModel {
    fn predict(&self, ds: &Dataset, rows: &[usize],
               ctx: &mut EvalContext) -> Predictions;
}

pub trait Algorithm: Send + Sync {
    fn name(&self) -> &str;
    fn space(&self) -> ConfigSpace;
    fn supports(&self, task: Task) -> bool;
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>>;
    /// Rough relative cost hint used by the blocks' cost model.
    fn cost_hint(&self) -> f64 {
        1.0
    }
}

/// Subsample training rows according to the fidelity knob. Full
/// fidelity borrows the caller's row set (the common case on the
/// final refit path) instead of copying it; the rng is only advanced
/// when an actual subsample is drawn, so the borrow is invisible to
/// downstream random streams.
pub(crate) fn fidelity_rows<'a>(train: &'a [usize], fidelity: f64,
                                rng: &mut Rng) -> Cow<'a, [usize]> {
    let f = fidelity.clamp(0.05, 1.0);
    if f >= 0.999 {
        return Cow::Borrowed(train);
    }
    let m = ((train.len() as f64 * f).round() as usize)
        .clamp(8.min(train.len()), train.len());
    Cow::Owned(rng.sample_indices(train.len(), m)
        .into_iter()
        .map(|i| train[i])
        .collect())
}

// ====================================================================
// Native arm wrappers
// ====================================================================

macro_rules! simple_fitted {
    ($name:ident, $model:ty) => {
        struct $name($model);
        impl FittedModel for $name {
            fn predict(&self, ds: &Dataset, rows: &[usize],
                       _ctx: &mut EvalContext) -> Predictions {
                self.0.predict(ds, rows)
            }
        }
    };
}

// ---- decision tree -------------------------------------------------

pub struct DecisionTreeAlgo;
struct FittedTree {
    tree: tree::Tree,
    task: Task,
}

impl FittedModel for FittedTree {
    fn predict(&self, ds: &Dataset, rows: &[usize],
               _ctx: &mut EvalContext) -> Predictions {
        // blocked gather: bounded row-major buffer, each source
        // column streamed once per block (util::kernels)
        let mut block = Vec::new();
        match self.task {
            Task::Classification { n_classes } => {
                let mut scores = vec![0.0f32; rows.len() * n_classes];
                for blo in (0..rows.len()).step_by(PREDICT_BLOCK_ROWS) {
                    let bhi = (blo + PREDICT_BLOCK_ROWS).min(rows.len());
                    ds.gather_rows_rowmajor(&rows[blo..bhi], &mut block);
                    for r in blo..bhi {
                        let buf = &block[(r - blo) * ds.d
                                         ..(r - blo + 1) * ds.d];
                        let dist = self.tree.predict_row(buf);
                        for c in 0..n_classes.min(dist.len()) {
                            scores[r * n_classes + c] = dist[c] as f32;
                        }
                    }
                }
                Predictions::ClassScores { n_classes, scores }
            }
            Task::Regression => {
                let mut vals = vec![0.0f32; rows.len()];
                for blo in (0..rows.len()).step_by(PREDICT_BLOCK_ROWS) {
                    let bhi = (blo + PREDICT_BLOCK_ROWS).min(rows.len());
                    ds.gather_rows_rowmajor(&rows[blo..bhi], &mut block);
                    for r in blo..bhi {
                        let buf = &block[(r - blo) * ds.d
                                         ..(r - blo + 1) * ds.d];
                        vals[r] = self.tree.predict_row(buf)[0] as f32;
                    }
                }
                Predictions::Values(vals)
            }
        }
    }
}

impl Algorithm for DecisionTreeAlgo {
    fn name(&self) -> &str {
        "decision_tree"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new()
            .cat("criterion", &["gini", "entropy"], "gini")
            .int("max_depth", 1, 20, 10)
            .int("min_samples_split", 2, 20, 2)
            .int("min_samples_leaf", 1, 20, 1)
            .float("max_features", 0.2, 1.0, 1.0)
    }
    fn supports(&self, _task: Task) -> bool {
        true
    }
    fn cost_hint(&self) -> f64 {
        0.5
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        let cls = ds.task.is_classification();
        let p = tree::TreeParams {
            max_depth: cfg.usize_or("max_depth", 10).max(1),
            min_samples_split: cfg.usize_or("min_samples_split", 2).max(2),
            min_samples_leaf: cfg.usize_or("min_samples_leaf", 1).max(1),
            max_features: cfg.f64_or("max_features", 1.0),
            criterion: if !cls {
                tree::Criterion::Mse
            } else if cfg.str_or("criterion", "gini") == "entropy" {
                tree::Criterion::Entropy
            } else {
                tree::Criterion::Gini
            },
            random_thresholds: false,
            n_classes: if cls { ds.task.n_classes() } else { 0 },
        };
        let y: Vec<f64> = ds.y.iter().map(|&v| v as f64).collect();
        let t = tree::Tree::fit_with(|i, j| ds.at(i, j), ds.d, &y,
                                     &rows, &p, &mut ctx.rng);
        Ok(Box::new(FittedTree { tree: t, task: ds.task }))
    }
}

// ---- forests -------------------------------------------------------

pub struct RandomForestAlgo;
pub struct ExtraTreesAlgo;
simple_fitted!(FittedForest, forest::Forest);

fn forest_space(extra: bool) -> ConfigSpace {
    let cs = ConfigSpace::new()
        .int("n_estimators", 10, 96, 32)
        .cat("criterion", &["gini", "entropy"], "gini")
        .int("max_depth", 3, 20, 12)
        .int("min_samples_leaf", 1, 10, 1)
        .float("max_features", 0.1, 1.0, 0.7);
    if extra {
        cs
    } else {
        cs.cat("bootstrap", &["true", "false"], "true")
    }
}

fn fit_forest(extra: bool, ds: &Dataset, train: &[usize], cfg: &Config,
              ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
    let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
    let p = forest::ForestParams {
        n_estimators: cfg.usize_or("n_estimators", 32).max(1),
        max_depth: cfg.usize_or("max_depth", 12).max(1),
        min_samples_split: 2 * cfg.usize_or("min_samples_leaf", 1).max(1),
        min_samples_leaf: cfg.usize_or("min_samples_leaf", 1).max(1),
        max_features: cfg.f64_or("max_features", 0.7),
        bootstrap: cfg.str_or("bootstrap", "true") == "true",
        criterion: if !ds.task.is_classification() {
            tree::Criterion::Mse
        } else if cfg.str_or("criterion", "gini") == "entropy" {
            tree::Criterion::Entropy
        } else {
            tree::Criterion::Gini
        },
        extra,
    };
    let f = forest::Forest::fit(ds, &rows, &p, &mut ctx.rng);
    Ok(Box::new(FittedForest(f)))
}

impl Algorithm for RandomForestAlgo {
    fn name(&self) -> &str {
        "random_forest"
    }
    fn space(&self) -> ConfigSpace {
        forest_space(false)
    }
    fn supports(&self, _task: Task) -> bool {
        true
    }
    fn cost_hint(&self) -> f64 {
        3.0
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        fit_forest(false, ds, train, cfg, ctx)
    }
}

impl Algorithm for ExtraTreesAlgo {
    fn name(&self) -> &str {
        "extra_trees"
    }
    fn space(&self) -> ConfigSpace {
        forest_space(true)
    }
    fn supports(&self, _task: Task) -> bool {
        true
    }
    fn cost_hint(&self) -> f64 {
        2.0
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        fit_forest(true, ds, train, cfg, ctx)
    }
}

// ---- boosting ------------------------------------------------------

pub struct GradientBoostingAlgo;
pub struct LightGbmAlgo;
pub struct AdaBoostAlgo;
simple_fitted!(FittedGbm, boosting::Gbm);
simple_fitted!(FittedAda, boosting::AdaBoost);

impl Algorithm for GradientBoostingAlgo {
    fn name(&self) -> &str {
        "gradient_boosting"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new()
            .int("n_estimators", 16, 128, 60)
            .log_float("learning_rate", 0.01, 0.5, 0.1)
            .int("max_depth", 2, 6, 3)
            .float("subsample", 0.5, 1.0, 0.9)
            .int("min_samples_leaf", 1, 10, 3)
    }
    fn supports(&self, _task: Task) -> bool {
        true
    }
    fn cost_hint(&self) -> f64 {
        4.0
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        let p = boosting::GbmParams {
            n_estimators: cfg.usize_or("n_estimators", 60).max(1),
            learning_rate: cfg.f64_or("learning_rate", 0.1),
            max_depth: cfg.usize_or("max_depth", 3).max(1),
            subsample: cfg.f64_or("subsample", 0.9),
            min_samples_leaf: cfg.usize_or("min_samples_leaf", 3).max(1),
            n_bins: 0,
        };
        let g = boosting::Gbm::fit(ds, &rows, &p, &mut ctx.rng);
        Ok(Box::new(FittedGbm(g)))
    }
}

impl Algorithm for LightGbmAlgo {
    fn name(&self) -> &str {
        "lightgbm"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new()
            .int("n_estimators", 16, 128, 60)
            .log_float("learning_rate", 0.01, 0.5, 0.1)
            .int("max_depth", 2, 8, 4)
            .int("n_bins", 8, 64, 32)
            .float("subsample", 0.5, 1.0, 0.9)
            .int("min_samples_leaf", 1, 20, 5)
    }
    fn supports(&self, _task: Task) -> bool {
        true
    }
    fn cost_hint(&self) -> f64 {
        3.0
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        let p = boosting::GbmParams {
            n_estimators: cfg.usize_or("n_estimators", 60).max(1),
            learning_rate: cfg.f64_or("learning_rate", 0.1),
            max_depth: cfg.usize_or("max_depth", 4).max(1),
            subsample: cfg.f64_or("subsample", 0.9),
            min_samples_leaf: cfg.usize_or("min_samples_leaf", 5).max(1),
            n_bins: cfg.usize_or("n_bins", 32).max(2),
        };
        let g = boosting::Gbm::fit(ds, &rows, &p, &mut ctx.rng);
        Ok(Box::new(FittedGbm(g)))
    }
}

impl Algorithm for AdaBoostAlgo {
    fn name(&self) -> &str {
        "adaboost"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new()
            .int("n_estimators", 16, 96, 40)
            .log_float("learning_rate", 0.05, 2.0, 1.0)
            .int("max_depth", 1, 4, 2)
    }
    fn supports(&self, _task: Task) -> bool {
        true
    }
    fn cost_hint(&self) -> f64 {
        2.0
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        let p = boosting::AdaParams {
            n_estimators: cfg.usize_or("n_estimators", 40).max(1),
            learning_rate: cfg.f64_or("learning_rate", 1.0),
            max_depth: cfg.usize_or("max_depth", 2).max(1),
        };
        let a = boosting::AdaBoost::fit(ds, &rows, &p, &mut ctx.rng);
        Ok(Box::new(FittedAda(a)))
    }
}

// ---- probabilistic arms --------------------------------------------

pub struct GaussianNbAlgo;
pub struct LdaAlgo;
pub struct QdaAlgo;
simple_fitted!(FittedNb, simple::GaussianNb);
simple_fitted!(FittedDisc, simple::Discriminant);

impl Algorithm for GaussianNbAlgo {
    fn name(&self) -> &str {
        "gaussian_nb"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new().log_float("var_smoothing", 1e-10, 1e-3, 1e-9)
    }
    fn supports(&self, task: Task) -> bool {
        task.is_classification()
    }
    fn cost_hint(&self) -> f64 {
        0.2
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        Ok(Box::new(FittedNb(simple::GaussianNb::fit(
            ds, &rows, cfg.f64_or("var_smoothing", 1e-9)))))
    }
}

impl Algorithm for LdaAlgo {
    fn name(&self) -> &str {
        "lda"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new()
            .float("shrinkage", 0.0, 0.9, 0.1)
            .cat("solver", &["cholesky"], "cholesky")
    }
    fn supports(&self, task: Task) -> bool {
        task.is_classification()
    }
    fn cost_hint(&self) -> f64 {
        0.4
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        let m = simple::Discriminant::fit(ds, &rows, true,
                                          cfg.f64_or("shrinkage", 0.1))
            .ok_or_else(|| anyhow::anyhow!("lda: singular covariance"))?;
        Ok(Box::new(FittedDisc(m)))
    }
}

impl Algorithm for QdaAlgo {
    fn name(&self) -> &str {
        "qda"
    }
    fn space(&self) -> ConfigSpace {
        ConfigSpace::new().float("reg_param", 0.0, 0.9, 0.1)
    }
    fn supports(&self, task: Task) -> bool {
        task.is_classification()
    }
    fn cost_hint(&self) -> f64 {
        0.5
    }
    fn fit(&self, ds: &Dataset, train: &[usize], cfg: &Config,
           ctx: &mut EvalContext) -> Result<Box<dyn FittedModel>> {
        let rows = fidelity_rows(train, ctx.fidelity, &mut ctx.rng);
        let m = simple::Discriminant::fit(ds, &rows, false,
                                          cfg.f64_or("reg_param", 0.1))
            .ok_or_else(|| anyhow::anyhow!("qda: singular covariance"))?;
        Ok(Box::new(FittedDisc(m)))
    }
}

// ====================================================================
// Roster
// ====================================================================

/// The algorithm roster for a task. PJRT-backed arms are included only
/// when a runtime is available (artifacts built).
pub fn roster(task: Task, with_pjrt: bool) -> Vec<Arc<dyn Algorithm>> {
    let mut v: Vec<Arc<dyn Algorithm>> = vec![
        Arc::new(DecisionTreeAlgo),
        Arc::new(RandomForestAlgo),
        Arc::new(ExtraTreesAlgo),
        Arc::new(GradientBoostingAlgo),
        Arc::new(LightGbmAlgo),
        Arc::new(AdaBoostAlgo),
    ];
    if task.is_classification() {
        v.push(Arc::new(GaussianNbAlgo));
        v.push(Arc::new(LdaAlgo));
        v.push(Arc::new(QdaAlgo));
    }
    if with_pjrt {
        v.extend(pjrt::pjrt_roster(task));
    }
    v.retain(|a| a.supports(task));
    v
}

pub fn algo_by_name(name: &str, task: Task) -> Option<Arc<dyn Algorithm>> {
    roster(task, true).into_iter().find(|a| a.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, GenKind, Profile};

    fn ds(task: Task) -> Dataset {
        generate(&Profile {
            name: "roster".into(),
            task,
            gen: if task.is_classification() {
                GenKind::Blobs { sep: 2.0 }
            } else {
                GenKind::LinearReg { informative: 4 }
            },
            n: 300,
            d: 8,
            noise: 0.05,
            imbalance: 1.0,
            redundant: 1,
            wild_scales: false,
            seed: 3,
        })
    }

    #[test]
    fn native_cls_roster_fits_and_predicts() {
        let task = Task::Classification { n_classes: 3 };
        let data = ds(task);
        let train: Vec<usize> = (0..240).collect();
        let test: Vec<usize> = (240..300).collect();
        for algo in roster(task, false) {
            let mut ctx = EvalContext::new(None, 7);
            let cfg = algo.space().default_config();
            let m = algo.fit(&data, &train, &cfg, &mut ctx)
                .unwrap_or_else(|e| panic!("{} fit: {e}", algo.name()));
            let p = m.predict(&data, &test, &mut ctx);
            assert_eq!(p.n(), test.len(), "{}", algo.name());
            let yt: Vec<f32> = test.iter().map(|&i| data.y[i]).collect();
            let acc = crate::data::metrics::balanced_accuracy(
                &yt, &p.argmax_labels());
            assert!(acc > 0.5, "{} acc={acc}", algo.name());
        }
    }

    #[test]
    fn native_reg_roster_fits_and_predicts() {
        let task = Task::Regression;
        let data = ds(task);
        let train: Vec<usize> = (0..240).collect();
        let test: Vec<usize> = (240..300).collect();
        let yt: Vec<f32> = test.iter().map(|&i| data.y[i]).collect();
        let mean: f32 = yt.iter().sum::<f32>() / yt.len() as f32;
        let base = crate::data::metrics::mse(&yt, &vec![mean; yt.len()]);
        for algo in roster(task, false) {
            let mut ctx = EvalContext::new(None, 8);
            let cfg = algo.space().default_config();
            let m = algo.fit(&data, &train, &cfg, &mut ctx)
                .unwrap_or_else(|e| panic!("{} fit: {e}", algo.name()));
            let p = m.predict(&data, &test, &mut ctx);
            let err = crate::data::metrics::mse(&yt, p.values());
            assert!(err < base, "{}: mse {err} vs baseline {base}",
                    algo.name());
        }
    }

    #[test]
    fn sampled_configs_never_crash() {
        let task = Task::Classification { n_classes: 2 };
        let data = ds(task);
        let train: Vec<usize> = (0..200).collect();
        let mut rng = Rng::new(5);
        for algo in roster(task, false) {
            let cs = algo.space();
            for _ in 0..5 {
                let cfg = cs.sample(&mut rng);
                let mut ctx = EvalContext::new(None, rng.next_u64());
                let m = algo.fit(&data, &train, &cfg, &mut ctx);
                assert!(m.is_ok(), "{} cfg {}", algo.name(), cfg.key());
            }
        }
    }

    #[test]
    fn fidelity_subsamples_train() {
        let mut rng = Rng::new(1);
        let train: Vec<usize> = (100..400).collect();
        let half = fidelity_rows(&train, 0.5, &mut rng);
        assert_eq!(half.len(), 150);
        assert!(half.iter().all(|i| train.contains(i)));
        let full = fidelity_rows(&train, 1.0, &mut rng);
        assert_eq!(full.len(), 300);
    }

    #[test]
    fn roster_counts_match_design() {
        assert_eq!(roster(Task::Classification { n_classes: 2 }, false)
            .len(), 9);
        assert_eq!(roster(Task::Regression, false).len(), 6);
    }
}
