//! Bench harness (no `criterion` offline): wall-clock timing with
//! warmup + repetition statistics, and an ASCII table printer used by
//! every `benches/*.rs` target to render the paper's tables/figures.

use std::time::Instant;

/// Timing summary of one benchmarked closure.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    /// Median of the measured runs — the number the perf-trajectory
    /// gate (`tools/benchdiff`) compares against `BENCH_baseline.json`;
    /// far less sensitive to scheduler noise spikes than the mean.
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn per_iter_label(&self) -> String {
        fmt_duration(self.mean_s)
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark `f` with `warmup` unmeasured runs then `iters` measured
/// runs; returns per-run statistics.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                         mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = crate::util::stats::mean(&samples);
    let std = crate::util::stats::std_dev(&samples);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0, f64::max);
    let median = median_of(&samples);
    Timing {
        name: name.to_string(),
        iters: iters.max(1),
        mean_s: mean,
        median_s: median,
        std_s: std,
        min_s: min,
        max_s: max,
    }
}

/// Median of a non-empty sample set (even count: mean of the two
/// central order statistics).
fn median_of(samples: &[f64]) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    if n == 0 {
        return 0.0;
    }
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

/// ASCII table printer (right-aligned numeric columns) for paper-style
/// result tables.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn row_f(&mut self, name: &str, vals: &[f64], prec: usize) {
        let mut cells = vec![name.to_string()];
        cells.extend(vals.iter().map(|v| format!("{v:.prec$}")));
        self.row(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i == 0 {
                    line.push_str(&format!(" {:<w$} ", cells[i],
                                           w = widths[i]));
                } else {
                    line.push_str(&format!("| {:>w$} ", cells[i],
                                           w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render an ASCII "figure": one labelled series of (x, y) points as a
/// compact text curve — used to reproduce the paper's figures in
/// terminal output and bench logs.
pub fn render_curves(title: &str, xlabel: &str,
                     series: &[(String, Vec<(f64, f64)>)]) -> String {
    let mut out = format!("\n== {title} ==   (x = {xlabel})\n");
    for (name, pts) in series {
        out.push_str(&format!("  {name:>24}: "));
        for (x, y) in pts {
            out.push_str(&format!("({x:.4}, {y:.4}) "));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let t = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(t.mean_s > 0.0);
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s + 1e-12);
        assert!(t.min_s <= t.median_s && t.median_s <= t.max_s + 1e-12);
        assert_eq!(t.iters, 5);
    }

    #[test]
    fn median_is_order_statistic() {
        assert_eq!(median_of(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median_of(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median_of(&[7.0]), 7.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["sys", "a", "b"]);
        t.row_f("volcano", &[1.25, 2.0], 2);
        t.row_f("ausk", &[10.5, 0.125], 2);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("volcano"));
        assert!(s.contains("10.50"));
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(2.5e-9).contains("ns"));
        assert!(fmt_duration(3.0e-5).contains("µs"));
        assert!(fmt_duration(0.25).contains("ms"));
        assert!(fmt_duration(2.0).contains("s"));
    }

    #[test]
    #[should_panic(expected = "table row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

// ====================================================================
// Experiment-scale support for the paper-table bench targets
// ====================================================================

/// Experiment scale, controlled by `VOLCANO_BENCH=quick|std|full`.
/// `quick` (default) shrinks datasets / budgets so the whole table
/// suite completes on one CPU core; `full` uses the DESIGN.md scaled
/// budgets.
#[derive(Clone, Copy, Debug)]
pub struct BenchScale {
    /// cap on datasets per corpus
    pub datasets_cap: usize,
    /// cap on rows per dataset
    pub n_cap: usize,
    /// evaluation budget per system run
    pub evals: usize,
    /// repetitions (seeds) per cell
    pub reps: usize,
}

pub fn bench_scale() -> BenchScale {
    match std::env::var("VOLCANO_BENCH").as_deref() {
        Ok("full") => BenchScale {
            datasets_cap: usize::MAX,
            n_cap: usize::MAX,
            evals: 150,
            reps: 3,
        },
        Ok("std") => BenchScale {
            datasets_cap: 10,
            n_cap: 1200,
            evals: 60,
            reps: 1,
        },
        _ => BenchScale {
            datasets_cap: 4,
            n_cap: 600,
            evals: 20,
            reps: 1,
        },
    }
}

/// Shrink a registry profile to the bench scale.
pub fn shrink_profile(mut p: crate::data::synthetic::Profile,
                      scale: &BenchScale)
    -> crate::data::synthetic::Profile {
    p.n = p.n.min(scale.n_cap);
    p
}

/// Where bench targets drop machine-readable results.
pub fn results_dir() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from("target/bench_results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

pub fn save_results(name: &str, v: &crate::util::json::Json) {
    let path = results_dir().join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, v.to_string()) {
        eprintln!("warn: could not write {}: {e}", path.display());
    } else {
        println!("[results -> {}]", path.display());
    }
}

/// Workspace root (parent of this crate's directory): where the
/// `BENCH_*.json` summaries land so CI can upload them as artifacts
/// without digging through `target/`.
fn workspace_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."))
}

/// Drop a machine-readable bench summary at the repo root as
/// `BENCH_<name>.json`. Hot-path benches call this in addition to
/// `save_results` so the summary survives a `cargo clean` and the CI
/// artifact step has a fixed path to upload.
pub fn save_bench_summary(name: &str, v: &crate::util::json::Json) {
    let path = workspace_root().join(format!("BENCH_{name}.json"));
    if let Err(e) = std::fs::write(&path, v.to_string()) {
        eprintln!("warn: could not write {}: {e}", path.display());
    } else {
        println!("[bench summary -> {}]", path.display());
    }
}

/// Peak resident set size of this process in bytes (Linux `VmHWM`,
/// `None` elsewhere). The large-data bench reports it next to its
/// timings: the columnar substrate's acceptance criterion is a lower
/// peak than a row-major copy-per-split run would need.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let kb: u64 = status
        .lines()
        .find(|l| l.starts_with("VmHWM:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()?;
    Some(kb * 1024)
}

/// JSON row for one [`Timing`] (used by the `BENCH_*.json` summaries).
pub fn timing_to_json(t: &Timing) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("operation", Json::Str(t.name.clone())),
        ("iters", Json::Num(t.iters as f64)),
        ("mean_s", Json::Num(t.mean_s)),
        ("median_s", Json::Num(t.median_s)),
        ("std_s", Json::Num(t.std_s)),
        ("min_s", Json::Num(t.min_s)),
        ("max_s", Json::Num(t.max_s)),
    ])
}

/// Shared parser for the bench / driver knobs: `--<flag> N` (pass
/// after `--` under `cargo bench`/`cargo run`) wins over the env
/// var, which wins over `default`. `zero_ok` admits 0 as a real
/// value (the super-batch "whole round" setting); otherwise 0 and
/// unparseable values fall through.
fn bench_knob(flag: &str, env: &str, zero_ok: bool, default: usize)
    -> usize {
    let valid = |n: &usize| zero_ok || *n > 0;
    crate::cli::Args::from_env()
        .ok()
        .and_then(|a| a.usize_or(flag, usize::MAX).ok())
        .filter(|&n| n != usize::MAX)
        .filter(valid)
        .or_else(|| {
            std::env::var(env)
                .ok()
                .and_then(|v| v.parse().ok())
                .filter(valid)
        })
        .unwrap_or(default)
}

/// Worker threads for bench / driver runs: `--workers N` (pass after
/// `--` under `cargo bench`/`cargo run`) or the VOLCANO_WORKERS env
/// var; defaults to 1 (serial). N > 1 also proposes candidates in
/// batches of N, and batch BO reorders proposals — so expect small
/// deviations from the serial (N = 1) paper-table trajectories.
/// Worker count alone is trajectory-invariant only at a fixed batch
/// size (see rust/README.md).
pub fn bench_workers() -> usize {
    bench_knob("workers", "VOLCANO_WORKERS", false, 1)
}

/// Cross-leaf super-batch size for bench / driver runs:
/// `--super-batch N` (after `--`) or VOLCANO_SUPER_BATCH; defaults to
/// 1 (off — every leaf pull is its own batch). 0 submits a whole
/// conditioning round per `evaluate_batch` call. Like the leaf batch
/// size this is a semantic knob, so paper-table trajectories shift
/// when it is enabled (worker count alone still never changes them).
pub fn bench_super_batch() -> usize {
    bench_knob("super-batch", "VOLCANO_SUPER_BATCH", true, 1)
}

/// Async pipeline depth for bench / driver runs: `--pipeline-depth N`
/// (after `--`) or VOLCANO_PIPELINE_DEPTH; defaults to 1
/// (synchronous — today's trajectories bit for bit). With N > 1 the
/// coordinator speculatively proposes up to N - 1 chunks of the next
/// conditioning rounds while the current chunk evaluates on the
/// pool. Like the (super-)batch size this is a semantic knob; worker
/// count alone still never changes trajectories at a fixed depth.
pub fn bench_pipeline_depth() -> usize {
    bench_knob("pipeline-depth", "VOLCANO_PIPELINE_DEPTH", false, 1)
}

/// FE artifact-store byte budget (in MB) for bench / driver runs:
/// `--fe-cache-mb N` (after `--`) or VOLCANO_FE_CACHE_MB; defaults
/// to 0 (store off — every evaluation recomputes its FE pipeline).
/// Unlike the batching knobs this is *not* semantic: artifacts are
/// content-addressed by everything their computation depends on, so
/// any bound leaves trajectories bit-identical — a pure wall-clock
/// knob, safe to flip on paper-table runs.
pub fn bench_fe_cache_mb() -> usize {
    bench_knob("fe-cache-mb", "VOLCANO_FE_CACHE_MB", true, 0)
}

/// Open the PJRT runtime if artifacts are built (bench targets degrade
/// to the native roster otherwise, with a warning).
pub fn try_runtime() -> Option<crate::runtime::Runtime> {
    let dir = crate::runtime::Runtime::default_dir();
    match crate::runtime::Runtime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("warn: PJRT runtime unavailable ({e}); \
                       running with native arms only");
            None
        }
    }
}

/// Result grid of systems x datasets.
pub struct Matrix {
    pub datasets: Vec<String>,
    pub systems: Vec<String>,
    /// utility[ds][sys] (higher better)
    pub utility: Vec<Vec<f64>>,
    /// natural metric value[ds][sys]
    pub metric_value: Vec<Vec<f64>>,
}

impl Matrix {
    pub fn average_ranks(&self) -> Vec<f64> {
        crate::util::stats::average_ranks(&self.utility, true, 1e-4)
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("datasets", Json::arr_str(&self.datasets)),
            ("systems", Json::arr_str(&self.systems)),
            ("utility", Json::Arr(self.utility.iter()
                .map(|r| Json::arr_f64(r)).collect())),
            ("metric_value", Json::Arr(self.metric_value.iter()
                .map(|r| Json::arr_f64(r)).collect())),
        ])
    }
}

/// Run every system on every dataset profile (the shared shape of the
/// paper's table experiments). Metric chosen per task (balanced
/// accuracy / MSE). Failures score at the crash floor.
pub fn run_matrix(profiles: &[crate::data::synthetic::Profile],
                  systems: &[crate::baselines::SystemKind],
                  scale: crate::coordinator::SpaceScale,
                  evals: usize, seed: u64,
                  corpus: Option<&crate::meta::MetaCorpus>,
                  runtime: Option<&crate::runtime::Runtime>) -> Matrix {
    use crate::baselines::{run_system, BaseSpec};
    let mut utility = Vec::new();
    let mut metric_value = Vec::new();
    for profile in profiles {
        let ds = crate::data::synthetic::generate(profile);
        let metric = if ds.task.is_classification() {
            crate::data::metrics::Metric::BalancedAccuracy
        } else {
            crate::data::metrics::Metric::Mse
        };
        let spec = BaseSpec {
            scale,
            metric,
            max_evals: evals,
            budget_secs: f64::INFINITY,
            workers: bench_workers(),
            super_batch: bench_super_batch(),
            pipeline_depth: bench_pipeline_depth(),
            fe_cache_mb: bench_fe_cache_mb(),
            seed,
        };
        let mut urow = Vec::new();
        let mut mrow = Vec::new();
        let t0 = std::time::Instant::now();
        for &sys in systems {
            match run_system(sys, &ds, &spec, corpus, runtime) {
                Ok(out) => {
                    urow.push(out.ensemble_test_utility
                        .max(out.test_utility));
                    mrow.push(out.test_metric_value);
                }
                Err(e) => {
                    eprintln!("  {} on {}: {e}", sys.name(), ds.name);
                    urow.push(f64::NEG_INFINITY);
                    mrow.push(f64::NAN);
                }
            }
        }
        eprintln!("  [{}] done in {:.1}s", ds.name,
                  t0.elapsed().as_secs_f64());
        utility.push(urow);
        metric_value.push(mrow);
    }
    Matrix {
        datasets: profiles.iter().map(|p| p.name.clone()).collect(),
        systems: systems.iter().map(|s| s.name()).collect(),
        utility,
        metric_value,
    }
}
