//! Coordinator: joint-space construction, the pipeline evaluator (the
//! black-box objective f), and the top-level VolcanoML system with the
//! paper's public API shape (DataManager / Classifier / Regressor
//! analogues; Appendix A.2.2).

pub mod automl;
pub mod evaluator;

use std::sync::Arc;

use crate::algos::Algorithm;
use crate::data::dataset::Task;
use crate::fe::FePipeline;
use crate::space::{Condition, ConfigSpace};

/// The three search-space scales of §6.5 (20 / 29 / ~100
/// hyper-parameters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpaceScale {
    Small,
    Medium,
    Large,
}

impl SpaceScale {
    pub fn parse(s: &str) -> Option<SpaceScale> {
        Some(match s {
            "small" => SpaceScale::Small,
            "medium" => SpaceScale::Medium,
            "large" => SpaceScale::Large,
            _ => return None,
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            SpaceScale::Small => "small",
            SpaceScale::Medium => "medium",
            SpaceScale::Large => "large",
        }
    }
}

/// Algorithm roster per scale (§6.5: small = random forest only;
/// medium = linear SVC + random forest + AdaBoost; large = the full
/// roster).
pub fn roster_for(scale: SpaceScale, task: Task, with_pjrt: bool)
    -> Vec<Arc<dyn Algorithm>> {
    let full = crate::algos::roster(task, with_pjrt);
    match scale {
        SpaceScale::Small => full
            .into_iter()
            .filter(|a| a.name() == "random_forest")
            .collect(),
        SpaceScale::Medium => full
            .into_iter()
            .filter(|a| {
                matches!(a.name(),
                         "random_forest" | "adaboost" | "linear_svc"
                         | "ridge")
            })
            .collect(),
        SpaceScale::Large => full,
    }
}

/// FE pipeline per scale (§6.5: small/medium use the four feature
/// selectors; large uses the full Fig 2 pipeline).
pub fn pipeline_for(scale: SpaceScale, enriched_smote: bool,
                    with_embedding: bool) -> FePipeline {
    match scale {
        SpaceScale::Small | SpaceScale::Medium => {
            FePipeline::selectors_only()
        }
        SpaceScale::Large => {
            FePipeline::standard(enriched_smote, with_embedding)
        }
    }
}

/// Compose the joint AutoML space:
/// `algorithm` + conditional `alg.<name>:<hp>` + `fe:` params.
pub fn joint_space(pipeline: &FePipeline,
                   algos: &[Arc<dyn Algorithm>]) -> ConfigSpace {
    assert!(!algos.is_empty(), "empty algorithm roster");
    let names: Vec<&str> = algos.iter().map(|a| a.name()).collect();
    let mut cs = ConfigSpace::new().cat("algorithm", &names, names[0]);
    for algo in algos {
        for p in algo.space().params {
            let mut q = p.clone();
            q.name = format!("alg.{}:{}", algo.name(), p.name);
            q.condition = match q.condition {
                // intra-algo conditions keep their (renamed) parent
                Some(mut c) => {
                    c.parent = format!("alg.{}:{}", algo.name(),
                                       c.parent);
                    Some(c)
                }
                None => Some(Condition {
                    parent: "algorithm".into(),
                    values: vec![algo.name().to_string()],
                }),
            };
            cs.params.push(q);
        }
    }
    cs.merge_prefixed("fe", &pipeline.space())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_rosters_match_paper_sizes() {
        let t = Task::Classification { n_classes: 2 };
        assert_eq!(roster_for(SpaceScale::Small, t, false).len(), 1);
        assert_eq!(roster_for(SpaceScale::Medium, t, false).len(), 2);
        assert!(roster_for(SpaceScale::Large, t, false).len() >= 9);
    }

    #[test]
    fn space_sizes_grow_with_scale() {
        let t = Task::Classification { n_classes: 2 };
        let mut sizes = Vec::new();
        for scale in [SpaceScale::Small, SpaceScale::Medium,
                      SpaceScale::Large] {
            let pipeline = pipeline_for(scale, false, false);
            let algos = roster_for(scale, t, false);
            let space = joint_space(&pipeline, &algos);
            sizes.push(space.len());
        }
        assert!(sizes[0] < sizes[1] && sizes[1] < sizes[2],
                "{sizes:?}");
        // paper ladder: 20 / 29 / ~100 hyper-parameters
        assert!((15..=30).contains(&sizes[0]), "small={}", sizes[0]);
        // without the PJRT arms (artifacts absent in some test envs)
        // medium is smaller; with linear_svc it reaches the paper's 29
        assert!((15..=45).contains(&sizes[1]), "medium={}", sizes[1]);
        assert!(sizes[2] >= 60, "large={}", sizes[2]); // ~100 with PJRT arms
    }

    #[test]
    fn joint_space_conditions_algo_params_on_algorithm() {
        let t = Task::Classification { n_classes: 2 };
        let pipeline = pipeline_for(SpaceScale::Medium, false, false);
        let algos = roster_for(SpaceScale::Medium, t, false);
        let space = joint_space(&pipeline, &algos);
        let p = space
            .param("alg.random_forest:n_estimators")
            .expect("rf hp present");
        let cond = p.condition.as_ref().unwrap();
        assert_eq!(cond.parent, "algorithm");
        assert_eq!(cond.values, vec!["random_forest"]);
        // sampling activates only the chosen algorithm's params
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..20 {
            let cfg = space.sample(&mut rng);
            let algo = cfg.str_or("algorithm", "");
            for (k, _) in cfg.iter() {
                if let Some(rest) = k.strip_prefix("alg.") {
                    let owner = rest.split(':').next().unwrap();
                    assert_eq!(owner, algo, "{k} active under {algo}");
                }
            }
        }
    }
}
